"""Default reprolint configuration: scopes, registries, paths.

Everything here is the repo's contract with the checker.  Tests override
individual fields (``dataclasses.replace``) to point rules at fixture
trees; the CLI uses the defaults verbatim.
"""
from __future__ import annotations

import dataclasses
import pathlib


@dataclasses.dataclass(frozen=True)
class SchemaSpec:
    """One versioned persisted schema: where its shape lives (a dataclass's
    fields or a builder function's dict-literal keys) and which module
    constant versions it."""
    name: str
    kind: str           # "dataclass" | "dict_keys"
    file: str           # repo-relative file holding the shape
    symbol: str         # class name (dataclass) or function name (dict_keys)
    version_file: str   # repo-relative file holding the version constant
    version_const: str


# D-rules police the directories whose iteration orders / hashes feed event
# scheduling and persisted keys.  (tests/lint_fixtures is always in scope.)
# workload/ joined when chaos injectors made traffic programs RNG-bearing:
# injector randomness must be seeded-Generator-only (D103).
DETERMINISM_SCOPE = ("src/repro/core", "src/repro/net", "src/repro/api",
                     "src/repro/workload")

# classes on the per-packet/per-event path: H205 requires each to declare
# __slots__ covering every attribute its methods assign, and C304 pins the
# declared tuples against artifacts/schema_fingerprint.json
HOT_CLASSES: tuple[tuple[str, str], ...] = (
    ("src/repro/net/packet_sim.py", "FlowRT"),
    ("src/repro/net/packet_sim.py", "PacketSim"),
    ("src/repro/net/sharded_sim.py", "ShardedPacketSim"),
    ("src/repro/net/sharded_sim.py", "_LaneSim"),
    ("src/repro/net/hybrid_sim.py", "HybridSim"),
    ("src/repro/net/hybrid_sim.py", "HPart"),
    ("src/repro/net/soa.py", "FlowTable"),
    ("src/repro/net/soa.py", "LaneState"),
    ("src/repro/net/cca.py", "INTInfo"),
    ("src/repro/net/cca.py", "CCA"),
    ("src/repro/net/cca.py", "DCTCP"),
    ("src/repro/net/cca.py", "DCQCN"),
    ("src/repro/net/cca.py", "TIMELY"),
    ("src/repro/net/cca.py", "HPCC"),
    ("src/repro/core/wormhole.py", "Part"),
)

# persisted, versioned shapes: changing a field without bumping the paired
# version constant orphans every artifact already on disk (the PR 2 lesson)
VERSIONED_SCHEMAS: tuple[SchemaSpec, ...] = (
    SchemaSpec("MemoEntry", "dataclass",
               "src/repro/core/memo.py", "MemoEntry",
               "src/repro/core/memo.py", "FORMAT_VERSION"),
    SchemaSpec("RunResult", "dataclass",
               "src/repro/api/results.py", "RunResult",
               "src/repro/api/store.py", "RECORD_VERSION"),
    SchemaSpec("run_store_record", "dict_keys",
               "src/repro/api/store.py", "_record",
               "src/repro/api/store.py", "RECORD_VERSION"),
    SchemaSpec("learned_params_meta", "dict_keys",
               "src/repro/learned/fit.py", "fit",
               "src/repro/learned/model.py", "PARAMS_VERSION"),
)

# spawn-worker entry modules (pickled-by-name functions live here) plus the
# store-service server/client (which must run in minimal, jax-free worker
# environments): their static module-level import closure must never reach
# jax — a worker that imports jax pays XLA startup per process and can
# deadlock on forked state
WORKER_ENTRIES = ("repro.net.sharded_sim", "repro.api.campaign",
                  "repro.api.serve", "repro.net.chaos")
BANNED_WORKER_IMPORTS = ("jax", "jaxlib")


@dataclasses.dataclass(frozen=True)
class Config:
    root: pathlib.Path
    # the fixture corpus deliberately violates every rule — it is scanned
    # only by tests/test_reprolint.py, never by the CI gate
    excludes: tuple[str, ...] = ("tests/lint_fixtures",)
    baseline_path: str = "tools/reprolint/baseline.json"
    fingerprint_path: str = "artifacts/schema_fingerprint.json"
    hot_classes: tuple[tuple[str, str], ...] = HOT_CLASSES
    schemas: tuple[SchemaSpec, ...] = VERSIONED_SCHEMAS
    worker_entries: tuple[str, ...] = WORKER_ENTRIES
    banned_worker_imports: tuple[str, ...] = BANNED_WORKER_IMPORTS
    module_roots: tuple[str, ...] = ("src",)
