"""``python -m reprolint`` entry point (PYTHONPATH must include tools/)."""
from .cli import main

raise SystemExit(main())
