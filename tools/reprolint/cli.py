"""reprolint command line.

Exit codes, mirroring ``benchmarks/ci_regression.py``:

* 0 — clean (no new findings, no stale baseline entries);
* 1 — findings (or stale baseline / fingerprint drift);
* 2 — usage / environment error (bad path, broken baseline file).
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

from . import rules_contracts
from .config import Config
from .engine import (
    all_rules,
    apply_baseline,
    iter_py_files,
    load_baseline,
    run_lint,
    write_baseline,
)


def find_repo_root(start: pathlib.Path) -> pathlib.Path:
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "pyproject.toml").exists() or (cand / ".git").exists():
            return cand
    return start


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="reprolint",
        description="AST invariant checker: determinism (D), hot path (H), "
                    "contracts (C), spawn safety (S).")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/directories to scan (default: src tests)")
    parser.add_argument("--format", choices=("text", "github"),
                        default="text",
                        help="finding output style (github = CI annotations)")
    parser.add_argument("--root", default=None,
                        help="repo root (default: walk up from cwd)")
    parser.add_argument("--baseline", default=None,
                        help="baseline file of grandfathered findings "
                             "(default: tools/reprolint/baseline.json)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from current findings")
    parser.add_argument("--fingerprint", default=None,
                        help="schema fingerprint path "
                             "(default: artifacts/schema_fingerprint.json)")
    parser.add_argument("--update", action="store_true",
                        help="regenerate the schema fingerprint (refuses "
                             "field changes without a version bump)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print every registered rule and exit")
    return parser


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for info in all_rules():
            scope = f"  [scope: {', '.join(info.scope)}]" if info.scope else ""
            print(f"{info.rule_id}  {info.summary}{scope}")
        return 0

    root = find_repo_root(
        pathlib.Path(args.root) if args.root else pathlib.Path.cwd())
    config = Config(root=root)
    if args.fingerprint:
        config = dataclasses.replace(config, fingerprint_path=args.fingerprint)
    if args.baseline:
        config = dataclasses.replace(config, baseline_path=args.baseline)

    if args.update:
        ok, messages = rules_contracts.update_fingerprint(config)
        for m in messages:
            print(m)
        return 0 if ok else 1

    paths = args.paths or ["src", "tests"]
    files = iter_py_files(paths, root, config.excludes)
    if not files:
        print(f"reprolint: no python files under {paths!r} (root={root})",
              file=sys.stderr)
        return 2
    _tree, findings, n_suppressed = run_lint(files, config)

    baseline_path = root / config.baseline_path
    try:
        baseline = load_baseline(baseline_path)
    except (ValueError, OSError) as e:
        print(f"reprolint: unreadable baseline {baseline_path}: {e}",
              file=sys.stderr)
        return 2

    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {config.baseline_path} "
              f"({len(findings)} grandfathered findings)")
        return 0

    new, grandfathered, stale = apply_baseline(findings, baseline)
    for f in new:
        print(f.github() if args.format == "github" else f.text())
    status = 0
    if new:
        status = 1
    if stale:
        status = 1
        for key in stale:
            print(f"stale baseline entry (finding no longer occurs): {key} "
                  f"— rerun with --update-baseline and commit the shrink",
                  file=sys.stderr)
    tail = (f"{len(files)} files, {len(new)} finding(s), "
            f"{len(grandfathered)} baselined, {n_suppressed} suppressed "
            f"by pragma")
    print(("reprolint: " + tail) if status else ("reprolint: clean — " + tail),
          file=sys.stderr)
    return status


if __name__ == "__main__":
    raise SystemExit(main())
