"""H-rules: hot-path discipline.

PR 7 specialized ``PacketSim.run`` into a tight loop — local counters,
batched drains, no per-event allocation.  Functions carrying the
``@hot_path`` decorator (``repro.hotpath.hot_path``) opt into these checks
so the next "just add a log line" diff fails review mechanically instead of
costing 15% of packet throughput six months later.
"""
from __future__ import annotations

import ast

from .astutil import (
    class_slots,
    functions_with_class,
    is_hot_path,
    self_attr_writes,
    walk_skipping_nested_functions,
)
from .engine import FileCtx, Finding, TreeCtx, rule, tree_rule

_LOG_MODULES = {"logging", "log", "logger", "warnings"}
_LOG_METHODS = {"debug", "info", "warning", "warn", "error", "exception",
                "critical", "log"}


def _hot_functions(ctx: FileCtx):
    for fn, cls in functions_with_class(ctx.tree):
        if is_hot_path(fn):
            yield fn, cls


@rule("H201", "no logging/print in @hot_path functions")
def h201_no_logging(ctx: FileCtx) -> list[Finding]:
    out: list[Finding] = []
    for fn, _cls in _hot_functions(ctx):
        for node in walk_skipping_nested_functions(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name) and func.id == "print":
                out.append(ctx.finding(
                    "H201", node,
                    f"print() inside @hot_path {fn.name}(): formats and "
                    f"flushes per event — hoist diagnostics out of the loop"))
            elif isinstance(func, ast.Attribute) \
                    and func.attr in _LOG_METHODS \
                    and isinstance(func.value, ast.Name) \
                    and func.value.id in _LOG_MODULES:
                out.append(ctx.finding(
                    "H201", node,
                    f"{func.value.id}.{func.attr}() inside @hot_path "
                    f"{fn.name}(): even a disabled logger formats its "
                    f"arguments — log before/after the loop instead"))
    return out


@rule("H202", "no itertools.count in @hot_path functions")
def h202_no_itertools_count(ctx: FileCtx) -> list[Finding]:
    """PR 7's lesson: ``next(itertools.count())`` is a C-call per event that
    an int increment beats 3x; hot loops keep the sequence counter local."""
    out: list[Finding] = []
    for fn, _cls in _hot_functions(ctx):
        for node in walk_skipping_nested_functions(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            is_count = (
                (isinstance(func, ast.Attribute) and func.attr == "count"
                 and isinstance(func.value, ast.Name)
                 and func.value.id == "itertools")
                or (isinstance(func, ast.Name) and func.id == "count"))
            if is_count:
                out.append(ctx.finding(
                    "H202", node,
                    f"itertools.count inside @hot_path {fn.name}(): use a "
                    f"local int counter and flush it back once at the end"))
    return out


@rule("H203", "no closure/lambda allocation in @hot_path functions")
def h203_no_closures(ctx: FileCtx) -> list[Finding]:
    out: list[Finding] = []
    for fn, _cls in _hot_functions(ctx):
        for node in walk_skipping_nested_functions(fn):
            if isinstance(node, ast.Lambda):
                out.append(ctx.finding(
                    "H203", node,
                    f"lambda allocated inside @hot_path {fn.name}(): each "
                    f"evaluation builds a new function object — hoist it to "
                    f"module/class scope"))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(ctx.finding(
                    "H203", node,
                    f"nested function {node.name}() defined inside @hot_path "
                    f"{fn.name}(): allocates a closure per call — hoist it "
                    f"out of the hot function"))
    return out


@rule("H204", "no attribute writes to un-slotted self in @hot_path methods")
def h204_slotted_writes(ctx: FileCtx) -> list[Finding]:
    """A ``self.x = ...`` on a ``__dict__``-backed instance is a dict store
    per event; hot classes declare ``__slots__`` so the same write is an
    array slot.  (Completeness against the hot-class registry is H205 —
    this rule only demands that the enclosing class declares *some*
    ``__slots__``.)"""
    out: list[Finding] = []
    for fn, cls in _hot_functions(ctx):
        if cls is None or class_slots(cls) is not None:
            continue
        for attr, node in self_attr_writes(fn):
            out.append(ctx.finding(
                "H204", node,
                f"self.{attr} write in @hot_path {cls.name}.{fn.name}() but "
                f"{cls.name} has no __slots__ — declare __slots__ so hot "
                f"attribute stores skip the instance __dict__"))
    return out


@tree_rule("H205", "registered hot classes declare complete __slots__")
def h205_hot_class_registry(tree: TreeCtx) -> list[Finding]:
    """Every (file, class) in ``config.hot_classes`` must declare
    ``__slots__`` covering every ``self.X`` its own methods assign.  Slots
    inherited along the statically-resolvable base chain count."""
    out: list[Finding] = []
    all_classes = tree.classes()

    def inherited_slots(cls: ast.ClassDef, seen: set[str]) -> set[str]:
        names: set[str] = set()
        for base in cls.bases:
            base_name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None)
            if base_name is None or base_name in seen:
                continue
            seen.add(base_name)
            entry = all_classes.get(base_name)
            if entry is None:
                continue
            _rel, base_cls = entry
            base_slots = class_slots(base_cls)
            if base_slots is not None:
                names.update(base_slots)
            names.update(inherited_slots(base_cls, seen))
        return names

    for rel, class_name in tree.config.hot_classes:
        ctx = tree.file(rel)
        if ctx is None:
            continue  # file not in this scan — nothing to check
        cls = next((n for n in ast.walk(ctx.tree)
                    if isinstance(n, ast.ClassDef) and n.name == class_name),
                   None)
        if cls is None:
            out.append(Finding(rel, 1, 1, "H205",
                               f"hot class {class_name} is registered in "
                               f"reprolint config but not defined in {rel}"))
            continue
        slots = class_slots(cls)
        if slots is None:
            out.append(ctx.finding(
                "H205", cls,
                f"{class_name} is in the hot-class registry but declares no "
                f"__slots__ (and is not @dataclass(slots=True))"))
            continue
        declared = set(slots) | inherited_slots(cls, {class_name})
        missing: dict[str, ast.AST] = {}
        for fn, fn_cls in functions_with_class(ctx.tree):
            if fn_cls is not cls:
                continue
            for attr, node in self_attr_writes(fn):
                if not attr.startswith("__") and attr not in declared \
                        and attr not in missing:
                    missing[attr] = node
        for attr, node in sorted(missing.items()):
            out.append(ctx.finding(
                "H205", node,
                f"{class_name}.{attr} is assigned but missing from "
                f"__slots__ — the write lands in a __dict__ that slotted "
                f"instances don't have"))
    return out
