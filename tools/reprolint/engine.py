"""reprolint core: file walking, rule registry, pragmas, baseline, output.

Rules come in two shapes:

* **file rules** (``@rule``) — ``fn(ctx: FileCtx) -> list[Finding]``, run once
  per scanned file, optionally restricted to a path ``scope``;
* **tree rules** (``@tree_rule``) — ``fn(tree: TreeCtx) -> list[Finding]``,
  run once per invocation over the whole scanned set (import graphs, schema
  fingerprints, hot-class registries).

Suppression layers, applied in order:

1. ``# reprolint: allow[RULE]`` pragmas on the finding's line, or on an
   immediately preceding comment-only line (``allow[*]`` allows everything);
2. the committed baseline file of grandfathered findings.  Baseline keys are
   ``rule|path|message`` with a count — deliberately line-free, so unrelated
   line churn cannot invalidate a grandfathered entry.  Stale baseline
   entries (grandfathered findings that no longer occur) fail the run the
   same way stale counters fail ``benchmarks/ci_regression.py``: rerun with
   ``--update-baseline`` and commit the shrink.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from collections.abc import Callable, Iterable, Sequence


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, addressed by repo-relative path + position."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def key(self) -> str:
        """Baseline identity: line-free so line churn keeps grandfathering."""
        return f"{self.rule}|{self.path}|{self.message}"

    def text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def github(self) -> str:
        return (f"::error file={self.path},line={self.line},col={self.col},"
                f"title=reprolint {self.rule}::{self.message}")


@dataclasses.dataclass(frozen=True)
class RuleInfo:
    rule_id: str
    summary: str
    kind: str                           # "file" | "tree"
    fn: Callable
    scope: tuple[str, ...] | None = None  # rel-path prefixes; None = everywhere


FILE_RULES: dict[str, RuleInfo] = {}
TREE_RULES: dict[str, RuleInfo] = {}


def rule(rule_id: str, summary: str, scope: Sequence[str] | None = None):
    """Register a per-file rule: ``fn(ctx: FileCtx) -> list[Finding]``."""
    def deco(fn):
        FILE_RULES[rule_id] = RuleInfo(rule_id, summary, "file", fn,
                                       tuple(scope) if scope else None)
        return fn
    return deco


def tree_rule(rule_id: str, summary: str):
    """Register a whole-tree rule: ``fn(tree: TreeCtx) -> list[Finding]``."""
    def deco(fn):
        TREE_RULES[rule_id] = RuleInfo(rule_id, summary, "tree", fn)
        return fn
    return deco


def all_rules() -> list[RuleInfo]:
    merged = list(FILE_RULES.values()) + list(TREE_RULES.values())
    return sorted(merged, key=lambda r: r.rule_id)


class FileCtx:
    """One parsed source file: path, text, AST, and finding factory."""

    def __init__(self, path: pathlib.Path, rel: str, source: str) -> None:
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.lines = source.splitlines()

    def finding(self, rule_id: str, node: ast.AST, message: str) -> Finding:
        return Finding(path=self.rel,
                       line=int(getattr(node, "lineno", 1) or 1),
                       col=int(getattr(node, "col_offset", 0) or 0) + 1,
                       rule=rule_id, message=message)


class TreeCtx:
    """The whole scanned set, for rules that reason across files."""

    def __init__(self, root: pathlib.Path, files: list[FileCtx], config) -> None:
        self.root = root
        self.files = files
        self.config = config
        self._by_rel = {f.rel: f for f in files}

    def file(self, rel: str) -> FileCtx | None:
        return self._by_rel.get(rel)

    def classes(self) -> dict[str, tuple[str, ast.ClassDef]]:
        """{class name -> (rel, ClassDef)} over every scanned file (last
        definition wins; class names are unique in this repo)."""
        out: dict[str, tuple[str, ast.ClassDef]] = {}
        for ctx in self.files:
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.ClassDef):
                    out[node.name] = (ctx.rel, node)
        return out


# ---------------------------------------------------------------------- #
# pragmas
# ---------------------------------------------------------------------- #
_PRAGMA = re.compile(r"#\s*reprolint:\s*allow\[([A-Za-z0-9_*, ]+)\]")
_COMMENT_ONLY = re.compile(r"^\s*#")


def pragma_lines(source: str) -> dict[int, set[str]]:
    """{1-based line -> allowed rule ids}.  A pragma on a comment-only line
    also covers the next line (for statements too long to annotate inline)."""
    allowed: dict[int, set[str]] = {}
    lines = source.splitlines()
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        allowed.setdefault(i, set()).update(rules)
        if _COMMENT_ONLY.match(text) and i < len(lines):
            allowed.setdefault(i + 1, set()).update(rules)
    return allowed


# ---------------------------------------------------------------------- #
# file discovery + scoping
# ---------------------------------------------------------------------- #
def iter_py_files(paths: Iterable[str | pathlib.Path], root: pathlib.Path,
                  excludes: Sequence[str]) -> list[tuple[pathlib.Path, str]]:
    """Resolve the scan set to ``[(abs path, repo-relative posix rel)]``,
    deduped, sorted, with ``excludes`` prefixes dropped."""
    found: list[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if not p.is_absolute():
            p = root / p
        if p.is_file() and p.suffix == ".py":
            found.append(p)
        elif p.is_dir():
            found.extend(sorted(p.rglob("*.py")))
    out: list[tuple[pathlib.Path, str]] = []
    seen: set[str] = set()
    rroot = root.resolve()
    for p in found:
        try:
            rel = p.resolve().relative_to(rroot).as_posix()
        except ValueError:
            rel = p.as_posix()
        if rel in seen:
            continue
        seen.add(rel)
        if any(rel == e or rel.startswith(e.rstrip("/") + "/")
               for e in excludes):
            continue
        out.append((p, rel))
    out.sort(key=lambda t: t[1])
    return out


def in_scope(rel: str, scope: tuple[str, ...] | None) -> bool:
    """Scoped rules still apply to the lint-fixture corpus, wherever it is
    scanned from — fixtures exist to prove every rule fires."""
    if scope is None:
        return True
    if "lint_fixtures" in rel:
        return True
    return any(rel == s or rel.startswith(s.rstrip("/") + "/") for s in scope)


# ---------------------------------------------------------------------- #
# lint driver
# ---------------------------------------------------------------------- #
def run_lint(file_list: Sequence[tuple[pathlib.Path, str]],
             config) -> tuple[TreeCtx, list[Finding], int]:
    """Parse, run every registered rule, apply pragmas.  Returns
    ``(tree, findings, n_suppressed)`` with findings sorted by position."""
    findings: list[Finding] = []
    ctxs: list[FileCtx] = []
    for path, rel in file_list:
        try:
            source = path.read_text()
            ctxs.append(FileCtx(path, rel, source))
        except SyntaxError as e:
            findings.append(Finding(rel, int(e.lineno or 1), 1, "E000",
                                    f"syntax error: {e.msg}"))
        except OSError as e:
            findings.append(Finding(rel, 1, 1, "E000", f"unreadable: {e}"))
    tree = TreeCtx(config.root, ctxs, config)
    for ctx in ctxs:
        for info in FILE_RULES.values():
            if in_scope(ctx.rel, info.scope):
                findings.extend(info.fn(ctx))
    for info in TREE_RULES.values():
        findings.extend(info.fn(tree))

    pragmas = {ctx.rel: pragma_lines(ctx.source) for ctx in ctxs}
    kept: list[Finding] = []
    suppressed = 0
    for f in findings:
        allowed = pragmas.get(f.path, {}).get(f.line, ())
        if f.rule in allowed or "*" in allowed:
            suppressed += 1
        else:
            kept.append(f)
    kept.sort()
    return tree, kept, suppressed


# ---------------------------------------------------------------------- #
# baseline (grandfathered findings)
# ---------------------------------------------------------------------- #
def load_baseline(path: pathlib.Path | None) -> dict[str, int]:
    if path is None or not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def write_baseline(path: pathlib.Path, findings: Sequence[Finding]) -> None:
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.key()] = counts.get(f.key(), 0) + 1
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"format_version": 1, "findings": dict(sorted(counts.items()))},
        indent=1) + "\n")


def apply_baseline(findings: Sequence[Finding], baseline: dict[str, int],
                   ) -> tuple[list[Finding], list[Finding], list[str]]:
    """Split findings into (new, grandfathered) and report stale baseline
    keys — grandfathered findings that no longer occur must be pruned with
    ``--update-baseline`` (mirrors the counter baseline's two-way diff)."""
    remaining = dict(baseline)
    new: list[Finding] = []
    grandfathered: list[Finding] = []
    for f in findings:
        k = f.key()
        if remaining.get(k, 0) > 0:
            remaining[k] -= 1
            grandfathered.append(f)
        else:
            new.append(f)
    stale = sorted(k for k, v in remaining.items() if v > 0)
    return new, grandfathered, stale
