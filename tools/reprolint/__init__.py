"""reprolint: AST-based invariant checker for the repro simulator.

Rule families (see README "Static analysis gates"):

* **D** — determinism: no process-salted hashes, address-derived keys,
  global RNG state, or set-order-dependent iteration in
  ``src/repro/{core,net,api}``;
* **H** — hot-path discipline inside ``@hot_path`` functions, plus complete
  ``__slots__`` on the registered hot classes;
* **C** — engine registry contracts and version-bump enforcement for
  persisted schemas (against ``artifacts/schema_fingerprint.json``);
* **S** — spawn safety: picklable submit targets, jax-free worker entries.

Importing the package registers every rule.
"""
from __future__ import annotations

from . import (  # noqa: F401  (imported for rule registration)
    rules_contracts,
    rules_determinism,
    rules_hotpath,
    rules_spawn,
)
from .config import Config, SchemaSpec  # noqa: F401
from .engine import (  # noqa: F401
    FILE_RULES,
    TREE_RULES,
    Finding,
    all_rules,
    apply_baseline,
    iter_py_files,
    load_baseline,
    run_lint,
    write_baseline,
)

__version__ = "0.1.0"
