"""C-rules: persisted contracts.

The engine registry's promise (``run(...) -> RunResult``, ``uses_db=True``
implies a ``db`` parameter) and the versioned-schema promise (changing a
persisted shape bumps its version constant) are both invisible to the type
checker and only intermittently exercised by tests.  These rules make them
structural.

The schema/slots fingerprint works exactly like
``benchmarks/ci_regression.py``'s counter baseline: the committed
``artifacts/schema_fingerprint.json`` records every versioned shape and
every hot class's ``__slots__`` tuple; any drift fails the run until
``--update`` regenerates it — and ``--update`` itself REFUSES to record a
field change that was not paired with a version bump, so the one mutation
that orphans on-disk artifacts cannot be waved through.
"""
from __future__ import annotations

import ast
import json
import pathlib

from .astutil import annotated_field_names, class_slots, has_decorator
from .engine import FileCtx, Finding, TreeCtx, rule, tree_rule

FINGERPRINT_FORMAT_VERSION = 1


# ---------------------------------------------------------------------- #
# C301 / C302: engine contracts
# ---------------------------------------------------------------------- #
def _registered_engines(tree_ast: ast.AST):
    for node in ast.walk(tree_ast):
        if isinstance(node, ast.ClassDef) and has_decorator(node,
                                                            "register_engine"):
            yield node


def _find_method(cls: ast.ClassDef, name: str):
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and stmt.name == name:
            return stmt
    return None


def _returns_in_scope(fn):
    """Return statements belonging to ``fn`` itself (not nested defs)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Return):
            yield node
        stack.extend(ast.iter_child_nodes(node))


def _plausible_result(expr: ast.AST | None) -> bool:
    """Heuristic for "this expression can be a RunResult": calls, names,
    attribute/subscript chains, await.  Literals, None, tuples, dicts and
    comprehensions cannot be."""
    if expr is None:
        return False
    if isinstance(expr, ast.Await):
        return _plausible_result(expr.value)
    if isinstance(expr, ast.IfExp):
        return _plausible_result(expr.body) and _plausible_result(expr.orelse)
    return isinstance(expr, (ast.Call, ast.Name, ast.Attribute,
                             ast.Subscript))


@rule("C301", "@register_engine run() must return RunResult")
def c301_engine_returns(ctx: FileCtx) -> list[Finding]:
    out: list[Finding] = []
    for cls in _registered_engines(ctx.tree):
        run = _find_method(cls, "run")
        if run is None:
            out.append(ctx.finding(
                "C301", cls,
                f"@register_engine class {cls.name} defines no run() — the "
                f"registry contract is run(...) -> RunResult"))
            continue
        returns = list(_returns_in_scope(run))
        if not returns:
            out.append(ctx.finding(
                "C301", run,
                f"{cls.name}.run() never returns a value — the registry "
                f"contract is run(...) -> RunResult"))
            continue
        for ret in returns:
            if not _plausible_result(ret.value):
                what = ("bare return" if ret.value is None
                        else f"returns {ast.unparse(ret.value)}")
                out.append(ctx.finding(
                    "C301", ret,
                    f"{cls.name}.run() {what} — every path must return a "
                    f"RunResult"))
    return out


@rule("C302", "uses_db=True engines must accept a db parameter")
def c302_uses_db(ctx: FileCtx) -> list[Finding]:
    out: list[Finding] = []
    for cls in _registered_engines(ctx.tree):
        uses_db = False
        for stmt in cls.body:
            targets = stmt.targets if isinstance(stmt, ast.Assign) else (
                [stmt.target] if isinstance(stmt, ast.AnnAssign) else [])
            if any(isinstance(t, ast.Name) and t.id == "uses_db"
                   for t in targets):
                value = stmt.value
                uses_db = (isinstance(value, ast.Constant)
                           and value.value is True)
        if not uses_db:
            continue
        run = _find_method(cls, "run")
        if run is None:
            continue  # C301 already fires
        params = {a.arg for a in (run.args.posonlyargs + run.args.args
                                  + run.args.kwonlyargs)}
        if run.args.kwarg is not None:
            continue  # **opts threads db implicitly
        if "db" not in params:
            out.append(ctx.finding(
                "C302", run,
                f"{cls.name} declares uses_db=True but {cls.name}.run() "
                f"accepts no db parameter (and no **kwargs) — the db handle "
                f"cannot reach it"))
    return out


# ---------------------------------------------------------------------- #
# fingerprint extraction
# ---------------------------------------------------------------------- #
def _parse(root: pathlib.Path, rel: str) -> ast.Module | None:
    p = root / rel
    if not p.exists():
        return None
    return ast.parse(p.read_text(), filename=str(p))


def _module_const(tree_ast: ast.Module, name: str):
    for stmt in tree_ast.body:
        targets = stmt.targets if isinstance(stmt, ast.Assign) else (
            [stmt.target] if isinstance(stmt, ast.AnnAssign) else [])
        if any(isinstance(t, ast.Name) and t.id == name for t in targets):
            if isinstance(stmt.value, ast.Constant):
                return stmt.value.value
    return None


def _find_class(tree_ast: ast.Module, name: str) -> ast.ClassDef | None:
    for node in ast.walk(tree_ast):
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _find_function(tree_ast: ast.Module, name: str):
    for node in ast.walk(tree_ast):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node
    return None


def _dict_literal_keys(fn) -> list[str]:
    """Union of constant-string keys over every dict literal in ``fn`` —
    nested sub-dicts included, so reshaping e.g. ``meta["train"]`` is also a
    schema change."""
    keys: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
    return sorted(keys)


def extract_schema(root: pathlib.Path, spec) -> tuple[dict | None, str | None]:
    """Extract one schema entry ``{"version": ..., "fields": [...]}`` from
    source, or ``(None, error)``."""
    shape_tree = _parse(root, spec.file)
    if shape_tree is None:
        return None, f"{spec.file} not found"
    if spec.kind == "dataclass":
        cls = _find_class(shape_tree, spec.symbol)
        if cls is None:
            return None, f"class {spec.symbol} not found in {spec.file}"
        fields = annotated_field_names(cls)
    elif spec.kind == "dict_keys":
        fn = _find_function(shape_tree, spec.symbol)
        if fn is None:
            return None, f"function {spec.symbol} not found in {spec.file}"
        fields = _dict_literal_keys(fn)
    else:
        return None, f"unknown schema kind {spec.kind!r}"
    version_tree = shape_tree if spec.version_file == spec.file \
        else _parse(root, spec.version_file)
    if version_tree is None:
        return None, f"{spec.version_file} not found"
    version = _module_const(version_tree, spec.version_const)
    if version is None:
        return None, (f"version constant {spec.version_const} not found at "
                      f"module level of {spec.version_file}")
    return {"version": version, "fields": list(fields)}, None


def extract_fingerprint(config) -> tuple[dict, list[str]]:
    """Current fingerprint computed from source, plus extraction errors."""
    errors: list[str] = []
    schemas: dict[str, dict] = {}
    for spec in config.schemas:
        entry, err = extract_schema(config.root, spec)
        if err is not None:
            errors.append(f"schema {spec.name}: {err}")
        else:
            schemas[spec.name] = entry
    hot_slots: dict[str, list[str]] = {}
    for rel, class_name in config.hot_classes:
        tree_ast = _parse(config.root, rel)
        if tree_ast is None:
            errors.append(f"hot class {class_name}: {rel} not found")
            continue
        cls = _find_class(tree_ast, class_name)
        if cls is None:
            errors.append(f"hot class {class_name} not found in {rel}")
            continue
        slots = class_slots(cls)
        if slots is not None:
            hot_slots[f"{rel}:{class_name}"] = sorted(slots)
    fingerprint = {
        "format_version": FINGERPRINT_FORMAT_VERSION,
        "schemas": dict(sorted(schemas.items())),
        "hot_slots": dict(sorted(hot_slots.items())),
    }
    return fingerprint, errors


def load_fingerprint(path: pathlib.Path) -> dict | None:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def write_fingerprint(path: pathlib.Path, fingerprint: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(fingerprint, indent=1, sort_keys=True) + "\n")


def diff_fingerprint(current: dict, committed: dict,
                     ) -> tuple[list[str], list[str]]:
    """Compare source-derived vs committed fingerprints.

    Returns ``(violations, drifts)``: *violations* are field changes without
    a version bump — ``--update`` refuses these; *drifts* are everything
    else out of sync (new/removed schemas, version-bumped changes, slots
    churn) — fixed by rerunning ``--update`` and committing.
    """
    violations: list[str] = []
    drifts: list[str] = []
    cur_s = current.get("schemas", {})
    com_s = committed.get("schemas", {})
    for name in sorted(set(cur_s) | set(com_s)):
        if name not in com_s:
            drifts.append(f"schema {name} is new — run --update to record it")
        elif name not in cur_s:
            drifts.append(f"schema {name} left the config — run --update to "
                          f"prune it")
        else:
            cur, com = cur_s[name], com_s[name]
            fields_changed = list(cur["fields"]) != list(com["fields"])
            version_changed = cur["version"] != com["version"]
            if fields_changed and not version_changed:
                added = sorted(set(cur["fields"]) - set(com["fields"]))
                removed = sorted(set(com["fields"]) - set(cur["fields"]))
                delta = "; ".join(
                    s for s in (f"added {added}" if added else "",
                                f"removed {removed}" if removed else "",
                                "reordered" if not added and not removed
                                else "") if s)
                violations.append(
                    f"schema {name} changed ({delta}) but its version "
                    f"constant is still {com['version']} — bump it, then "
                    f"run --update")
            elif fields_changed or version_changed:
                drifts.append(
                    f"schema {name} changed with a version bump "
                    f"({com['version']} -> {cur['version']}) — run --update "
                    f"to commit the new fingerprint")
    cur_h = current.get("hot_slots", {})
    com_h = committed.get("hot_slots", {})
    for key in sorted(set(cur_h) | set(com_h)):
        if cur_h.get(key) != com_h.get(key):
            drifts.append(
                f"hot-class __slots__ for {key} no longer match the "
                f"committed fingerprint — run --update to acknowledge the "
                f"layout change")
    return violations, drifts


def update_fingerprint(config) -> tuple[bool, list[str]]:
    """``--update``: regenerate the fingerprint, REFUSING version-less field
    changes (additions-aware, like the counter baseline's two-way diff)."""
    current, errors = extract_fingerprint(config)
    if errors:
        return False, [f"extraction failed: {e}" for e in errors]
    path = config.root / config.fingerprint_path
    committed = load_fingerprint(path)
    messages: list[str] = []
    if committed is not None:
        violations, drifts = diff_fingerprint(current, committed)
        if violations:
            return False, [f"refusing to update: {v}" for v in violations]
        messages.extend(drifts)
    write_fingerprint(path, current)
    messages.append(f"wrote {config.fingerprint_path}")
    return True, messages


@tree_rule("C303", "versioned schema fields require a version bump")
def c303_schema_fingerprint(tree: TreeCtx) -> list[Finding]:
    config = tree.config
    if not config.schemas and not config.hot_classes:
        return []
    current, errors = extract_fingerprint(config)
    fp_rel = str(config.fingerprint_path)
    out = [Finding(fp_rel, 1, 1, "C303", f"fingerprint extraction: {e}")
           for e in errors]
    committed = load_fingerprint(config.root / config.fingerprint_path)
    if committed is None:
        out.append(Finding(
            fp_rel, 1, 1, "C303",
            "committed schema fingerprint is missing — generate it with "
            "`python -m reprolint --update` and commit it"))
        return out
    violations, drifts = diff_fingerprint(
        {"schemas": current["schemas"], "hot_slots": {}},
        {"schemas": committed.get("schemas", {}), "hot_slots": {}})
    for msg in violations + drifts:
        out.append(Finding(fp_rel, 1, 1, "C303", msg))
    return out


@tree_rule("C304", "hot-class __slots__ match the committed fingerprint")
def c304_slots_fingerprint(tree: TreeCtx) -> list[Finding]:
    config = tree.config
    if not config.hot_classes:
        return []
    committed = load_fingerprint(config.root / config.fingerprint_path)
    if committed is None:
        return []  # C303 already reports the missing file
    current, _errors = extract_fingerprint(config)
    _violations, drifts = diff_fingerprint(
        {"schemas": {}, "hot_slots": current["hot_slots"]},
        {"schemas": {}, "hot_slots": committed.get("hot_slots", {})})
    return [Finding(str(config.fingerprint_path), 1, 1, "C304", msg)
            for msg in drifts]
