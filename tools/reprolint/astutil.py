"""Shared AST helpers for the rule modules."""
from __future__ import annotations

import ast

FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def decorator_names(node: ast.ClassDef | ast.FunctionDef | ast.AsyncFunctionDef,
                    ) -> list[str]:
    """Dotted names of decorators (the callee for ``@deco(...)`` forms)."""
    out: list[str] = []
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(target)
        if name is not None:
            out.append(name)
    return out


def has_decorator(node, name: str) -> bool:
    """True if any decorator is ``name`` or ``*.name``."""
    return any(d == name or d.endswith("." + name)
               for d in decorator_names(node))


def is_hot_path(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    return has_decorator(fn, "hot_path")


def walk_skipping_nested_functions(node: ast.AST):
    """Yield descendants of ``node`` without descending into nested
    function/lambda bodies (their statements belong to another scope)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if isinstance(child, (*FunctionNode, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(child))


def functions_with_class(tree: ast.AST):
    """Yield ``(fn_node, enclosing ClassDef | None)`` for every function."""
    def visit(node: ast.AST, cls: ast.ClassDef | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child)
            elif isinstance(child, FunctionNode):
                yield (child, cls)
                yield from visit(child, None)
            else:
                yield from visit(child, cls)
    yield from visit(tree, None)


def dataclass_slots_flag(cls: ast.ClassDef) -> bool:
    """True for ``@dataclass(slots=True)`` / ``@dataclasses.dataclass(...)``."""
    for dec in cls.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        name = dotted(dec.func) or ""
        if name != "dataclass" and not name.endswith(".dataclass"):
            continue
        for kw in dec.keywords:
            if kw.arg == "slots" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


def annotated_field_names(cls: ast.ClassDef) -> list[str]:
    """Dataclass-style field names: AnnAssign targets in the class body,
    ClassVar annotations excluded (dataclass slots exclude them too)."""
    out: list[str] = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            ann = ast.unparse(stmt.annotation)
            if "ClassVar" in ann:
                continue
            out.append(stmt.target.id)
    return out


def class_slots(cls: ast.ClassDef) -> tuple[str, ...] | None:
    """The class's declared slots: an explicit ``__slots__`` assignment
    (tuple/list/set of string constants, or a single string), or the field
    names for ``@dataclass(slots=True)``.  None = un-slotted (instances get
    a ``__dict__``)."""
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        if not any(isinstance(t, ast.Name) and t.id == "__slots__"
                   for t in targets):
            continue
        value = stmt.value
        if isinstance(value, ast.Constant) and isinstance(value.value, str):
            return (value.value,)
        if isinstance(value, (ast.Tuple, ast.List, ast.Set)):
            names = []
            for elt in value.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    names.append(elt.value)
            return tuple(names)
        return ()  # dynamic __slots__ — treat as declared-but-unverifiable
    if dataclass_slots_flag(cls):
        return tuple(annotated_field_names(cls))
    return None


def self_attr_writes(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                     ) -> list[tuple[str, ast.AST]]:
    """``self.X`` assignment targets in ``fn`` (own scope only)."""
    out: list[tuple[str, ast.AST]] = []

    def targets_of(node):
        if isinstance(node, ast.Assign):
            return node.targets
        if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            return [node.target]
        return []

    for node in walk_skipping_nested_functions(fn):
        for t in targets_of(node):
            for leaf in ast.walk(t):
                if (isinstance(leaf, ast.Attribute)
                        and isinstance(leaf.value, ast.Name)
                        and leaf.value.id == "self"):
                    out.append((leaf.attr, leaf))
    return out


def import_aliases(tree: ast.AST) -> dict[str, str]:
    """{local alias -> dotted module} for every ``import`` in the file
    (function-level imports included — an alias is an alias)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for alias in node.names:
                out[alias.asname or alias.name] = f"{node.module}.{alias.name}"
    return out
