"""S-rules: spawn safety.

Worker pools here use the ``spawn`` start method, so everything shipped to
``submit`` is pickled by qualified name — lambdas and local closures fail at
runtime, on the first scenario big enough to shard.  And a worker module
whose import closure reaches jax pays XLA initialization per process (and
can deadlock on state forked before the pool started): PR 2 made the worker
entries jax-free, S402 keeps them that way.
"""
from __future__ import annotations

import ast
import pathlib

from .engine import FileCtx, Finding, TreeCtx, rule, tree_rule

_SUBMIT_NAMES = {"submit", "map", "apply_async", "starmap"}


@rule("S401", "no lambdas/local closures at executor submit sites")
def s401_submit_args(ctx: FileCtx) -> list[Finding]:
    out: list[Finding] = []
    # names of functions defined at non-module scope (closures): submitting
    # one pickles by qualname, which spawn workers cannot resolve
    local_fns: set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(node):
                if sub is node:
                    continue
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local_fns.add(sub.name)

    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _SUBMIT_NAMES):
            continue
        # only treat it as an executor call if the receiver smells like one
        recv = node.func.value
        recv_name = ""
        if isinstance(recv, ast.Name):
            recv_name = recv.id
        elif isinstance(recv, ast.Attribute):
            recv_name = recv.attr
        if not any(tok in recv_name.lower()
                   for tok in ("pool", "executor", "exec")):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                out.append(ctx.finding(
                    "S401", arg,
                    f"lambda passed to {recv_name}.{node.func.attr}(): "
                    f"spawn workers unpickle tasks by qualified name — pass "
                    f"a module-level function"))
            elif isinstance(arg, ast.Name) and arg.id in local_fns:
                out.append(ctx.finding(
                    "S401", arg,
                    f"locally-defined function {arg.id!r} passed to "
                    f"{recv_name}.{node.func.attr}(): closures don't pickle "
                    f"to spawn workers — hoist it to module level"))
    return out


def _module_rel_candidates(module: str, roots) -> list[str]:
    parts = module.split(".")
    out = []
    for root in roots:
        base = "/".join([root, *parts])
        out.append(base + ".py")
        out.append(base + "/__init__.py")
    return out


def _module_level_imports(tree_ast: ast.Module) -> list[tuple[str, int]]:
    """(dotted module, line) for every import reachable at import time —
    module body plus class bodies; function bodies and TYPE_CHECKING blocks
    are lazy and excluded."""
    out: list[tuple[str, int]] = []

    def is_type_checking(test: ast.AST) -> bool:
        return (isinstance(test, ast.Name) and test.id == "TYPE_CHECKING") \
            or (isinstance(test, ast.Attribute)
                and test.attr == "TYPE_CHECKING")

    def scan(body) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    out.append((alias.name, stmt.lineno))
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.level:  # relative import — resolved by the caller
                    out.append((f".{stmt.module or ''}", stmt.lineno))
                elif stmt.module:
                    out.append((stmt.module, stmt.lineno))
                    # `from pkg import sub` may bind a submodule
                    for alias in stmt.names:
                        out.append((f"{stmt.module}.{alias.name}",
                                    stmt.lineno))
            elif isinstance(stmt, ast.If):
                if not is_type_checking(stmt.test):
                    scan(stmt.body)
                scan(stmt.orelse)
            elif isinstance(stmt, (ast.Try, ast.With)):
                for field in ("body", "orelse", "finalbody"):
                    scan(getattr(stmt, field, []) or [])
                for h in getattr(stmt, "handlers", []) or []:
                    scan(h.body)
            elif isinstance(stmt, ast.ClassDef):
                scan(stmt.body)
    scan(tree_ast.body)
    return out


@tree_rule("S402", "worker entry modules must stay jax-free")
def s402_worker_imports(tree: TreeCtx) -> list[Finding]:
    """BFS the static module-level import graph from each worker entry in
    ``config.worker_entries``; report any path that reaches a banned import
    (jax/jaxlib), with the full chain so the offending edge is obvious."""
    config = tree.config
    root: pathlib.Path = config.root
    banned = tuple(config.banned_worker_imports)
    out: list[Finding] = []

    def resolve(module: str) -> tuple[str, ast.Module] | None:
        for rel in _module_rel_candidates(module, config.module_roots):
            ctx = tree.file(rel)
            if ctx is not None:
                return rel, ctx.tree
            p = root / rel
            if p.exists():
                try:
                    return rel, ast.parse(p.read_text(), filename=str(p))
                except SyntaxError:
                    return None
        return None

    def resolve_relative(importer: str, spec: str) -> list[str]:
        # single-level relative (`from . import x` / `from .mod import x`).
        # The anchor package differs for modules vs packages (__init__.py),
        # which the dotted name alone can't distinguish — emit both
        # candidates; resolve() drops the one that doesn't exist.
        tail = spec.lstrip(".")
        anchors = [importer]
        if "." in importer:
            anchors.append(importer.rsplit(".", 1)[0])
        return [f"{a}.{tail}" if tail else a for a in anchors]

    for entry in config.worker_entries:
        queue: list[tuple[str, list[str]]] = [(entry, [entry])]
        visited: set[str] = set()
        while queue:
            module, chain = queue.pop(0)
            if module in visited:
                continue
            visited.add(module)
            loc = resolve(module)
            if loc is None:
                continue  # stdlib / third-party that isn't banned
            rel, mod_ast = loc
            for raw, imp_line in _module_level_imports(mod_ast):
                candidates = (resolve_relative(module, raw)
                              if raw.startswith(".") else [raw])
                for imported in candidates:
                    top = imported.split(".")[0]
                    if top in banned:
                        out.append(Finding(
                            rel, imp_line, 1, "S402",
                            f"worker entry {entry} reaches '{imported}' at "
                            f"import time via {' -> '.join(chain)} — spawn "
                            f"workers must not initialize jax; make the "
                            f"import lazy (inside the function that needs "
                            f"it)"))
                        continue
                    # enqueue every dotted prefix: a.b.c imports a and a.b
                    parts = imported.split(".")
                    for i in range(1, len(parts) + 1):
                        prefix = ".".join(parts[:i])
                        if prefix not in visited:
                            queue.append((prefix, chain + [prefix]))
    return out
