"""D-rules: determinism.

Every key that outlives a process (SimDB buckets, run-store keys) and every
iteration order that feeds event scheduling must be a pure function of the
simulation inputs — never of ``PYTHONHASHSEED``, object addresses, global
RNG state, or set ordering.  PR 2's builtin-``hash()`` bug orphaned every
saved SimDB; these rules make that class of regression un-landable.
"""
from __future__ import annotations

import ast

from .config import DETERMINISM_SCOPE
from .engine import FileCtx, Finding, rule


@rule("D101", "builtin hash() is salted per process", scope=DETERMINISM_SCOPE)
def d101_builtin_hash(ctx: FileCtx) -> list[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            out.append(ctx.finding(
                "D101", node,
                "builtin hash() is salted per interpreter (PYTHONHASHSEED); "
                "use repro.core.fcg.stable_hash for any value that can "
                "outlive this process"))
    return out


@rule("D102", "id()-derived keys are run-dependent", scope=DETERMINISM_SCOPE)
def d102_id_keys(ctx: FileCtx) -> list[Finding]:
    """Flag ``id(x)`` flowing into key positions: dict keys, set elements,
    subscripts, ``*key*``-named call arguments or assignment targets."""
    out: list[Finding] = []

    def is_id_call(n: ast.AST) -> bool:
        return (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "id")

    def scan(node: ast.AST, keyish: bool) -> None:
        if is_id_call(node) and keyish:
            out.append(ctx.finding(
                "D102", node,
                "id() is a memory address — reused across objects and "
                "different every run; key on a stable identity (fid, name, "
                "stable_hash) instead"))
            return
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is not None:
                    scan(k, True)
            for v in node.values:
                scan(v, False)
            return
        if isinstance(node, ast.Set):
            for e in node.elts:
                scan(e, True)
            return
        if isinstance(node, ast.Subscript):
            scan(node.value, False)
            scan(node.slice, True)
            return
        if isinstance(node, ast.Assign):
            keyish_target = any(
                isinstance(t, ast.Name) and "key" in t.id.lower()
                for t in node.targets)
            for t in node.targets:
                scan(t, False)
            scan(node.value, keyish_target)
            return
        if isinstance(node, ast.Call):
            fname = node.func.attr if isinstance(node.func, ast.Attribute) \
                else (node.func.id if isinstance(node.func, ast.Name) else "")
            arg_keyish = "key" in fname.lower()
            scan(node.func, False)
            for a in node.args:
                scan(a, arg_keyish)
            for kw in node.keywords:
                scan(kw.value, arg_keyish or "key" in (kw.arg or "").lower())
            return
        for child in ast.iter_child_nodes(node):
            scan(child, keyish)

    scan(ctx.tree, False)
    return out


_SAFE_RANDOM = {"Random", "SystemRandom"}
_SAFE_NP_RANDOM = {"default_rng", "Generator", "SeedSequence", "PCG64",
                   "Philox", "SFC64", "MT19937", "BitGenerator"}


@rule("D103", "module-level RNG state is unseeded/shared",
      scope=DETERMINISM_SCOPE)
def d103_global_rng(ctx: FileCtx) -> list[Finding]:
    """Flag use of the ``random`` / ``np.random`` module-global generators.
    Constructing an explicitly seeded generator (``random.Random(seed)``,
    ``np.random.default_rng(seed)``) is the sanctioned pattern."""
    out: list[Finding] = []
    random_aliases: set[str] = set()
    numpy_aliases: set[str] = set()
    npr_aliases: set[str] = set()   # `from numpy import random as npr`
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                if alias.name == "random":
                    random_aliases.add(local)
                elif alias.name == "numpy":
                    numpy_aliases.add(local)
                elif alias.name == "numpy.random":
                    npr_aliases.add(alias.asname or "numpy")
        elif isinstance(node, ast.ImportFrom) and not node.level:
            if node.module == "random":
                for alias in node.names:
                    if alias.name not in _SAFE_RANDOM:
                        out.append(ctx.finding(
                            "D103", node,
                            f"'from random import {alias.name}' binds the "
                            f"process-global generator; use a seeded "
                            f"random.Random(seed) instance"))
            elif node.module == "numpy":
                for alias in node.names:
                    if alias.name == "random":
                        npr_aliases.add(alias.asname or "random")
            elif node.module == "numpy.random":
                for alias in node.names:
                    if alias.name not in _SAFE_NP_RANDOM:
                        out.append(ctx.finding(
                            "D103", node,
                            f"'from numpy.random import {alias.name}' binds "
                            f"the legacy global RandomState; use "
                            f"np.random.default_rng(seed)"))

    def np_random_base(n: ast.AST) -> bool:
        # `np.random` or a direct alias of numpy.random
        if isinstance(n, ast.Attribute) and n.attr == "random" \
                and isinstance(n.value, ast.Name) \
                and n.value.id in numpy_aliases:
            return True
        return isinstance(n, ast.Name) and n.id in npr_aliases

    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        func = node.func
        base, attr = func.value, func.attr
        if isinstance(base, ast.Name) and base.id in random_aliases \
                and attr not in _SAFE_RANDOM:
            out.append(ctx.finding(
                "D103", node,
                f"random.{attr}() draws from the process-global generator "
                f"(seed order couples unrelated call sites); use a seeded "
                f"random.Random(seed) instance"))
        elif np_random_base(base) and attr not in _SAFE_NP_RANDOM:
            out.append(ctx.finding(
                "D103", node,
                f"np.random.{attr}() uses the legacy global RandomState; "
                f"use np.random.default_rng(seed)"))
    return out


_SET_METHODS = {"union", "intersection", "difference",
                "symmetric_difference", "copy"}
_ORDER_INSENSITIVE = {"sorted", "min", "max", "sum", "len", "any", "all",
                      "set", "frozenset"}


def _is_set_expr(node: ast.AST, known: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in ("set",
                                                                "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SET_METHODS \
                and _is_set_expr(node.func.value, known):
            return True
    if isinstance(node, ast.Name):
        return node.id in known
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return (_is_set_expr(node.left, known)
                or _is_set_expr(node.right, known))
    return False


@rule("D104", "set iteration order feeds downstream state",
      scope=DETERMINISM_SCOPE)
def d104_set_iteration(ctx: FileCtx) -> list[Finding]:
    """Flag iteration over sets in order-preserving positions (``for``
    loops, list/dict comprehensions, ``list(s)``/``tuple(s)``): the order is
    a function of hashing + insertion history, which is exactly the kind of
    incidental state event scheduling and key construction must not read.
    ``sorted(s)`` / ``min``/``max``/``sum``/``len``/``any``/``all`` are the
    order-insensitive escapes; a pragma documents a deliberately
    order-dependent site."""
    out: list[Finding] = []
    msg = ("iteration order of a set is a function of hashing and insertion "
           "history; iterate sorted(...) (or justify the current order with "
           "`# reprolint: allow[D104]`)")

    def flag(node: ast.AST) -> None:
        out.append(ctx.finding("D104", node, msg))

    def scan_scope(body: list[ast.stmt], known: set[str]) -> None:
        for stmt in body:
            scan_stmt(stmt, known)

    def check_iter(it: ast.AST, known: set[str]) -> None:
        if _is_set_expr(it, known):
            flag(it)

    def scan_expr(node: ast.AST, known: set[str]) -> None:
        # a comprehension or list()/tuple() feeding an order-insensitive
        # reducer (max(x for x in s), sum(...), sorted(list(s))) is fine:
        # the set order never reaches the result
        exempt: set[int] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id in _ORDER_INSENSITIVE:
                for a in sub.args:
                    exempt.add(id(a))
        for sub in ast.walk(node):
            if id(sub) in exempt:
                continue
            if isinstance(sub, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
                for gen in sub.generators:
                    check_iter(gen.iter, known)
            elif isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                    and sub.func.id in ("list", "tuple") and len(sub.args) == 1:
                check_iter(sub.args[0], known)

    def set_annotated_params(fn) -> set[str]:
        names: set[str] = set()
        for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
            ann = a.annotation
            if isinstance(ann, ast.Subscript):
                ann = ann.value
            if isinstance(ann, ast.Name) and ann.id in ("set", "frozenset"):
                names.add(a.arg)
        return names

    def scan_stmt(stmt: ast.stmt, known: set[str]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_scope(stmt.body, set_annotated_params(stmt))
            return
        if isinstance(stmt, ast.ClassDef):
            scan_scope(stmt.body, set())
            return
        if isinstance(stmt, ast.For):
            check_iter(stmt.iter, known)
            scan_expr(stmt.iter, known)
            scan_scope(stmt.body, known)
            scan_scope(stmt.orelse, known)
            return
        if isinstance(stmt, ast.Assign):
            scan_expr(stmt.value, known)
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    if _is_set_expr(stmt.value, known):
                        known.add(t.id)
                    else:
                        known.discard(t.id)
            return
        if isinstance(stmt, ast.AugAssign):
            scan_expr(stmt.value, known)
            return
        # generic statement: scan expressions, recurse into nested bodies
        for field in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field, None)
            if inner:
                scan_scope(inner, known)
        handlers = getattr(stmt, "handlers", None)
        if handlers:
            for h in handlers:
                scan_scope(h.body, known)
        if not hasattr(stmt, "body"):
            scan_expr(stmt, known)
        else:
            # scan the statement's own expressions (test, items, value...)
            for field, value in ast.iter_fields(stmt):
                if field in ("body", "orelse", "finalbody", "handlers"):
                    continue
                if isinstance(value, ast.AST):
                    scan_expr(value, known)
                elif isinstance(value, list):
                    for v in value:
                        if isinstance(v, ast.AST):
                            scan_expr(v, known)

    scan_scope(ctx.tree.body, set())
    return out
