"""Distribution: logical-axis sharding rules, pipeline parallelism, and
gradient compression."""
