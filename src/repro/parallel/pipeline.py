"""Pipeline parallelism via shard_map + collective_permute (GPipe schedule).

The stage dimension maps onto a ``pipe`` mesh axis; microbatches stream
through stages with ppermute handoffs.  Bubble fraction = (P-1)/(M+P-1), so
callers should set microbatches M >> stages P.  This is a first-class
library feature exercised by tests on small CPU meshes; the production
dry-run meshes use DP×TP(+pod) per the assignment (PP composes by nesting a
``pipe`` axis into the mesh and wrapping the per-stage step with
``pipeline_apply``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def pipeline_apply(mesh: Mesh, stage_fn, n_microbatches: int):
    """stage_fn(stage_params, x_mb) -> y_mb, applied across the 'pipe' axis.

    x: [M, mb, ...] microbatched input living on stage 0's shard;
    returns the final stage's outputs in the same layout."""
    P = mesh.shape["pipe"]
    M = n_microbatches

    def per_stage(stage_params, xs):
        # shard_map keeps the sharded leading stage dim (local size 1): drop it
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index("pipe")
        steps = M + P - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def body(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any); others take the handoff
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), keepdims=False)
            x = jnp.where(stage == 0, mb_in, buf)
            active = (t - stage >= 0) & (t - stage < M)
            y = stage_fn(stage_params, x)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # pass to the next stage; the last stage records its output
            nxt = jax.lax.ppermute(y, "pipe",
                                   [(i, (i + 1) % P) for i in range(P)])
            out_idx = jnp.clip(t - stage, 0, M - 1)
            record = active & (stage == P - 1)
            outs = jnp.where(
                record,
                jax.lax.dynamic_update_index_in_dim(outs, y, out_idx, 0),
                outs)
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, steps, body, (buf, outs))
        # only the final stage recorded real outputs; make them replicated
        return jax.lax.psum(outs, "pipe")

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(PS("pipe"), PS()),      # params split by stage; data replicated
        out_specs=PS(),
        check_rep=False,
    )


def stage_split(params_stacked, n_stages: int):
    """Reshape a [L, ...]-stacked layer pytree into [P, L/P, ...] so the
    'pipe' axis shards whole stages."""
    def one(x):
        L = x.shape[0]
        assert L % n_stages == 0
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree.map(one, params_stacked)
