"""Gradient compression with error feedback (distributed-optimization trick
for bandwidth-bound DP at 1000+-node scale).

Two codecs, both with per-leaf error-feedback residuals [Seide'14; Lin'18]:
  * top-k sparsification (keep the k largest-magnitude entries per leaf)
  * int8 quantisation (per-leaf absmax scaling)

The train loop applies ``compress -> (wire) -> decompress`` around the
gradient all-reduce; under pjit the "wire" is implicit, so the measurable
effect here is the accuracy contract (tests) and the wire-bytes accounting
consumed by the Wormhole workload generator (a compressed DP phase shrinks
the elephant flows by the compression ratio).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | topk | int8
    topk_frac: float = 0.01
    error_feedback: bool = True

    def wire_ratio(self) -> float:
        """Fraction of raw gradient bytes on the wire (for traffic gen)."""
        if self.kind == "topk":
            return self.topk_frac * 3.0   # values + indices overhead
        if self.kind == "int8":
            return 0.25                   # bf16 -> int8 + scales
        return 1.0


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


@partial(jax.jit, static_argnames=("cfg",))
def compress_decompress(grads, residuals, cfg: CompressionConfig):
    """Returns (decompressed grads as seen after the wire, new residuals)."""
    if cfg.kind == "none":
        return grads, residuals

    def one(g, r):
        gf = g.astype(jnp.float32) + (r if cfg.error_feedback else 0.0)
        if cfg.kind == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
        elif cfg.kind == "topk":
            flat = gf.reshape(-1)
            k = max(1, int(cfg.topk_frac * flat.size))
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = jnp.abs(flat) >= thresh
            deq = jnp.where(mask, flat, 0.0).reshape(gf.shape)
        else:
            raise ValueError(cfg.kind)
        new_r = (gf - deq) if cfg.error_feedback else r
        return deq.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
