"""Logical-axis sharding rules (MaxText-style).

Every parameter/cache dimension carries a logical name; a per-(arch × mesh ×
shape) rules table maps names → mesh axes.  ``resolve`` validates
divisibility and mesh-axis reuse per tensor, dropping infeasible mappings to
replication — so one rules table covers every architecture without
special-casing (xlstm's 4 heads simply stay unsharded on a 16-wide model
axis, etc.).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# default logical -> mesh mapping; tuples = sharded over several axes
DEFAULT_RULES: dict[str | None, tuple[str, ...] | str | None] = {
    "embed": ("pod", "data"),     # ZeRO-3-style: params fully sharded over DP
    "mlp": "model",
    "expert_mlp": None,           # expert inner dim (used when experts shard)
    "heads": "model",
    "kv": "model",
    "head": None,
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "cache_seq": None,
    None: None,
}


def rules_for(cfg, mesh: Mesh, shape_kind: str, seq_len: int = 0,
              global_batch: int = 0, n_params: float = 0.0) -> dict:
    """Per-arch/per-cell adaptation of the default rules."""
    rules = dict(DEFAULT_RULES)
    model_size = mesh.shape.get("model", 1)
    if cfg.moe_experts and cfg.moe_experts % model_size != 0:
        # experts unshardable (mixtral: 8 experts, 16-wide model axis):
        # shard each expert's hidden dim instead
        rules["expert"] = None
        rules["expert_mlp"] = "model"
    if shape_kind == "decode":
        # Serving is weight-stationary: gathering ZeRO-sharded params every
        # token would dominate (§Perf iteration 'decode-sharding').
        #  * small models: replicate over DP, TP-resident weights;
        #  * beyond-HBM giants: keep weights fully sharded (2D tensor
        #    parallelism; experts additionally spread over every mesh axis —
        #    DeepSeek-style EP serving) and replicate the batch instead, so
        #    the per-token collectives move activations, never weights.
        tp_resident_gb = (n_params * 2 / model_size) / 1e9
        if n_params and tp_resident_gb <= 8.0:
            rules["embed"] = None
            rules["expert"] = rules["expert"] if cfg.moe_experts else None
        elif n_params:
            rules["act_batch"] = None          # batch replicated; weights stay
            if cfg.moe_experts:
                rules["expert"] = ("pod", "data", "model")
        dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
        if global_batch % dp != 0:
            # long_500k: batch 1 — parallelism must come from the model dims;
            # shard the KV/cache sequence instead (sequence-parallel decode)
            rules["act_batch"] = None
            rules["cache_seq"] = "data"
        if cfg.n_kv % model_size != 0:
            rules["kv"] = None
            if rules["cache_seq"] is None:
                rules["cache_seq"] = "model"
    return rules


def resolve(axes: tuple, shape: tuple, rules: dict, mesh: Mesh) -> PS:
    """Map logical axes -> PartitionSpec, enforcing divisibility and
    one-use-per-mesh-axis within the tensor."""
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, axes):
        mapping = rules.get(name, None)
        if mapping is None:
            out.append(None)
            continue
        axes_tuple = (mapping,) if isinstance(mapping, str) else tuple(mapping)
        picked = []
        size = 1
        for ax in axes_tuple:
            if ax in mesh.shape and ax not in used:
                if dim % (size * mesh.shape[ax]) == 0:
                    picked.append(ax)
                    size *= mesh.shape[ax]
        if picked:
            used.update(picked)
            out.append(tuple(picked) if len(picked) > 1 else picked[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return PS(*out)


def tree_shardings(axes_tree, struct_tree, rules: dict, mesh: Mesh):
    """NamedShardings for a pytree of ShapeDtypeStructs given its logical
    axes tree."""
    def one(axes, struct):
        return NamedSharding(mesh, resolve(tuple(axes), struct.shape, rules, mesh))
    return jax.tree.map(one, axes_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_shardings(batch_structs, rules: dict, mesh: Mesh):
    """Inputs: shard the leading (batch) dim; scalars replicate."""
    def one(struct):
        if struct.ndim == 0:
            return NamedSharding(mesh, PS())
        axes = ("act_batch",) + (None,) * (struct.ndim - 1)
        return NamedSharding(mesh, resolve(axes, struct.shape, rules, mesh))
    return jax.tree.map(one, batch_structs)
