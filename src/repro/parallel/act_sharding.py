"""Activation sharding constraints (sequence parallelism).

The dominant train-time memory term is the per-layer [B, S, d] scan carry
(the activation checkpoint).  Constraining it to P(batch=("pod","data"),
seq="model") shards the checkpoints over *all* mesh axes — sequence
parallelism in the Megatron-SP sense; GSPMD inserts the all-gathers inside
attention where the full sequence is genuinely needed.

The launcher installs the constraint (it knows the mesh + rules); model code
calls ``constrain`` unconditionally — a no-op unless installed.
"""
from __future__ import annotations

import jax

_SPEC = None  # NamedSharding | None


def install(sharding) -> None:
    global _SPEC
    _SPEC = sharding


def clear() -> None:
    install(None)


def constrain(x):
    if _SPEC is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _SPEC)
