"""Pure-jnp oracle for the fused DCTCP fluid step (matches
repro.net.fluid_jax.fluid_run's inline branch)."""
from __future__ import annotations

import jax.numpy as jnp


def cca_step_ref(R, W, alpha, delivered, size, line, rtt0, M, q, bw,
                 *, dt: float, g: float = 1 / 16, ecn_k: float = 64_000.0,
                 mss: float = 1000.0):
    p_l = jnp.clip((q - ecn_k) / (2 * ecn_k), 0.0, 1.0)
    qd = (q / bw) @ M.T
    rtt = rtt0 + qd
    p_f = jnp.max(M * p_l[None, :], axis=1)
    dtn = dt / rtt
    alpha2 = (1 - g * dtn) * alpha + g * dtn * p_f
    grow = mss * dtn * (1 - p_f)
    cut = p_f * alpha * W / 2 * dtn
    W2 = jnp.clip(W + grow - cut, mss, 2 * line * rtt0)
    active = delivered < size
    R2 = jnp.where(active, jnp.minimum(W2 / rtt, line), 0.0)
    delivered2 = jnp.minimum(delivered + R2 * dt, size)
    arrivals = R2 @ M
    return R2, W2, alpha2, delivered2, arrivals
