"""Fused DCTCP-fluid CCA step as a Pallas TPU kernel.

Layout: flows are tiled in blocks of BF=128 along the grid; each grid step
holds one (BF × L) tile of the flow↔link incidence matrix in VMEM together
with the full (L,) queue/bandwidth vectors.  Per-flow math is VPU
elementwise work; the two contractions (queue-delay row-reduce and arrival
column-reduce) are MXU/VPU reductions over the resident tile.  Link arrivals
accumulate across the sequential TPU grid into a single (L,) output block
(first block initialises, later blocks add) — the standard Pallas
accumulation pattern.

VMEM budget per grid step (f32): incidence tile 128·L·4B — for L ≤ 4096
that is ≤ 2 MiB, comfortably inside the ~16 MiB VMEM of a TPU core, leaving
room for the dozen (BF,)/(L,) vectors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BF = 128  # flow block (sublane-friendly multiple of 8, lane-width multiple)


def _cca_step_kernel(R_ref, W_ref, alpha_ref, dlv_ref, size_ref, line_ref,
                     rtt0_ref, M_ref, q_ref, bw_ref,
                     R2_ref, W2_ref, alpha2_ref, dlv2_ref, arr_ref,
                     *, dt: float, g: float, ecn_k: float, mss: float):
    i = pl.program_id(0)
    q = q_ref[...]
    bw = bw_ref[...]
    M = M_ref[...]
    p_l = jnp.clip((q - ecn_k) / (2 * ecn_k), 0.0, 1.0)
    qd = jnp.sum(M * (q / bw)[None, :], axis=1)
    rtt = rtt0_ref[...] + qd
    p_f = jnp.max(M * p_l[None, :], axis=1)
    dtn = dt / rtt
    alpha = alpha_ref[...]
    alpha2 = (1 - g * dtn) * alpha + g * dtn * p_f
    W = W_ref[...]
    grow = mss * dtn * (1 - p_f)
    cut = p_f * alpha * W * 0.5 * dtn
    line = line_ref[...]
    W2 = jnp.clip(W + grow - cut, mss, 2 * line * rtt0_ref[...])
    active = dlv_ref[...] < size_ref[...]
    R2 = jnp.where(active, jnp.minimum(W2 / rtt, line), 0.0)
    dlv2 = jnp.minimum(dlv_ref[...] + R2 * dt, size_ref[...])

    R2_ref[...] = R2
    W2_ref[...] = W2
    alpha2_ref[...] = alpha2
    dlv2_ref[...] = dlv2

    contrib = jnp.sum(M * R2[:, None], axis=0)

    @pl.when(i == 0)
    def _init():
        arr_ref[...] = contrib

    @pl.when(i > 0)
    def _acc():
        arr_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("dt", "g", "ecn_k", "mss", "interpret"))
def cca_step_padded(R, W, alpha, delivered, size, line, rtt0, M, q, bw,
                    *, dt: float, g: float, ecn_k: float, mss: float,
                    interpret: bool = True):
    """All inputs pre-padded: F % BF == 0.  Padded flows must have size=0,
    line=1, rtt0>0 so they stay inactive."""
    F, L = M.shape
    assert F % BF == 0, F
    grid = (F // BF,)
    flow_spec = pl.BlockSpec((BF,), lambda i: (i,))
    link_spec = pl.BlockSpec((L,), lambda i: (0,))
    kernel = functools.partial(_cca_step_kernel, dt=dt, g=g, ecn_k=ecn_k, mss=mss)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[flow_spec] * 7 + [pl.BlockSpec((BF, L), lambda i: (i, 0)),
                                    link_spec, link_spec],
        out_specs=[flow_spec] * 4 + [link_spec],
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32)] * 4
        + [jax.ShapeDtypeStruct((L,), jnp.float32)],
        interpret=interpret,
    )(R, W, alpha, delivered, size, line, rtt0, M, q, bw)
    return tuple(out)
