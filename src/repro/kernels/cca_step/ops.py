"""jit'd public wrapper: pads (flows, links) to kernel tile multiples,
dispatches the Pallas kernel, unpads.  On non-TPU backends the kernel runs
in interpret mode (CPU validation); on TPU set interpret=False."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.cca_step.kernel import BF, cca_step_padded

_LANES = 128


def _pad_to(x, n, fill=0.0):
    if x.shape[0] == n:
        return x
    return jnp.pad(x, (0, n - x.shape[0]), constant_values=fill)


@partial(jax.jit, static_argnames=("dt", "g", "ecn_k", "mss", "interpret"))
def cca_step(R, W, alpha, delivered, size, line, rtt0, M, q, bw, *,
             dt: float, g: float = 1 / 16, ecn_k: float = 64_000.0,
             mss: float = 1000.0, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    F, L = M.shape
    Fp = -(-F // BF) * BF
    Lp = -(-L // _LANES) * _LANES
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    Mp = jnp.pad(f32(M), ((0, Fp - F), (0, Lp - L)))
    args = (
        _pad_to(f32(R), Fp), _pad_to(f32(W), Fp, fill=mss),
        _pad_to(f32(alpha), Fp), _pad_to(f32(delivered), Fp),
        _pad_to(f32(size), Fp),                # padded flows: size 0 -> idle
        _pad_to(f32(line), Fp, fill=1.0),
        _pad_to(f32(rtt0), Fp, fill=1.0),
        Mp,
        _pad_to(f32(q), Lp), _pad_to(f32(bw), Lp, fill=1.0),
    )
    R2, W2, a2, d2, arr = cca_step_padded(
        *args, dt=dt, g=g, ecn_k=ecn_k, mss=mss, interpret=interpret)
    return R2[:F], W2[:F], a2[:F], d2[:F], arr[:L]
