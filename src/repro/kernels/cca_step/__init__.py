from repro.kernels.cca_step.ops import cca_step
