"""Pure-jnp oracle for fixed-point max-min water-filling.

Dense ``incidence [F, L]`` / ``cap [L]`` form (see
``ops.incidence_from_csr``).  Each round saturates the most-contended
link — smallest ``cap/users`` fair share — and freezes every flow that
crosses it; ties freeze together, which converges to the same allocation
as the one-link-at-a-time progressive loop because a link tied at share
``s`` still has share exactly ``s`` after the other tied links' users are
subtracted.  At most one round per link does work, so ``L`` static rounds
reach the fixed point and further rounds are identity.

Scope: paths must be *simple* (no repeated link within one flow's path —
true of every real route).  The historical dict solver decrements a
link's capacity once per *occurrence* while counting one user per flow;
0/1 incidence cannot express that quirk, so only the exact array solver
(``ops.maxmin_rates_arrays``) reproduces it bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 3e38                   # sentinel share for user-less links
NOLINK_RATE = 1e12           # rate for flows that cross no link (dict parity)


def maxmin_ref(inc, cap):
    """inc: [F, L] float 0/1 flow-over-link incidence; cap: [L] float
    capacities (bytes/s).  Returns [F] float32 max-min fair rates."""
    inc = jnp.asarray(inc, jnp.float32)
    cap = jnp.asarray(cap, jnp.float32)
    F, L = inc.shape
    if L == 0:
        return jnp.full((F,), NOLINK_RATE, jnp.float32)

    def round_(_, carry):
        rates, cap, active = carry
        users = jnp.sum(inc * active[:, None], axis=0)
        share = jnp.where(users > 0, cap / jnp.maximum(users, 1.0), BIG)
        s = jnp.min(share)
        sat = ((share <= s) & (users > 0)).astype(jnp.float32)
        hit = jnp.sum(inc * sat[None, :], axis=1) > 0
        newly = (active > 0) & hit & (s < BIG)
        r = jnp.maximum(s, 0.0)
        rates = jnp.where(newly, r, rates)
        newly_f = newly.astype(jnp.float32)
        cap = cap - r * jnp.sum(inc * newly_f[:, None], axis=0)
        return rates, cap, active * (1.0 - newly_f)

    rates, _, active = jax.lax.fori_loop(
        0, max(L, 1), round_,
        (jnp.zeros(F, jnp.float32), cap, jnp.ones(F, jnp.float32)))
    return jnp.where(active > 0, jnp.float32(NOLINK_RATE), rates)
