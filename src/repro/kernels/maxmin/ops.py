"""Shape-stable array API for the max-min water-filling solver.

Three interchangeable implementations sit behind one CSR flow-path layout
(``path_links`` + ``path_off``, the struct-of-arrays form every fast lane
shares — see ``repro.net.soa``):

* :func:`maxmin_rates_arrays` — the **default**: an exact array
  re-implementation of the historical dict/set progressive water-filling
  loop (kept as ``repro.net.flows.maxmin_rates_dict`` for parity tests).
  Bit-for-bit equal outputs, which is what keeps ``fidelity="packet"``
  hybrid runs and every CI counter identical across the refactor: link
  capacities are seeded in first-appearance order, the most-contended link
  is chosen by ``argmin`` (first occurrence == the dict loop's strict ``<``
  over insertion order), and every per-round capacity decrement subtracts
  the *identical* fair-share scalar, so accumulation order cannot change a
  single bit.
* :func:`maxmin_rates_jax` with ``impl="ref"`` — the pure-JAX fixed-point
  oracle (``repro.kernels.maxmin.ref``), dense flow×link incidence.
* the Pallas kernel (``repro.kernels.maxmin.kernel``), same fixed-point
  algorithm in VMEM — selected with ``impl="kernel"``.

jax is imported lazily: the packet path (including the sharded loop's
spawn workers) stays jax-free unless a jax implementation is requested.
"""
from __future__ import annotations

from collections.abc import Mapping, Sequence

import numpy as np

# deterministic instrumentation for the CI counter gate: every solver
# invocation (any impl) bumps these; benchmarks/ci_regression.py snapshots
# them around its scenario pass
SOLVER_COUNTERS = {"invocations": 0, "max_flows": 0}


def reset_counters() -> dict:
    """Zero the module counters and return the values they held."""
    held = dict(SOLVER_COUNTERS)
    SOLVER_COUNTERS["invocations"] = 0
    SOLVER_COUNTERS["max_flows"] = 0
    return held


def paths_to_arrays(paths: Mapping[int, Sequence[int]]):
    """CSR layout of a ``{fid: [port ids]}`` mapping, preserving the
    mapping's iteration order (the order seeds link first-appearance order,
    which the exact solver's tie-breaks depend on)."""
    fids = list(paths)
    off = np.zeros(len(fids) + 1, dtype=np.int64)
    chunks = []
    for i, fid in enumerate(fids):
        p = paths[fid]
        off[i + 1] = off[i] + len(p)
        if len(p):
            chunks.append(np.asarray(p, dtype=np.int64))
    links = (np.concatenate(chunks) if chunks
             else np.zeros(0, dtype=np.int64))
    return fids, links, off


def _capacities(link_bw, links: np.ndarray) -> np.ndarray:
    """Gather ``link_bw[l]`` for dense link ids — ``link_bw`` is anything
    indexable by port id (ndarray, list, or dict)."""
    if isinstance(link_bw, np.ndarray):
        return link_bw[links].astype(np.float64)
    return np.array([float(link_bw[int(l)]) for l in links], dtype=np.float64)


def _gather_csr(off: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Concatenated entry indices of CSR ``rows`` (vectorized range-concat)."""
    starts = off[rows]
    lens = (off[rows + 1] - starts).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    out = np.repeat(starts - (np.cumsum(lens) - lens), lens)
    return out + np.arange(total, dtype=np.int64)


def maxmin_rates_arrays(path_links: np.ndarray, path_off: np.ndarray,
                        link_bw) -> np.ndarray:
    """Exact progressive water-filling over CSR paths: float64 rates
    (bytes/s) per flow, bit-identical to the historical dict solver.

    ``path_links``: concatenated port ids; ``path_off``: per-flow offsets
    (len F+1); ``link_bw``: capacities indexable by port id.
    """
    F = len(path_off) - 1
    SOLVER_COUNTERS["invocations"] += 1
    if F > SOLVER_COUNTERS["max_flows"]:
        SOLVER_COUNTERS["max_flows"] = F
    rates = np.zeros(F, dtype=np.float64)
    if F == 0:
        return rates
    E = int(path_off[-1])
    if E == 0:                      # no flow crosses a link
        rates[:] = 1e12
        return rates
    path_links = np.asarray(path_links, dtype=np.int64)
    path_off = np.asarray(path_off, dtype=np.int64)
    # dense link ids in first-appearance order (== dict insertion order)
    uniq, first, inv = np.unique(path_links, return_index=True,
                                 return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty(len(uniq), dtype=np.int64)
    rank[order] = np.arange(len(uniq), dtype=np.int64)
    dense = rank[inv]               # per path entry: dense link index
    L = len(uniq)
    cap = _capacities(link_bw, uniq[order])
    flow_of_entry = np.repeat(np.arange(F, dtype=np.int64),
                              np.diff(path_off))
    # link -> entries CSR (which flows cross each link)
    by_link = np.argsort(dense, kind="stable")
    link_off = np.searchsorted(dense[by_link], np.arange(L + 1))
    # per-flow *unique* links (the dict kept a set per link, so a repeated
    # link in one path counts one user — but its capacity is decremented
    # once per occurrence, which the raw-entry subtraction below preserves)
    pair = flow_of_entry * L + dense
    upair = np.unique(pair)
    u_link = (upair % L).astype(np.int64)
    u_flow = (upair // L).astype(np.int64)
    u_off = np.searchsorted(u_flow, np.arange(F + 1))
    users = np.bincount(u_link, minlength=L).astype(np.int64)

    unfrozen = np.ones(F, dtype=bool)
    n_left = F
    while n_left:
        with np.errstate(divide="ignore", invalid="ignore"):
            share = np.where(users > 0, cap / users, np.inf)
        best = int(np.argmin(share))
        if users[best] <= 0:        # only link-less flows remain
            rates[unfrozen] = 1e12
            break
        s = share[best]
        if s < 0.0:
            s = 0.0
        sel = flow_of_entry[by_link[link_off[best]:link_off[best + 1]]]
        sel = np.unique(sel)
        sel = sel[unfrozen[sel]]
        rates[sel] = s
        unfrozen[sel] = False
        n_left -= len(sel)
        # every decrement this round subtracts the identical scalar ``s``
        # (or integer 1), so the order of repeated updates cannot change
        # the result — np.subtract.at is bit-equal to the dict loop
        np.subtract.at(cap, dense[_gather_csr(path_off, sel)], s)
        np.subtract.at(users, u_link[_gather_csr(u_off, sel)], 1)
    return rates


def solve_paths(paths: Mapping[int, Sequence[int]], link_bw) -> dict[int, float]:
    """Dict-in/dict-out convenience over :func:`maxmin_rates_arrays` —
    the drop-in body of ``repro.net.flows.maxmin_rates``."""
    fids, links, off = paths_to_arrays(paths)
    rates = maxmin_rates_arrays(links, off, link_bw)
    return dict(zip(fids, rates.tolist()))


# ---------------------------------------------------------------------- #
# jax implementations (dense incidence; lazy import)
# ---------------------------------------------------------------------- #
def incidence_from_csr(path_links: np.ndarray, path_off: np.ndarray,
                       link_bw) -> tuple[np.ndarray, np.ndarray]:
    """Dense ``(incidence [F, L], cap [L])`` float32 arrays over the links
    that actually appear, in first-appearance order — the fixed-shape input
    of the jax/Pallas implementations."""
    F = len(path_off) - 1
    path_links = np.asarray(path_links, dtype=np.int64)
    if len(path_links) == 0:
        return np.zeros((F, 0), np.float32), np.zeros(0, np.float32)
    uniq, first, inv = np.unique(path_links, return_index=True,
                                 return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty(len(uniq), dtype=np.int64)
    rank[order] = np.arange(len(uniq), dtype=np.int64)
    dense = rank[inv]
    L = len(uniq)
    inc = np.zeros((F, L), dtype=np.float32)
    flow_of_entry = np.repeat(np.arange(F, dtype=np.int64),
                              np.diff(np.asarray(path_off, dtype=np.int64)))
    inc[flow_of_entry, dense] = 1.0
    cap = _capacities(link_bw, uniq[order]).astype(np.float32)
    return inc, cap


def maxmin_rates_jax(path_links, path_off, link_bw, *, impl: str = "ref",
                     interpret: bool | None = None) -> np.ndarray:
    """Fixed-point max-min via the jax ref (``impl="ref"``) or the Pallas
    kernel (``impl="kernel"``).  float32 — approximate parity with the
    exact solver (≲1e-4 rel), exact parity kernel↔ref."""
    SOLVER_COUNTERS["invocations"] += 1
    F = len(path_off) - 1
    if F > SOLVER_COUNTERS["max_flows"]:
        SOLVER_COUNTERS["max_flows"] = F
    inc, cap = incidence_from_csr(path_links, path_off, link_bw)
    if impl == "ref":
        from repro.kernels.maxmin.ref import maxmin_ref
        return np.asarray(maxmin_ref(inc, cap))
    if impl == "kernel":
        from repro.kernels.maxmin.kernel import maxmin_kernel
        return np.asarray(maxmin_kernel(inc, cap, interpret=interpret))
    raise ValueError(f"unknown impl {impl!r} (use 'ref' or 'kernel')")
