"""Fixed-point max-min water-filling as a Pallas kernel.

One invocation holds the whole ``incidence [F, L]`` tile plus the
capacity row in VMEM and runs the saturate-and-freeze rounds of
``ref.maxmin_ref`` in-register: every round is two row/column reductions
over the same resident tile, so looping on-chip beats ``L`` separate
host-side reductions exactly the way ``steady_scan`` fused its three.
At the bench ceiling (10k flows × 128 links, float32) the tile is
~5 MiB — inside a TPU core's VMEM; CPU runs use interpret mode.

Static round count: each effective round saturates (and thereafter
silences) at least one link, so ``L`` rounds reach the fixed point and
the remaining iterations are identity (``newly`` empties once the min
share hits the BIG sentinel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.maxmin.ref import BIG, NOLINK_RATE

BF = 8     # flow-axis pad multiple (float32 sublane tile)
BL = 128   # link-axis pad multiple (lane tile)


def _maxmin_kernel(inc_ref, cap_ref, rates_ref, *, rounds: int):
    inc = inc_ref[...]            # [F, L]
    cap0 = cap_ref[...]           # [1, L]
    F = inc.shape[0]

    def round_(_, carry):
        rates, cap, active = carry            # [F,1], [1,L], [F,1]
        users = jnp.sum(inc * active, axis=0, keepdims=True)
        share = jnp.where(users > 0, cap / jnp.maximum(users, 1.0), BIG)
        s = jnp.min(share)
        sat = ((share <= s) & (users > 0)).astype(jnp.float32)
        hit = jnp.sum(inc * sat, axis=1, keepdims=True) > 0
        newly = (active > 0) & hit & (s < BIG)
        r = jnp.maximum(s, 0.0)
        rates = jnp.where(newly, r, rates)
        newly_f = newly.astype(jnp.float32)
        cap = cap - r * jnp.sum(inc * newly_f, axis=0, keepdims=True)
        return rates, cap, active * (1.0 - newly_f)

    rates, _, active = jax.lax.fori_loop(
        0, rounds, round_,
        (jnp.zeros((F, 1), jnp.float32), cap0,
         jnp.ones((F, 1), jnp.float32)))
    rates_ref[...] = jnp.where(active > 0, jnp.float32(NOLINK_RATE), rates)


@functools.partial(jax.jit, static_argnames=("rounds", "interpret"))
def _maxmin_padded(inc, cap, *, rounds: int, interpret: bool):
    F, L = inc.shape
    # whole-array dispatch: no grid — the single tile lives in VMEM
    out = pl.pallas_call(
        functools.partial(_maxmin_kernel, rounds=rounds),
        out_shape=jax.ShapeDtypeStruct((F, 1), jnp.float32),
        interpret=interpret,
    )(inc, cap)
    return out[:, 0]


def maxmin_kernel(inc, cap, interpret: bool | None = None):
    """inc: [F, L] float 0/1 incidence; cap: [L] capacities.  Returns [F]
    float32 max-min rates, parity with ``ref.maxmin_ref``.  Padding is
    inert: padded links get cap 0 with no users (share = BIG sentinel,
    never the min while real work remains) and padded flows cross no link
    (they end active → NOLINK_RATE, sliced off)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    inc = jnp.asarray(inc, jnp.float32)
    cap = jnp.asarray(cap, jnp.float32)
    F, L = inc.shape
    if L == 0:
        return jnp.full((F,), jnp.float32(NOLINK_RATE))
    Fp = -(-max(F, 1) // BF) * BF
    Lp = -(-L // BL) * BL
    incp = jnp.pad(inc, ((0, Fp - F), (0, Lp - L)))
    capp = jnp.pad(cap, (0, Lp - L))[None, :]
    out = _maxmin_padded(incp, capp, rounds=max(L, 1), interpret=interpret)
    return out[:F]
