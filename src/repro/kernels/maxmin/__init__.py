"""Max-min water-filling solver package (see ops.py for the layout).

Only the numpy-facing API is imported eagerly — the jax ref/kernel load
lazily so the packet path (and its spawn workers) never pays a jax import.
"""
from repro.kernels.maxmin.ops import (
    SOLVER_COUNTERS,
    maxmin_rates_arrays,
    maxmin_rates_jax,
    paths_to_arrays,
    reset_counters,
    solve_paths,
)

__all__ = [
    "SOLVER_COUNTERS",
    "maxmin_rates_arrays",
    "maxmin_rates_jax",
    "paths_to_arrays",
    "reset_counters",
    "solve_paths",
]
