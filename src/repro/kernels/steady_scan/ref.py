"""Pure-jnp oracle for windowed fluctuation detection (paper Eq. 6/7)."""
from __future__ import annotations

import jax.numpy as jnp


def steady_scan_ref(hist, window: int, atol: float = 0.0):
    """hist: [F, H] rate history (most recent last).  Returns (fluct, mean)
    over the trailing ``window`` samples per flow.  ``atol``: dead-band —
    rows whose window max is <= atol are steady by definition (matches the
    scalar detector on zero-pinned metrics such as an empty queue)."""
    w = hist[:, hist.shape[1] - window:]
    mx = w.max(axis=1)
    mn = w.min(axis=1)
    mean = w.mean(axis=1)
    fluct = jnp.where(mean > 0, (mx - mn) / jnp.maximum(mean, 1e-30), jnp.inf)
    return jnp.where(mx <= atol, 0.0, fluct), mean
