"""jit'd wrapper: pad flows to the tile multiple, dispatch, unpad."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.steady_scan.kernel import BF, steady_scan_padded


@partial(jax.jit, static_argnames=("window", "interpret"))
def steady_scan(hist, window: int, interpret: bool | None = None):
    """hist: [F, H] float rate history.  Returns (fluct [F], mean [F]) over
    the trailing ``window`` samples (paper Eq. 6 / Eq. 7)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hist = jnp.asarray(hist, jnp.float32)
    F, H = hist.shape
    assert 0 < window <= H
    Fp = -(-F // BF) * BF
    histp = jnp.pad(hist, ((0, Fp - F), (0, 0)), constant_values=1.0)
    fluct, mean = steady_scan_padded(histp, window=window, interpret=interpret)
    return fluct[:F], mean[:F]
