"""jit'd wrapper: pad flows to the tile multiple, dispatch, unpad."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.steady_scan.kernel import BF, steady_scan_padded


@partial(jax.jit, static_argnames=("window", "atol", "interpret"))
def steady_scan(hist, window: int, atol: float = 0.0,
                interpret: bool | None = None):
    """hist: [F, H] float rate history.  Returns (fluct [F], mean [F]) over
    the trailing ``window`` samples (paper Eq. 6 / Eq. 7).  ``atol`` is the
    zero-pinned-metric dead-band of the scalar/batch detectors (Eq. 6 with
    the qlen special case)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hist = jnp.asarray(hist, jnp.float32)
    F, H = hist.shape
    assert 0 < window <= H
    Fp = -(-F // BF) * BF
    pad_val = max(1.0, 2.0 * atol)   # padded rows must stay out of the band
    histp = jnp.pad(hist, ((0, Fp - F), (0, 0)), constant_values=pad_val)
    fluct, mean = steady_scan_padded(histp, window=window, atol=atol,
                                     interpret=interpret)
    return fluct[:F], mean[:F]
