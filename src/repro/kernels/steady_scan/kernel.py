"""Windowed steady-state detection (Eq. 6/7) as a Pallas TPU kernel.

The monitor buffer is a dense (flows × history) array; each grid step loads
one (BF × H) tile into VMEM and computes trailing-window max/min/mean with
VPU row reductions.  For the production monitor (F up to 10^5 flows,
H = 128 samples) a tile is 128·128·4B = 64 KiB — bandwidth-bound, so one
pass over the buffer is optimal; fusing max/min/mean into a single read is
the entire point of the kernel (three separate jnp reductions would read
the buffer three times).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BF = 128


def _steady_kernel(hist_ref, fluct_ref, mean_ref, *, window: int, atol: float):
    h = hist_ref[...]
    H = h.shape[1]
    w = h[:, H - window:]
    mx = jnp.max(w, axis=1)
    mn = jnp.min(w, axis=1)
    mean = jnp.sum(w, axis=1) / window
    fluct = jnp.where(mean > 0, (mx - mn) / jnp.maximum(mean, 1e-30),
                      jnp.float32(jnp.inf))
    # dead-band (scalar detector parity): a metric pinned at <= atol is
    # steady by definition even though its relative fluctuation is 0/0
    fluct_ref[...] = jnp.where(mx <= atol, jnp.float32(0.0), fluct)
    mean_ref[...] = mean


@functools.partial(jax.jit, static_argnames=("window", "atol", "interpret"))
def steady_scan_padded(hist, *, window: int, atol: float = 0.0,
                       interpret: bool = True):
    F, H = hist.shape
    assert F % BF == 0
    grid = (F // BF,)
    out = pl.pallas_call(
        functools.partial(_steady_kernel, window=window, atol=atol),
        grid=grid,
        in_specs=[pl.BlockSpec((BF, H), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((BF,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((F,), jnp.float32)] * 2,
        interpret=interpret,
    )(hist)
    return tuple(out)
