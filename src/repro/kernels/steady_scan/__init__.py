from repro.kernels.steady_scan.ops import steady_scan
