"""Pure-jnp oracle: softmax attention with causal / sliding-window masks and
grouped KV heads."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """q: [B, Hq, S, D]; k/v: [B, Hk, S, D] with Hq % Hk == 0."""
    B, Hq, S, D = q.shape
    Hk = k.shape[1]
    assert Hq % Hk == 0
    g = Hq // Hk
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
