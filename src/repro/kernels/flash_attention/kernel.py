"""Blockwise online-softmax (flash) attention as a Pallas TPU kernel.

Grid: (batch·q_heads, S/BQ, S/BK) with the KV dimension innermost — the TPU
grid is sequential, so the (m, l, acc) running-softmax state lives in VMEM
scratch across KV steps and is finalised on the last one.  GQA is an
index_map: the KV block for flattened q-head ``bh`` comes from kv head
``(bh % Hq) // (Hq // Hk)``.  Causal/sliding-window masking is computed from
block offsets; fully-masked KV blocks still iterate (the grid is static) but
their contribution is exp(-inf)=0 — the skip optimisation is recorded as a
perf-iteration idea in EXPERIMENTS.md §Perf.

VMEM per step (f32): q/k/v/acc tiles 4·BQ·D ≈ 4·128·128·4B = 256 KiB for
D=128 — MXU-aligned (BQ, BK, D all multiples of 128 when D permits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int | None,
                  n_kv: int, s_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [BQ, D]
    k = k_ref[0]                       # [BK, D]
    v = v_ref[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [BQ, BK]

    qpos = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    kpos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = kpos < s_valid            # padded KV columns contribute nothing
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, logits.max(axis=1))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(logits - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "interpret", "s_valid"))
def flash_attention_padded(q, k, v, *, causal: bool, window: int | None,
                           scale: float, s_valid: int, interpret: bool = True):
    """q: [BH, S, D] flattened (batch·q_heads); k/v: [BHk, S, D] flattened
    (batch·kv_heads); requires S % BQ == 0 == S % BK and knowledge of the
    head grouping encoded by the caller in the index mapping."""
    BHq, S, D = q.shape
    BHk = k.shape[0]
    group = BHq // BHk
    n_kv = S // BK
    grid = (BHq, S // BQ, n_kv)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, n_kv=n_kv, s_valid=s_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, BK, D), lambda b, iq, ik: (b // group, ik, 0)),
            pl.BlockSpec((1, BK, D), lambda b, iq, ik: (b // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, S, D), q.dtype),
        scratch_shapes=[
            pltpu_scratch((BQ,), jnp.float32),
            pltpu_scratch((BQ,), jnp.float32),
            pltpu_scratch((BQ, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def pltpu_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
