"""jit'd wrapper: head flattening for GQA, sequence padding, dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import BK, BQ, flash_attention_padded


@partial(jax.jit, static_argnames=("causal", "window", "scale", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, interpret: bool | None = None):
    """q: [B, Hq, S, D]; k/v: [B, Hk, S, D] (Hq % Hk == 0).  Causal and/or
    sliding-window masked online-softmax attention."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, S, D = q.shape
    Hk = k.shape[1]
    assert Hq % Hk == 0
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    blk = max(BQ, BK)
    Sp = -(-S // blk) * blk
    pad = Sp - S

    def prep(x):
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x.reshape(B * x.shape[1], Sp, D)

    # flatten with head-major so the kernel's b // group mapping lines up:
    # q heads of one batch are contiguous, kv heads likewise
    qf = prep(q)
    kf = prep(k)
    vf = prep(v)
    out = flash_attention_padded(qf, kf, vf, causal=causal, window=window,
                                 scale=scale, s_valid=S, interpret=interpret)
    out = out.reshape(B, Hq, Sp, D)[:, :, :S]
    return out
