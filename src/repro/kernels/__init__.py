"""Pallas TPU kernels for the perf-critical compute layers:

  cca_step        — fused congestion-control fluid step + incidence-matmul
                    queue aggregation (the packet loop's hot path, batched)
  steady_scan     — windowed rate-fluctuation detection (§5.1.2) over the
                    (flows × history) monitor buffer
  flash_attention — blockwise online-softmax attention (causal / sliding
                    window / GQA) for the architecture zoo

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with padding/dispatch) and ref.py (pure-jnp oracle used by tests).
Kernels are validated in interpret mode on CPU; BlockSpecs are sized for
TPU VMEM (see per-kernel notes)."""
