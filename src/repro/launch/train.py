"""Training driver: ``python -m repro.launch.train --arch granite-3-2b
--steps 200`` trains a (reduced or full) config with the full substrate:
AdamW, microbatching, checkpoints, failure recovery, optional gradient
compression."""
from __future__ import annotations

import argparse

from repro.configs.registry import get
from repro.data.pipeline import TokenPipeline
from repro.models.api import build_model
from repro.parallel.compression import CompressionConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced config (CPU-sized); --no-reduced "
                         "for the full config on a real cluster")
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params/1e6:.1f}M params")
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    tcfg = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        opt=AdamWConfig(lr=args.lr, warmup=max(10, args.steps // 20),
                        total_steps=args.steps),
        compression=CompressionConfig(kind=args.compression),
        ckpt_dir=args.ckpt_dir, log_every=10,
    )
    out = train(model, pipe, tcfg)
    print(f"final loss {out['losses'][-1]:.4f} "
          f"(start {out['losses'][0]:.4f}); stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
