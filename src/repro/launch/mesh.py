"""Production meshes.  A FUNCTION, not a module-level constant, so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
