"""Roofline terms from the compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips × 819 GB/s HBM)
    collective term = collective_bytes / (chips × 50 GB/s ICI per link)

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (with an analytic
6·N·D fallback/cross-check).  collective_bytes is parsed from the
post-SPMD HLO text: the summed result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (result
bytes ≈ bytes moved per chip for AG/AR; RS moves the larger operand — we
scale RS by its shard count, conservatively).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(typed: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(typed):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind result bytes summed over the module (per-device,
    since post-SPMD shapes are per-shard)."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        typed = m.group(1) or m.group(2)
        kind = m.group(3)
        # "-start" ops are paired with "-done"; count the start only
        span_txt = hlo_text[m.start():m.start() + 40]
        if "-done(" in span_txt:
            continue
        out[kind] = out.get(kind, 0.0) + _shape_bytes(typed)
    return out


def roofline(flops: float, hbm_bytes: float, coll_bytes: float,
             chips: int) -> dict:
    """All inputs are whole-job totals except coll_bytes (per-chip)."""
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (chips * HBM_BW)
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update({
        "dominant": dom.replace("_s", ""),
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
    })
    return terms


def model_flops(n_params: float, n_active: float, tokens: float,
                kind: str) -> float:
    """6·N·D (train) / 2·N·D (prefill/decode) with active params for MoE."""
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens
