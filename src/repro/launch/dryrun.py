import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract memory/cost/collective analysis
for the roofline table (EXPERIMENTS.md §Dry-run/§Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The 512 placeholder host devices exist ONLY here (the env var above runs
before any jax import, per the assignment); smoke tests and benchmarks see
the real single CPU device.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import cells, get
from repro.launch import analytic as AN
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.parallel import act_sharding
from repro.parallel.sharding import (batch_shardings, rules_for,
                                     tree_shardings)
from repro.train import optimizer as O

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _opt_dtype(cfg):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.opt_dtype]


def build_step(model, cfg, kind: str):
    """The jittable step function + (arg structs, in/out shardings builder)."""
    ocfg = O.AdamWConfig(state_dtype=_opt_dtype(cfg))

    if kind == "train":
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
            params2, opt2, metrics = O.update(params, grads, opt_state, ocfg)
            return params2, opt2, loss, metrics["grad_norm"]
        return train_step

    if kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        return prefill_step

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return serve_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               extra_rules: dict | None = None, cfg_override=None):
    cfg = cfg_override if cfg_override is not None else get(arch)
    cell = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = rules_for(cfg, mesh, cell.kind, cell.seq_len, cell.global_batch,
                      n_params=model.n_params)
    if extra_rules:
        rules.update(extra_rules)

    param_structs = model.param_structs()
    param_sh = tree_shardings(model.param_axes(), param_structs, rules, mesh)
    inputs = model.input_specs(cell)

    if cell.kind in ("train", "prefill"):
        from repro.parallel.sharding import resolve
        spec = resolve(("act_batch", "act_seq", None),
                       (cell.global_batch, cell.seq_len, cfg.d_model),
                       {**rules, "act_seq": "model"}, mesh)
        act_sharding.install(jax.NamedSharding(mesh, spec))
    else:
        act_sharding.clear()

    if cell.kind == "train":
        ocfg = O.AdamWConfig(state_dtype=_opt_dtype(cfg))
        opt_structs = {
            "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, ocfg.state_dtype),
                              param_structs),
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, ocfg.state_dtype),
                              param_structs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_sh = {
            "m": tree_shardings(model.param_axes(), opt_structs["m"], rules, mesh),
            "v": tree_shardings(model.param_axes(), opt_structs["v"], rules, mesh),
            "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        batch_sh = batch_shardings(inputs, rules, mesh)
        fn = jax.jit(build_step(model, cfg, "train"),
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None, None),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(param_structs, opt_structs, inputs)
    elif cell.kind == "prefill":
        batch_sh = batch_shardings(inputs, rules, mesh)
        fn = jax.jit(build_step(model, cfg, "prefill"),
                     in_shardings=(param_sh, batch_sh))
        with mesh:
            lowered = fn.lower(param_structs, inputs)
    else:
        cache_structs = inputs["cache"]
        cache_sh = tree_shardings(model.cache_axes(), cache_structs, rules, mesh)
        tok_sh = batch_shardings(inputs["tokens"], rules, mesh)
        pos_sh = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
        fn = jax.jit(build_step(model, cfg, "decode"),
                     in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(param_structs, cache_structs, inputs["tokens"],
                               inputs["pos"])
    return cfg, model, mesh, cell, lowered, chips


def analyse(cfg, model, mesh, cell, lowered, chips) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # structural evidence of the collective schedule GSPMD chose (note: HLO
    # cost/byte counts do NOT multiply through scan trip counts, so the
    # magnitudes come from the analytic model below — see analytic.py)
    coll_parsed = RL.collective_bytes(hlo)

    mesh_shape = dict(mesh.shape)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    n_active = _active_params(cfg, model)
    mflops = RL.model_flops(model.n_params, n_active, tokens, cell.kind)
    fl = AN.cell_flops(cfg, cell)
    memm = AN.cell_memory(cfg, cell, model.n_params, chips, dp)
    coll = AN.cell_collectives(cfg, cell, model.n_params, mesh_shape)
    terms = RL.roofline(fl["total"], memm.traffic_bytes, coll["total"], chips)
    naive_mem_s = (memm.traffic_bytes + memm.naive_attn_extra) / (chips * RL.HBM_BW)
    out = {
        "arch": cfg.name, "shape": cell.name, "mesh": tuple(mesh.shape.values()),
        "chips": chips, "compile_s": round(compile_s, 1),
        "params_b": model.n_params / 1e9,
        "argument_gb_per_device": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "xla_temp_gb_per_device": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "est_peak_gb_per_device": memm.peak_bytes_per_device / 1e9,
        "fits_16gb_hbm": bool(memm.peak_bytes_per_device < 16e9),
        "hlo_flops": fl["total"], "model_flops": mflops,
        "useful_flops_ratio": mflops / fl["total"] if fl["total"] else 0.0,
        "hbm_bytes": memm.traffic_bytes,
        "naive_attn_memory_s": naive_mem_s,
        "collective_bytes_per_chip": coll["total"],
        "collectives_analytic": coll,
        "collectives_hlo_evidence": coll_parsed,
        **terms,
    }
    return out


def _active_params(cfg, model) -> float:
    n = model.n_params
    if not cfg.moe_experts:
        return n
    # subtract inactive expert weights
    f = cfg.moe_d_ff or cfg.d_ff
    n_moe_layers = 0
    for st in cfg.stages():
        for b in st.blocks:
            if b.ffn == "moe":
                n_moe_layers += st.repeat
    per_expert = 3 * cfg.d_model * f
    total_expert = n_moe_layers * cfg.moe_experts * per_expert
    active_expert = n_moe_layers * max(cfg.moe_top_k, 1) * per_expert
    return n - total_expert + active_expert


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: pathlib.Path):
    multi = mesh_kind == "multi"
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    out_path = outdir / f"{tag}.json"
    if out_path.exists():
        print(f"[skip cached] {tag}")
        return json.loads(out_path.read_text())
    print(f"[lower] {tag}")
    t0 = time.time()
    try:
        parts = lower_cell(arch, shape_name, multi)
        rec = analyse(*parts)
        rec["status"] = "ok"
    except Exception as e:  # record failures as bugs to fix
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    rec["wall_s"] = round(time.time() - t0, 1)
    outdir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    extra = "" if status != "ok" else (
        f" dom={rec['dominant']} frac={rec['roofline_fraction']:.2f}"
        f" peak={rec['est_peak_gb_per_device']:.1f}GB")
    print(f"[{status}] {tag} ({rec['wall_s']}s){extra}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = [(a, s) for (a, s, skip) in cells() if skip is None]
        if args.arch:
            todo = [t for t in todo if t[0] == args.arch]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    for mesh_kind in meshes:
        for arch, shape in todo:
            run_cell(arch, shape, mesh_kind, outdir)


if __name__ == "__main__":
    main()
