"""Serving driver: batched prefill + decode with the KV/recurrent caches.

    python -m repro.launch.serve --arch xlstm-125m --batch 4 --prompt-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get
from repro.models.api import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get(args.arch).reduced() if args.reduced else get(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    S = P + args.new_tokens + 8
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)

    cache = model.init_cache(B, S)
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    # prefill via decode steps (exact; batched serving path)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    for i in range(P):
        logits, cache = decode(params, cache, prompt[:, i:i + 1], i)
    out_tokens = []
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(args.new_tokens):
        logits, cache = decode(params, cache, tok, P + i)
        tok = jnp.argmax(logits, -1)[:, None]
        out_tokens.append(tok)
    dt = time.perf_counter() - t0
    total = B * (P + args.new_tokens)
    print(f"{cfg.name}: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU, batch={B})")
    print("sampled:", jnp.concatenate(out_tokens, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
