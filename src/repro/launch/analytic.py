"""Analytic FLOPs / HBM-traffic / collective-bytes model per (arch × shape ×
mesh) cell.

Why analytic: XLA's cost analysis does not multiply through `while`
(scan-over-layers) trip counts, so both lowered and compiled FLOP counts
under-report by ~L× on CPU.  The einsum-level accounting below is exact for
our model definitions; the compiled-HLO collective parse (roofline.py)
remains as structural evidence of the schedule GSPMD chose.

Memory traffic is reported for the TPU-target implementation: attention
logits stay in VMEM (the flash_attention kernel exists and is validated),
so no S² HBM term; the einsum fallback's S² traffic is reported separately
as the un-optimised baseline (§Perf).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ATTN, ATTN_GLOBAL, ATTN_LOCAL, MAMBA, MLP,
                                MLSTM, MOE, SLSTM, ArchConfig, ShapeCell)

BF16 = 2


# --------------------------------------------------------------------- #
# FLOPs (forward, whole job)
# --------------------------------------------------------------------- #
def _attn_flops(cfg, B, S, T, causal):
    H, Hk, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    d = cfg.d_model
    proj = 2 * B * S * d * (H + 2 * Hk) * hd + 2 * B * S * H * hd * d
    t_eff = T / 2 if (causal and S == T) else T
    qk = 2 * B * S * t_eff * H * hd * 2          # scores + values
    return proj + qk


def _mla_flops(cfg, B, S, T, causal):
    d, H = cfg.d_model, cfg.n_heads
    qh = cfg.nope_dim + cfg.rope_dim
    proj = (2 * B * S * d * cfg.q_lora + 2 * B * S * cfg.q_lora * H * qh
            + 2 * B * S * d * (cfg.kv_lora + cfg.rope_dim)
            + 2 * B * T * cfg.kv_lora * H * (cfg.nope_dim + cfg.v_head_dim)
            + 2 * B * S * H * cfg.v_head_dim * d)
    t_eff = T / 2 if (causal and S == T) else T
    qk = 2 * B * S * t_eff * H * (qh + cfg.v_head_dim)
    return proj + qk


def _mlp_flops(cfg, B, S, f=None):
    f = f or cfg.d_ff
    mats = 3 if cfg.mlp_kind == "swiglu" else 2
    return mats * 2 * B * S * cfg.d_model * f


def _moe_flops(cfg, B, S):
    d = cfg.d_model
    E, k = cfg.moe_experts, cfg.moe_top_k
    f = cfg.moe_d_ff or cfg.d_ff
    T = B * S
    cap = int(cfg.capacity_factor * T * k / E)
    router = 2 * T * d * E
    experts = 3 * 2 * E * cap * d * f
    if cfg.moe_dispatch == "einsum":
        dispatch = 2 * 2 * T * E * cap * d        # dense one-hot dispatch
    else:
        dispatch = 0.0                            # gather/scatter: data movement
    shared = _mlp_flops(cfg, B, S, f=f * cfg.moe_shared) if cfg.moe_shared else 0
    return router + experts + dispatch + shared


def _mamba_flops(cfg, B, S):
    d = cfg.d_model
    di = cfg.expand * d
    ds = cfg.d_state
    dtr = max(1, d // 16)
    return (2 * B * S * d * 2 * di + 2 * B * S * di * cfg.conv_kernel
            + 2 * B * S * di * (dtr + 2 * ds) + 2 * B * S * dtr * di
            + 8 * B * S * di * ds                 # selective scan elementwise
            + 2 * B * S * di * d)


def _mlstm_flops(cfg, B, S, T):
    d = cfg.d_model
    di = cfg.expand * d
    if S == 1:                                     # recurrent decode step
        H = cfg.n_heads
        hd = di // H
        return (2 * B * d * 2 * di + 3 * 2 * B * di * di
                + 6 * B * H * hd * hd + 2 * B * di * d)
    quad = 2 * B * S * (T / 2) * di * 2
    return (2 * B * S * d * 2 * di + 3 * 2 * B * S * di * di + quad
            + 2 * B * S * di * d)


def _slstm_flops(cfg, B, S):
    d = cfg.d_model
    di = cfg.expand * d
    H = cfg.n_heads
    hd = di // H
    return (2 * B * S * d * 4 * di + 2 * B * S * H * hd * 4 * hd
            + 2 * B * S * di * d)


def forward_flops(cfg: ArchConfig, B: int, S: int, T: int | None = None) -> float:
    """One forward pass over S new tokens against context T (= S if None)."""
    T = T or S
    total = 0.0
    for st in cfg.stages():
        for blk in st.blocks:
            if cfg.mla and blk.mixer == ATTN:
                m = _mla_flops(cfg, B, S, T, True)
            elif blk.mixer in (ATTN, ATTN_GLOBAL):
                w = cfg.window if cfg.attn_kind == "swa" else 0
                m = _attn_flops(cfg, B, S, min(T, w) if w else T, True)
            elif blk.mixer == ATTN_LOCAL:
                m = _attn_flops(cfg, B, S, min(T, cfg.window), True)
            elif blk.mixer == MAMBA:
                m = _mamba_flops(cfg, B, S)
            elif blk.mixer == MLSTM:
                m = _mlstm_flops(cfg, B, S, T)
            elif blk.mixer == SLSTM:
                m = _slstm_flops(cfg, B, S)
            else:
                raise ValueError(blk.mixer)
            f = 0.0
            if blk.ffn == MLP:
                f = _mlp_flops(cfg, B, S)
            elif blk.ffn == MOE:
                f = _moe_flops(cfg, B, S)
            total += (m + f) * st.repeat
    if cfg.enc_dec:   # encoder stack + cross attention
        total += cfg.n_layers * (_attn_flops(cfg, B, T, T, False)
                                 + _mlp_flops(cfg, B, T))
        total += cfg.n_layers * _attn_flops(cfg, B, S, T, False)
    total += 2 * B * S * cfg.d_model * cfg.vocab   # unembed/loss
    return total


def cell_flops(cfg: ArchConfig, cell: ShapeCell, remat: bool | None = None) -> dict:
    B, S = cell.global_batch, cell.seq_len
    remat = cfg.remat if remat is None else remat
    if cell.kind == "train":
        fwd = forward_flops(cfg, B, S)
        factor = 4.0 if remat else 3.0             # fwd + 2×bwd (+1 recompute)
        return {"fwd": fwd, "total": fwd * factor}
    if cell.kind == "prefill":
        fwd = forward_flops(cfg, B, S)
        return {"fwd": fwd, "total": fwd}
    fwd = forward_flops(cfg, B, 1, T=S)
    return {"fwd": fwd, "total": fwd}


# --------------------------------------------------------------------- #
# HBM traffic + capacity (per device)
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class MemoryModel:
    traffic_bytes: float          # whole-job HBM bytes moved (all chips)
    peak_bytes_per_device: float  # capacity high-water estimate
    naive_attn_extra: float       # S² logits traffic if einsum attention


def cell_memory(cfg: ArchConfig, cell: ShapeCell, n_params: float,
                chips: int, dp: int) -> MemoryModel:
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    L = cfg.n_layers * (2 if cfg.enc_dec else 1)
    pbytes = n_params * BF16
    opt_bytes = 2 * n_params * (4 if cfg.opt_dtype == "float32" else 2)
    act_tensor = B * S * d * BF16
    if cell.kind == "train":
        # params read twice (fwd + recompute) + grads written/read + opt rw
        traffic = (3 * pbytes + 2 * pbytes + 2 * opt_bytes
                   + 10 * L * act_tensor)
        # checkpoints are sequence-parallel (constrained over dp×model)
        peak = (pbytes + pbytes + opt_bytes) / chips + L * act_tensor / chips \
            + 4 * act_tensor / dp
        naive = sum(st.repeat * B * (min(S, cfg.window) if
                    (blk.mixer == ATTN_LOCAL or cfg.attn_kind == "swa") and cfg.window
                    else S) * S * cfg.n_heads * 4
                    for st in cfg.stages() for blk in st.blocks
                    if blk.mixer in (ATTN, ATTN_GLOBAL, ATTN_LOCAL)) * 3
    elif cell.kind == "prefill":
        traffic = pbytes + 6 * L * act_tensor
        kv_bytes = _cache_bytes(cfg, B, S)
        peak = pbytes / chips + 2 * act_tensor / dp + kv_bytes / chips
        naive = L * B * S * S * cfg.n_heads * 4
    else:
        kv_bytes = _cache_bytes(cfg, B, S)
        traffic = pbytes + 2 * kv_bytes           # weights + cache read/write
        peak = (pbytes + kv_bytes) / chips + 2 * B * d * BF16
        naive = 0.0
    return MemoryModel(traffic_bytes=traffic, peak_bytes_per_device=peak,
                       naive_attn_extra=naive)


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    total = 0.0
    for st in cfg.stages():
        for blk in st.blocks:
            if cfg.mla and blk.mixer == ATTN:
                total += st.repeat * B * S * (cfg.kv_lora + cfg.rope_dim) * BF16
            elif blk.mixer in (ATTN, ATTN_GLOBAL, ATTN_LOCAL):
                w = cfg.window if (blk.mixer == ATTN_LOCAL
                                   or cfg.attn_kind == "swa") else 0
                T = min(S, w) if w else S
                total += st.repeat * 2 * B * T * cfg.n_kv * cfg.hd * BF16
            elif blk.mixer == MAMBA:
                di = cfg.expand * cfg.d_model
                total += st.repeat * B * di * (cfg.d_state * 4 + cfg.conv_kernel * BF16)
            elif blk.mixer in (MLSTM, SLSTM):
                di = cfg.expand * cfg.d_model
                H = cfg.n_heads
                hd = di // H
                total += st.repeat * B * H * (hd * hd + 2 * hd + 1) * 4
    if cfg.enc_dec:
        total += cfg.n_layers * 2 * B * min(S, 4096) * cfg.n_heads * cfg.hd * BF16
    return total


# --------------------------------------------------------------------- #
# Collective bytes per chip (ring algorithms; ICI links)
# --------------------------------------------------------------------- #
def cell_collectives(cfg: ArchConfig, cell: ShapeCell, n_params: float,
                     mesh_shape: dict) -> dict:
    """Per-chip bytes by source: ZeRO param gathers, grad reduce-scatter,
    TP activation all-reduces, MoE all-to-alls, vocab-sharded loss."""
    B, S = cell.global_batch, cell.seq_len
    model = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    d = cfg.d_model
    pbytes = n_params * BF16
    out = {"param_allgather": 0.0, "grad_reducescatter": 0.0,
           "tp_allreduce": 0.0, "moe_alltoall": 0.0, "loss_allreduce": 0.0}
    if cell.kind == "train":
        gathers = 3 if cfg.remat else 2           # fwd + bwd (+ recompute)
        out["param_allgather"] = gathers * pbytes * (dp - 1) / dp / model
        out["grad_reducescatter"] = pbytes * (dp - 1) / dp / model
        S_new, passes = S, 3                      # fwd+bwd activation ARs
    elif cell.kind == "prefill":
        out["param_allgather"] = pbytes * (dp - 1) / dp / model
        S_new, passes = S, 1
    else:
        tp_resident_gb = (n_params * 2 / model) / 1e9
        if tp_resident_gb > 8.0:
            # 2D weight-stationary serving: batch replicated, per-layer
            # activation reductions over both mesh axes; weights never move
            out["tp_allreduce"] = 0.0
            n_mix = sum(st.repeat * len(st.blocks) for st in cfg.stages())
            ar = (2 * (model - 1) / model + 2 * (dp - 1) / dp) \
                * B * 1 * d * BF16
            out["tp_allreduce"] = 2 * n_mix * ar
            if cfg.moe_experts:
                n_moe = sum(st.repeat for st in cfg.stages()
                            for blk in st.blocks if blk.ffn == MOE)
                out["moe_alltoall"] = 2 * n_moe * B * d * BF16 * max(cfg.moe_top_k, 1)
            out["total"] = sum(v for k2, v in out.items() if k2 != "total")
            return out
        S_new, passes = 1, 1
    b_local = max(1, B // dp)
    n_attn_layers = sum(st.repeat for st in cfg.stages() for blk in st.blocks
                        if blk.mixer in (ATTN, ATTN_GLOBAL, ATTN_LOCAL))
    n_mixer_layers = sum(st.repeat * len(st.blocks) for st in cfg.stages())
    # one AR after the mixer + one after the FFN per layer under TP
    ar = 2 * (model - 1) / model * b_local * S_new * d * BF16
    out["tp_allreduce"] = passes * 2 * n_mixer_layers * ar
    if cfg.moe_experts:
        n_moe = sum(st.repeat for st in cfg.stages() for blk in st.blocks
                    if blk.ffn == MOE)
        a2a_passes = passes
        if cell.kind == "train" and cfg.remat and cfg.remat_policy == "save_moe":
            a2a_passes = passes - 1      # no recompute all-to-alls
        tok_bytes = b_local * S_new * d * max(cfg.moe_top_k, 1)
        disp_b = 1 if cfg.moe_a2a_dtype else BF16   # fp8 dispatch wire
        out["moe_alltoall"] = a2a_passes * n_moe * tok_bytes * (disp_b + BF16)
    out["loss_allreduce"] = (b_local * S_new * 4 * 2) if cell.kind == "train" else 0.0
    out["total"] = sum(out.values())
    return out
