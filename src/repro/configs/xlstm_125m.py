"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].
12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  Block pattern: 1 sLSTM per 6
blocks (the xLSTM[7:1] ratio rounded to divide 12 layers; noted in
DESIGN.md).  Recurrent state => sub-quadratic: runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv=4, d_ff=0, vocab=50304, ssm_pattern=6,
    expand=2, subquadratic=True, remat=False, opt_dtype="float32",
    tie_embeddings=True,
)
