"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Period-8 blocks: attention at index 3, Mamba elsewhere; MoE on odd layers.
Recurrent Mamba state + 1:7-minority attention => runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=65536,
    hybrid_period=8, moe_experts=16, moe_top_k=2, moe_d_ff=14336,
    d_state=16, conv_kernel=4, expand=2, subquadratic=True,
)
