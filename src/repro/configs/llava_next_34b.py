"""llava-next-34b [vlm] — anyres tiling (stub frontend)
[hf:llava-hf/llava-v1.6].  60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.  input_specs() supplies precomputed patch embeddings (the
projector/vision tower is the assignment-mandated stub).  Full attention =>
long_500k skipped."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv=8, d_ff=20480, vocab=64000,
    frontend="vision_patches", n_patches=576,
)
