"""Architecture configuration system.

An ArchConfig fully determines (a) the JAX model (layers, mixers, FFN kinds,
decode caches), (b) the sharding rules used by the dry-run, and (c) the
TrafficModelSpec handed to the Wormhole workload generator.  Layer patterns
are expressed as repeated *stages*: each stage is a tuple of sub-blocks
scanned ``repeat`` times (keeping the lowered HLO small for 60+-layer
models).
"""
from __future__ import annotations

import dataclasses

from repro.workload.traffic import TrafficModelSpec

# mixer kinds
ATTN, ATTN_LOCAL, ATTN_GLOBAL, MAMBA, MLSTM, SLSTM = (
    "attn", "attn_local", "attn_global", "mamba", "mlstm", "slstm")
# ffn kinds
MLP, MOE, NONE = "mlp", "moe", "none"


@dataclasses.dataclass(frozen=True)
class SubBlock:
    mixer: str
    ffn: str


@dataclasses.dataclass(frozen=True)
class Stage:
    blocks: tuple[SubBlock, ...]
    repeat: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention pattern
    attn_kind: str = "full"      # full|swa|local_global
    window: int = 0
    local_global_period: int = 0  # every k-th layer is global (gemma3: 6)
    rope_theta: float = 1e4
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1
    moe_dense_first: int = 0     # first k layers use dense FFN (deepseek: 3)
    capacity_factor: float = 1.25
    # 'gather': sort+scatter dispatch (flops ∝ active experts; default).
    # 'einsum': GShard-style dense one-hot dispatch (kept as the §Perf
    # baseline — its [T,E,cap] tensors are catastrophic at DeepSeek scale).
    moe_dispatch: str = "gather"
    moe_a2a_dtype: str = ""      # "" | "float8_e4m3fn": quantised dispatch
                                 # (DeepSeek-V3-style fp8 all-to-all)
    # MLA (deepseek)
    mla: bool = False
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_head_dim: int = 128
    # hybrid / ssm
    hybrid_period: int = 0       # jamba: attn every 8th layer
    ssm_pattern: int = 0         # xlstm: sLSTM every k-th block
    d_state: int = 16
    conv_kernel: int = 4
    expand: int = 2
    # modality stubs
    frontend: str = ""           # "" | "vision_patches" | "audio_frames"
    n_patches: int = 576
    # enc-dec (whisper): n_layers applies to BOTH encoder and decoder
    enc_dec: bool = False
    mlp_kind: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"   # bf16 for >=100B params (HBM budget)
    remat: bool = True
    remat_policy: str = "full"   # full | save_moe (keep MoE outputs: no
                                 # recompute all-to-alls in the backward)
    loss_chunk: int = 512        # sequence chunking for the xent loss
    # sub-quadratic? (long_500k eligibility; see DESIGN.md skip table)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ------------------------------------------------------------------ #
    def stages(self) -> list[Stage]:
        """Layer pattern as scan-able stages."""
        L = self.n_layers
        if self.family in ("dense", "vlm"):
            if self.attn_kind == "local_global" and self.local_global_period:
                p = self.local_global_period
                blocks = tuple(SubBlock(ATTN_GLOBAL if (i == p - 1) else ATTN_LOCAL,
                                        MLP) for i in range(p))
                assert L % p == 0, (self.name, L, p)
                return [Stage(blocks, L // p)]
            return [Stage((SubBlock(ATTN, MLP),), L)]
        if self.family == "moe":
            out = []
            if self.moe_dense_first:
                out.append(Stage((SubBlock(ATTN, MLP),), self.moe_dense_first))
            rest = L - self.moe_dense_first
            if self.moe_every == 1:
                out.append(Stage((SubBlock(ATTN, MOE),), rest))
            else:
                p = self.moe_every
                blocks = tuple(SubBlock(ATTN, MOE if (i % p == p - 1) else MLP)
                               for i in range(p))
                assert rest % p == 0
                out.append(Stage(blocks, rest // p))
            return out
        if self.family == "hybrid":
            p = self.hybrid_period                     # jamba: 8
            assert L % p == 0
            blocks = []
            for i in range(p):
                mixer = ATTN if i % p == p // 2 - 1 else MAMBA   # 1 attn : p-1 mamba
                ffn = MOE if (self.moe_experts and i % 2 == 1) else MLP
                blocks.append(SubBlock(mixer, ffn))
            return [Stage(tuple(blocks), L // p)]
        if self.family == "ssm":                       # xlstm
            p = self.ssm_pattern or 6
            assert L % p == 0
            blocks = tuple(SubBlock(SLSTM if i == p - 1 else MLSTM, NONE)
                           for i in range(p))
            return [Stage(blocks, L // p)]
        if self.family in ("encdec", "audio"):
            # decoder stages (self-attn + cross-attn handled by the encdec
            # model wrapper; here we describe the decoder stack)
            return [Stage((SubBlock(ATTN, MLP),), L)]
        raise ValueError(self.family)

    # ------------------------------------------------------------------ #
    def layer_windows(self) -> list[tuple[str, int]]:
        """Per-sub-block (mixer, window) for attention mixers (0 = full)."""
        out = []
        for st in self.stages():
            for b in st.blocks:
                if b.mixer == ATTN_LOCAL:
                    out.append((b.mixer, self.window))
                elif b.mixer in (ATTN, ATTN_GLOBAL):
                    out.append((b.mixer, self.window if self.attn_kind == "swa" else 0))
                else:
                    out.append((b.mixer, 0))
        return out

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        st = self.stages()
        period = max(len(s.blocks) for s in st)
        layers = period * max(1, 2 if self.family != "moe" else 1)
        if self.moe_dense_first:
            layers = max(layers, 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=layers if not self.moe_dense_first else 1 + 1,
            d_model=128,
            n_heads=4, n_kv=4 if self.enc_dec else (min(self.n_kv, 2) or 2),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe_experts=min(self.moe_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=128 if self.moe_experts else 0,
            moe_dense_first=1 if self.moe_dense_first else 0,
            q_lora=64, kv_lora=32, rope_dim=16, nope_dim=32, v_head_dim=32,
            window=min(self.window, 64) if self.window else 0,
            n_patches=16,
            dtype="float32", param_dtype="float32",
            remat=False, loss_chunk=64,
        )

    # ------------------------------------------------------------------ #
    def traffic_spec(self, params: float | None = None,
                     active: float | None = None) -> TrafficModelSpec:
        return TrafficModelSpec(
            name=self.name, n_layers=self.n_layers, d_model=self.d_model,
            d_ff=self.d_ff or self.moe_d_ff, vocab=self.vocab,
            params=params or 0.0, active_params=active or 0.0,
            moe_experts=self.moe_experts, moe_top_k=self.moe_top_k,
            moe_layer_every=self.moe_every,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input-shape) dry-run cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
