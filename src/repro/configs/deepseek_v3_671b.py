"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8
[arXiv:2412.19437].  61L d_model=7168 128H d_ff=2048/expert vocab=129280.
First 3 layers dense-FFN (paper); MTP head omitted (noted in DESIGN.md).
Pure full-softmax attention over the whole context => long_500k skipped.
Optimizer state in bf16 (671B params / 16GB HBM chips)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv=128, d_ff=18432, vocab=129280, head_dim=128,
    mla=True, q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
    v_head_dim=128,
    moe_experts=256, moe_top_k=8, moe_shared=1, moe_d_ff=2048,
    moe_dense_first=3, opt_dtype="bfloat16",
)
