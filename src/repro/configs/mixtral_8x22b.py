"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
Sliding-window attention bounds the decode cache => runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    attn_kind="swa", window=4096,
    moe_experts=8, moe_top_k=2, moe_d_ff=16384,
    subquadratic=True,
)
