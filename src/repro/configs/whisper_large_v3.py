"""whisper-large-v3 [audio] — enc-dec, conv frontend stub
[arXiv:2212.04356].  32L (encoder AND decoder) d_model=1280 20H d_ff=5120
vocab=51866.  input_specs() supplies precomputed log-mel frame embeddings
(the conv1d stem is the assignment-mandated stub).  Decode shapes run (the
decoder self-attn caches + cross-attends to encoder states); long_500k is
out of the modality domain => skipped (DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv=20, d_ff=5120, vocab=51866,
    enc_dec=True, frontend="audio_frames", rope_theta=0.0,
    mlp_kind="gelu", tie_embeddings=False,
)
