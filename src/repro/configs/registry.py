"""Arch registry: ``--arch <id>`` ids → ArchConfig."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.gemma3_27b import CONFIG as _gemma
from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.mistral_large_123b import CONFIG as _mlarge
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.xlstm_125m import CONFIG as _xlstm

ARCHS: dict[str, ArchConfig] = {c.name: c for c in (
    _xlstm, _mixtral, _deepseek, _llava, _granite,
    _nemo, _mlarge, _gemma, _jamba, _whisper,
)}


def get(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise SystemExit(
            f"unknown --arch {name!r}; available: {sorted(ARCHS)}") from None


def cells():
    """All (arch, shape) dry-run cells, with skip reasons where applicable."""
    out = []
    for name, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.subquadratic:
                skip = "pure full-attention (or out-of-modality): quadratic at 500k"
            out.append((name, sname, skip))
    return out
