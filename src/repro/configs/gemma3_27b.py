"""gemma3-27b [dense] — 5:1 local:global, 128k
[hf:google/gemma-3].  62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144.  62 layers = ten full (5 local + 1 global) groups + a
2-layer (1 local + 1 global) tail stage, keeping the published 5:1 ratio
and layer count (stage structure noted in DESIGN.md).  Local window 1024;
global layers are sparse (1-in-6) with the 500k KV sequence-sharded over
the mesh => runs long_500k."""
import dataclasses

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MLP, ArchConfig,
                                Stage, SubBlock)


@dataclasses.dataclass(frozen=True)
class Gemma3Config(ArchConfig):
    def stages(self):
        # 60 layers of (5 local + 1 global) + 2-layer tail (1 local + 1 global)
        main = Stage(tuple(SubBlock(ATTN_GLOBAL if i == 5 else ATTN_LOCAL, MLP)
                           for i in range(6)), 10)
        tail = Stage((SubBlock(ATTN_LOCAL, MLP), SubBlock(ATTN_GLOBAL, MLP)), 1)
        return [main, tail]


CONFIG = Gemma3Config(
    name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
    n_heads=32, n_kv=16, d_ff=21504, vocab=262144, head_dim=128,
    attn_kind="local_global", window=1024, local_global_period=6,
    rope_theta=1e6, subquadratic=True,
)
