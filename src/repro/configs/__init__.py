"""Architecture configs: one module per assigned architecture (+ the paper's
own Table-1 workloads live in repro.workload.presets).  Use
``repro.configs.registry.get(name)`` / ``--arch <id>`` in the launchers."""

from repro.configs.base import SHAPES, ArchConfig, ShapeCell
from repro.configs.registry import ARCHS, get
