"""mistral-large-123b [dense]
[hf:mistralai/Mistral-Large-Instruct-2407].  88L d_model=12288 96H (GQA
kv=8) d_ff=28672 vocab=32768.  Full attention => long_500k skipped.
Optimizer state in bf16 (123B params)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv=8, d_ff=28672, vocab=32768, head_dim=128,
    opt_dtype="bfloat16",
)
