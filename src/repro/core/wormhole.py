"""Wormhole kernel (paper Fig. 6 workflow) — plugs into PacketSim.

Per-partition state machine:

    form ──memo hit──> REPLAY ──T_conv──> STEADY (stored FCG_end rates)
      │                                      │
      └─miss──> UNSTEADY ──ΔR_l<θ (all)──> STEADY ──interrupt──> reshape/form
                   │  (insert on first steady / completion)        │
                   └──────────────── completion ───────────────────┘

Interrupts (§5.3): ① flow entry (real-time ⇒ skip-back: lazy materialization
at the interrupt's own timestamp), ② flow completion (scheduled as the park
horizon = earliest virtual completion), ③ reroute (exposed as remove+add).
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.core import theory
from repro.core.fcg import FCG, build_fcg
from repro.core.memo import COMPLETION as R_COMPLETION
from repro.core.memo import STEADY as R_STEADY
from repro.core.memo import MemoEntry, MemoHit, SimDB, sim_fingerprint
from repro.core.partition import PartitionIndex
from repro.core.steady import is_steady, rate_estimate
from repro.net.packet_sim import KERNEL, FlowRT, PacketSim, SimKernel

UNSTEADY, REPLAY, PARKED = 0, 1, 2


@dataclasses.dataclass
class WormholeConfig:
    theta: float = 0.05            # fluctuation threshold (paper default, §7)
    # Per-partition adaptive θ from the paper's own guidance (Eq. 11):
    # θ_p = max(theta, theta_slack · sqrt(7·N_p / (16·C·RTT))) — below the
    # steady sawtooth amplitude the detector can never fire (§5.2).
    theta_auto: bool = True
    theta_slack: float = 1.3
    theta_cap: float = 0.30
    window: int = 32               # detection interval l cap (samples)
    # Per-partition l from Eq. 13: the window span must cover ≥2 sawtooth
    # periods T_C; shorter partitions detect sooner, longer never exceed cap.
    window_auto: bool = True
    window_min: int = 8
    metric: str = "rate"           # rate | inflight | qlen  (Fig 13a)
    enable_memo: bool = True
    enable_steady: bool = True
    max_skip: float = 0.5          # horizon refresh bound (s)
    min_flows_memo: int = 1
    # Beyond-paper robustness: a slow monotone ramp drifts < θ per window yet
    # is not converged (Eq. 5 assumes CCA convergence).  Require a second,
    # half-window-later check whose window mean agrees within θ/2 before
    # parking.  Disable for the paper-faithful detector.
    confirm: bool = True


@dataclasses.dataclass(slots=True)
class Part:
    pid: int
    gen: int
    fids: set[int]
    ports: frozenset[int]
    state: int = UNSTEADY
    formed_at: float = 0.0
    samples: int = 0
    entry_delivered: dict[int, float] = dataclasses.field(default_factory=dict)
    fcg: FCG | None = None
    miss: bool = False
    hit: MemoHit | None = None
    park_t: float = 0.0
    park_delivered: dict[int, float] = dataclasses.field(default_factory=dict)
    pending_means: dict[int, float] | None = None
    confirm_at: int = 0
    theta: float = 0.05
    window: int = 32


class WormholeKernel(SimKernel):
    def __init__(self, cfg: WormholeConfig | None = None, db: SimDB | None = None) -> None:
        self.cfg = cfg or WormholeConfig()
        self.db = db if db is not None else SimDB()
        self.index = PartitionIndex()
        self.parts: dict[int, Part] = {}
        self.metric_hist: dict[int, deque] = {}
        self._gen = 0
        self._finish_queue: deque[int] = deque()
        self._draining = False
        self.stats = {
            "parks": 0, "replays": 0, "skip_backs": 0, "unparks": 0,
            "est_events_skipped": 0.0, "skipped_flow_seconds": 0.0,
            "steady_flow_seconds": 0.0,
        }
        self.flow_steady_time: dict[int, float] = {}

    def attach(self, sim: PacketSim) -> None:
        super().attach(sim)
        sim.window = max(sim.window, self.cfg.window)
        # a DB recorded under one MTU/ECN/buffer/sampling regime must never
        # be replayed under another — bind (or verify) the fingerprint
        self.db.bind_fingerprint(sim_fingerprint(
            sim.mtu, sim.ecn_k, sim.buffer_bytes, sim.shared_buffer,
            sim.sample_interval if sim.sample_interval_explicit else None))
        # a partition-sharded sim keys its event lanes off this kernel's
        # live PartitionIndex — one lifecycle drives both (no shadow index)
        adopt = getattr(sim, "adopt_partition_index", None)
        if adopt is not None:
            adopt(self.index)

    # ------------------------------------------------------------------ #
    # interrupt ①: flow entry (merge + skip-back for parked partitions)
    # ------------------------------------------------------------------ #
    def on_flow_start(self, flow: FlowRT) -> None:
        self.on_flows_start([flow])

    def on_flows_start(self, flows: list[FlowRT]) -> None:
        """Batch admission: flows launched at the same instant (one
        collective call) form their partitions in one step, so the memoized
        FCG is the whole collective's conflict graph rather than a chain of
        partial ones."""
        now = self.sim.now
        self._with_drain(lambda: self._admit(flows, now), now)

    def _admit(self, flows: list[FlowRT], now: float) -> None:
        all_ports: set[int] = set()
        for f in flows:
            all_ports |= f.ports
        for pid in self.index.affected_partitions(all_ports):
            part = self.parts.get(pid)
            if part is not None and part.state != UNSTEADY:
                self._skip_back(part, now)
        for f in flows:
            _, merged = self.index.add_flow(f.fid, f.ports)
            for pid in merged:
                self.parts.pop(pid, None)
        final_pids = {self.index.flow_pid[f.fid] for f in flows}
        # sorted: partitions form (and schedule their first sample) in pid
        # order, not set order
        for pid in sorted(final_pids):
            self._form(pid, self.index.parts[pid], now)

    def _skip_back(self, part: Part, now: float) -> None:
        """Real-time interrupt at t2 < parked horizon t1: materialize the
        partition's analytic state at t2 and resume packet simulation (§6.3)."""
        self._account_skip(part, now)
        alive = [fid for fid in part.fids if not self.sim.flows[fid].done]
        self.sim.unpark_flows(alive, part.ports, now, now - part.park_t)
        part.state = UNSTEADY
        part.gen = -1  # orphan any pending UNPARK
        part.samples = 0
        self.stats["skip_backs"] += 1

    # ------------------------------------------------------------------ #
    # interrupt ①b: chaos (port capacity retargeted under live partitions)
    # ------------------------------------------------------------------ #
    def on_chaos(self, now: float, ports) -> None:
        """A chaos injector changed these ports' capacities: any parked or
        replaying partition touching them holds stale steady rates (and a
        memo match recorded under the old capacity) — skip back to packet
        fidelity and re-measure under the new regime."""
        affected = set(ports)

        def go() -> None:
            for pid in self.index.affected_partitions(affected):
                part = self.parts.get(pid)
                if part is not None and part.state != UNSTEADY:
                    self._skip_back(part, now)
        self._with_drain(go, now)

    # ------------------------------------------------------------------ #
    # interrupt ②: flow completion (reshape + possible split)
    # ------------------------------------------------------------------ #
    def on_flow_finish(self, flow: FlowRT, now: float) -> None:
        self._finish_queue.append(flow.fid)
        if not self._draining:
            self._with_drain(lambda: None, now)

    def _with_drain(self, fn, now: float) -> None:
        if self._draining:
            fn()
            return
        self._draining = True
        try:
            fn()
            while self._finish_queue:
                self._finish_reshape(self._finish_queue.popleft(), now)
        finally:
            self._draining = False

    def _finish_reshape(self, fid: int, now: float) -> None:
        pid = self.index.flow_pid.get(fid)
        if pid is None:
            return
        part = self.parts.get(pid)
        if part is not None:
            if part.state != UNSTEADY:
                # completion surfaced while parked (e.g. drained bytes at a
                # replay park): materialize + resume the residual flows
                self._account_skip(part, now)
                for g in list(part.fids):
                    self.sim._materialize(self.sim.flows[g], now)
                alive = [g for g in part.fids if not self.sim.flows[g].done]
                self.sim.unpark_flows(alive, part.ports, now, now - part.park_t)
                part.state = UNSTEADY
            elif (part.miss and self.cfg.enable_memo
                    and part.fcg is not None and now > part.formed_at):
                self._memo_insert(part, now, R_COMPLETION)
                part.miss = False
            part.gen = -1
            self.parts.pop(pid, None)
        _, splits = self.index.remove_flow(fid)
        for new_pid, flows in splits:
            self._form(new_pid, flows, now)

    # ------------------------------------------------------------------ #
    # partition formation: memo query (Fig 6 steps ①②③)
    # ------------------------------------------------------------------ #
    def _form(self, pid: int, fids: set[int], now: float) -> None:
        sim = self.sim
        # fids is iterated sorted throughout: every derived ordering
        # (entry_delivered, metric_hist insertion) is a pure function of the
        # flow ids, never of set-insertion history
        ordered = sorted(fids)
        ports: set[int] = set()
        for fid in ordered:
            ports |= self.index.flow_ports[fid]
        self._gen += 1
        part = Part(pid=pid, gen=self._gen, fids=set(fids), ports=frozenset(ports),
                    formed_at=now,
                    entry_delivered={fid: sim.flows[fid].delivered
                                     for fid in ordered})
        part.theta = self._theta_for(fids)
        part.window = self._window_for(fids)
        self.parts[pid] = part
        for fid in ordered:
            f = sim.flows[fid]
            f.rate_hist.clear()
            f.last_sample_delivered = f.delivered
            f.last_sample_t = now
            self.metric_hist[fid] = deque(maxlen=self.cfg.window)

        if self.cfg.enable_memo and len(fids) >= self.cfg.min_flows_memo:
            part.fcg = self._build_fcg(part)
            remaining = [sim.flows[fid].remaining() for fid in part.fcg.fids]
            hit = self.db.lookup(part.fcg, remaining, atol=2 * sim.mtu)
            if hit is not None:
                self._apply_hit(part, hit, now)
                return
            part.miss = True

    def _theta_for(self, fids) -> float:
        cfg = self.cfg
        if not cfg.theta_auto:
            return cfg.theta
        # Eq. 11 is the DCTCP sawtooth guidance; other CCAs carry their own
        # steady-oscillation hint (the drift guard below keeps slow
        # convergence ramps from being admitted by a loose θ — before it,
        # DCQCN DP flows parked mid-ramp with 42% FCT error; §Perf notes).
        eps = 0.0
        for fid in fids:
            cca = self.sim.flows[fid].cca
            if cca.steady_eps_hint is not None:
                eps = max(eps, cca.steady_eps_hint)
            else:  # window/sawtooth CCAs (dctcp, hpcc): Eq. 11 guidance
                crtt = cca.line_rate * cca.base_rtt / self.sim.mtu
                eps = max(eps, theory.dctcp_relative_fluctuation(
                    len(fids), 1.0, crtt, mss=1.0))
        return min(max(cfg.theta, cfg.theta_slack * eps), cfg.theta_cap)

    def _window_for(self, fids) -> int:
        cfg = self.cfg
        if not cfg.window_auto:
            return cfg.window
        sim = self.sim
        f0 = sim.flows[next(iter(fids))]
        l = theory.l_guidance(len(fids), f0.cca.line_rate, f0.cca.base_rtt,
                              sim.ecn_k, sim.sample_interval, mss=sim.mtu)
        return min(max(l, cfg.window_min), cfg.window)

    def _build_fcg(self, part: Part) -> FCG:
        sim = self.sim
        fids = sorted(part.fids)
        # line-rate labels come from the *live* capacities, not the flow's
        # add-time cca.line_rate: after a chaos capacity retarget the same
        # flow pattern is a different regime and must miss entries recorded
        # under the old rates.  Without chaos _link_bw holds exactly
        # float(topo.link_bw[p]), so keys are unchanged bit-for-bit.
        return build_fcg(
            fids, {fid: self.index.flow_ports[fid] for fid in fids},
            rates={fid: sim.flows[fid].cca.rate() for fid in fids},
            line_rates={fid: min(sim._link_bw[p] for p in sim.flows[fid].path)
                        for fid in fids},
            ccas={fid: sim.flows[fid].spec.cca for fid in fids},
            rtts={fid: sim.flows[fid].cca.base_rtt for fid in fids},
        )

    def _apply_hit(self, part: Part, hit: MemoHit, now: float) -> None:
        """Fast-forward the transient: replay the stored per-flow transfer
        volumes over T_conv, then jump to the stored FCG_end (§4.4)."""
        sim = self.sim
        e = hit.entry
        t_conv = max(e.t_conv, 1e-9)
        vrates = {}
        for u, v in hit.mapping.items():
            fid = part.fcg.fids[v]
            vrates[fid] = max(e.sizes[u], 1.0) / t_conv
        part.state = REPLAY
        part.hit = hit
        part.park_t = now
        part.park_delivered = {fid: sim.flows[fid].delivered for fid in part.fids}
        sim.park_flows(list(part.fids), now, vrates)
        sim.schedule(now + t_conv, KERNEL, ("unpark", part.pid, part.gen))
        self.stats["replays"] += 1

    # ------------------------------------------------------------------ #
    # steady-state detection (Fig 6 step ④⑤) — runs on monitor samples
    # ------------------------------------------------------------------ #
    def on_sample(self, now: float) -> None:
        sim = self.sim
        cfg = self.cfg
        for fid, f in sim.flows.items():
            if not f.started or f.done or f.parked:
                continue
            hist = self.metric_hist.get(fid)
            if hist is None:
                continue
            if cfg.metric == "rate":
                if f.rate_hist:
                    hist.append(f.rate_hist[-1])
            elif cfg.metric == "inflight":
                hist.append(f.inflight)
            elif cfg.metric == "qlen":
                # _link_bw is the sim's plain-float list cache of
                # topo.link_bw — same IEEE doubles, no ndarray scalar boxing
                hist.append(max((max(0.0, (sim.busy_until[p] - now)) * sim._link_bw[p]
                                 for p in f.path), default=0.0))
            else:
                raise ValueError(f"unknown metric {cfg.metric!r}")
        if not cfg.enable_steady:
            return
        self._with_drain(lambda: self._detect(now), now)

    def _detect(self, now: float) -> None:
        cfg = self.cfg
        sim = self.sim
        for part in list(self.parts.values()):
            if part.state != UNSTEADY or part.pid not in self.parts:
                continue
            part.samples += 1
            if part.samples < part.window:
                continue
            flows = [sim.flows[fid] for fid in part.fids]
            if any(not f.started or f.done or f.parked for f in flows):
                continue
            atol = 2 * sim.mtu if cfg.metric == "qlen" else 0.0
            if not all(is_steady(self.metric_hist[f.fid], part.window, part.theta,
                                 atol)
                       for f in flows):
                part.pending_means = None
                continue
            if not cfg.confirm:
                self._enter_steady(part, now)
                continue
            means = {f.fid: rate_estimate(f.rate_hist, part.window) for f in flows}
            if part.pending_means is None:
                part.pending_means = means
                part.confirm_at = part.samples + max(part.window // 2, 2)
            elif part.samples >= part.confirm_at:
                prev = part.pending_means
                tot_now = sum(means.values())
                tot_prev = sum(prev.get(fid, m) for fid, m in means.items())
                # partition-level drift: a slow convergence ramp moves every
                # flow the same way; steady oscillation does not
                drifting = abs(tot_now - tot_prev) > (part.theta / 6) * max(tot_now, 1e-9)
                if not drifting and all(
                        fid in prev and abs(m - prev[fid]) <= (part.theta / 2) * max(m, 1e-9)
                        for fid, m in means.items()):
                    self._enter_steady(part, now)
                else:
                    part.pending_means = means
                    part.confirm_at = part.samples + max(part.window // 2, 2)

    def _enter_steady(self, part: Part, now: float) -> None:
        sim = self.sim
        vrates = {fid: max(rate_estimate(sim.flows[fid].rate_hist, part.window), 1e-3)
                  for fid in part.fids}
        if part.miss and self.cfg.enable_memo and part.fcg is not None:
            self._memo_insert(part, now, R_STEADY, vrates)
            part.miss = False
        self._park(part, now, vrates)

    def _park(self, part: Part, now: float, vrates: dict[int, float]) -> None:
        sim = self.sim
        part.state = PARKED
        part.park_t = now
        part.park_delivered = {fid: sim.flows[fid].delivered for fid in part.fids}
        sim.park_flows(list(part.fids), now, vrates)
        horizon = now + self.cfg.max_skip
        for fid in part.fids:
            f = sim.flows[fid]
            if not f.done:
                horizon = min(horizon, sim.virtual_completion(f))
        self._gen += 1
        part.gen = self._gen
        sim.schedule(max(horizon, now + 1e-9), KERNEL, ("unpark", part.pid, part.gen))
        self.stats["parks"] += 1

    def _memo_insert(self, part: Part, now: float, reason: str,
                     vrates: dict[int, float] | None = None) -> None:
        sim = self.sim
        fcg = part.fcg
        sizes, end_rates, completed = [], [], []
        for v, fid in enumerate(fcg.fids):
            f = sim.flows[fid]
            sizes.append(f.delivered - part.entry_delivered.get(fid, 0.0))
            end_rates.append(vrates[fid] if vrates else f.cca.rate())
            if f.done:
                completed.append(v)
        backlogs = [max(0.0, (sim.busy_until[p] - now)) * sim._link_bw[p]
                    for p in part.ports]
        shared = [b for b in backlogs if b > 0]
        self.db.insert(MemoEntry(
            fcg=fcg, end_rates=end_rates, sizes=sizes,
            t_conv=max(now - part.formed_at, 1e-9), end_reason=reason,
            mean_backlog=(sum(shared) / len(shared)) if shared else 0.0,
            completed=tuple(completed),
        ))

    # ------------------------------------------------------------------ #
    # park horizon reached (Fig 6 steps ⑥⑦: interrupts + re-partition)
    # ------------------------------------------------------------------ #
    def on_kernel_event(self, now: float, payload) -> None:
        kind, pid, gen = payload
        part = self.parts.get(pid)
        if part is None or part.gen != gen or part.state == UNSTEADY:
            return
        self._with_drain(lambda: self._unpark(part, now), now)

    def _unpark(self, part: Part, now: float) -> None:
        sim = self.sim
        was_replay = part.state == REPLAY
        self._account_skip(part, now)
        for fid in list(part.fids):
            sim._materialize(sim.flows[fid], now)   # finishes enqueue on the drain
        alive = [fid for fid in part.fids if not sim.flows[fid].done]
        sim.unpark_flows(alive, part.ports, now, now - part.park_t)
        self.stats["unparks"] += 1

        if was_replay and part.hit is not None:
            e = part.hit.entry
            # jump to FCG_end: converged CCA state + frozen contention queues
            for u, v in part.hit.mapping.items():
                fid = part.fcg.fids[v]
                f = sim.flows[fid]
                if f.done:
                    continue
                f.cca.r = max(e.end_rates[u], 1e-3)
                if f.cca.window_based:
                    # w is the control variable: set it so w/srtt == r
                    f.cca.w = f.cca.r * max(f.cca.srtt, f.cca.base_rtt)
                # rate-based CCAs (DCQCN/TIMELY) keep w as a loose in-flight
                # cap — shrinking it to r*srtt would pin the flow at its
                # parked rate after the fast-forward
            if e.mean_backlog > 0:
                port_users: dict[int, int] = {}
                for fid in alive:
                    for p in sim.flows[fid].path:
                        port_users[p] = port_users.get(p, 0) + 1
                for p, cnt in port_users.items():
                    if cnt >= 2:
                        sim.busy_until[p] = max(
                            sim.busy_until[p],
                            now + e.mean_backlog / sim._link_bw[p])
            if e.end_reason == R_STEADY and self.cfg.enable_steady and alive:
                vrates = {}
                ok = True
                for u, v in part.hit.mapping.items():
                    fid = part.fcg.fids[v]
                    if fid in alive:
                        vrates[fid] = max(e.end_rates[u], 1e-3)
                        h = self.metric_hist.get(fid)
                        if h is not None:
                            h.extend([vrates[fid]] * self.cfg.window)
                    elif sim.flows[fid].done:
                        ok = False  # unexpected completion → re-detect
                if ok and len(vrates) == len(alive):
                    self._park(part, now, vrates)
                    return
        part.state = UNSTEADY
        part.formed_at = now
        part.samples = 0

    def _account_skip(self, part: Part, now: float) -> None:
        sim = self.sim
        steady = part.state == PARKED
        for fid in part.fids:
            f = sim.flows[fid]
            end = min(now, f.finish_t) if f.done else now
            d = max(0.0, end - part.park_t)
            self.stats["skipped_flow_seconds"] += d
            if steady:
                self.stats["steady_flow_seconds"] += d
                self.flow_steady_time[fid] = self.flow_steady_time.get(fid, 0.0) + d
            prev = part.park_delivered.get(fid, f.delivered)
            cur = f.spec.size if f.done else (
                f.delivered + max(0.0, (min(now, sim.now) - f.park_t)) * f.vrate)
            adv = max(0.0, min(cur, f.spec.size) - prev)
            self.stats["est_events_skipped"] += (adv / sim.mtu) * (len(f.path) + 3)

    # ------------------------------------------------------------------ #
    def report(self) -> dict:
        out = dict(self.stats)
        out.update({f"db_{k}": v for k, v in self.db.stats().items()})
        out["events_processed"] = self.sim.events_processed
        out["partitions"] = self._gen
        return out
