"""Port-level network partitioning (paper §3.1.1, §4.1, Appendix A/E).

Definition 1: flows sharing a port, together with all ports their paths
traverse, form one partition.  Equivalently: connected components of the
bipartite flow↔port graph.  ``network_partitioner`` is the from-scratch
Algorithm 1 (iterative DFS — recursion-free for large graphs);
``PartitionIndex`` maintains partitions incrementally under flow entry/exit
(Algorithm 2, Appendix E).
"""
from __future__ import annotations

import itertools
from collections.abc import Iterable, Mapping


def construct_bipartite_graph(flow_ports: Mapping[int, frozenset[int]]):
    """connections: flow id -> ports, port -> flow ids (Algorithm 1 l.1-7)."""
    port_to_flows: dict[int, list[int]] = {}
    for fid, ports in flow_ports.items():
        for p in ports:
            port_to_flows.setdefault(p, []).append(fid)
    return port_to_flows


def network_partitioner(flow_ports: Mapping[int, frozenset[int]]) -> list[set[int]]:
    """Algorithm 1: connected components via DFS over the bipartite graph.
    O(N + M) with N flows, M ports."""
    port_to_flows = construct_bipartite_graph(flow_ports)
    visited_f: set[int] = set()
    visited_p: set[int] = set()
    partitions: list[set[int]] = []
    for start in flow_ports:
        if start in visited_f:
            continue
        comp: set[int] = set()
        stack: list[tuple[bool, int]] = [(True, start)]  # (is_flow, id)
        while stack:
            is_flow, v = stack.pop()
            if is_flow:
                if v in visited_f:
                    continue
                visited_f.add(v)
                comp.add(v)
                for p in flow_ports[v]:
                    if p not in visited_p:
                        stack.append((False, p))
            else:
                if v in visited_p:
                    continue
                visited_p.add(v)
                for g in port_to_flows.get(v, ()):
                    if g not in visited_f:
                        stack.append((True, g))
        partitions.append(comp)
    return partitions


class PartitionObserver:
    """Callback protocol for structures that shadow the partition lifecycle
    (e.g. the sharded event loop's per-partition lanes): ``add_flow`` emits
    one merge event, ``remove_flow`` one split event.  Callbacks fire *after*
    the index reflects the change, so observers may query it freely."""

    def on_partition_merge(self, fid: int, new_pid: int,
                           merged_pids: set[int]) -> None: ...

    def on_partition_split(self, fid: int, old_pid: int,
                           new_parts: list[tuple[int, set[int]]]) -> None: ...


class PartitionIndex:
    """Incremental partition maintenance (Algorithm 2).

    Tracks {pid -> flows}, {flow -> pid}, {port -> pid} and the per-flow port
    sets.  ``add_flow`` merges every partition the new flow touches;
    ``remove_flow`` re-partitions only the residual flows of the leaving
    flow's partition (worst case degrades to Algorithm 1 on that subset).
    An optional :class:`PartitionObserver` mirrors merges/splits — the
    sharded event loop keys its lanes off this exact lifecycle."""

    GRANULARITIES = ("packet", "flow")

    def __init__(self) -> None:
        self._pid = itertools.count(1)
        self.parts: dict[int, set[int]] = {}
        self.flow_pid: dict[int, int] = {}
        self.flow_ports: dict[int, frozenset[int]] = {}
        self.port_pid: dict[int, int] = {}
        # simulation granularity tag per partition (the hybrid backend's
        # per-partition fidelity control): merges reset to "packet" (new
        # contention pattern), splits inherit (contention only shrank)
        self.granularity: dict[int, str] = {}
        self.observer: PartitionObserver | None = None

    # ------------------------------------------------------------------ #
    def set_granularity(self, pid: int, gran: str) -> None:
        if gran not in self.GRANULARITIES:
            raise ValueError(f"unknown granularity {gran!r}; "
                             f"have {self.GRANULARITIES}")
        if pid not in self.parts:
            raise KeyError(f"no partition {pid}")
        self.granularity[pid] = gran

    def ports_of(self, pid: int) -> set[int]:
        out: set[int] = set()
        for fid in self.parts[pid]:
            out |= self.flow_ports[fid]
        return out

    def affected_partitions(self, ports: Iterable[int]) -> set[int]:
        return {self.port_pid[p] for p in ports if p in self.port_pid}

    # ------------------------------------------------------------------ #
    def add_flow(self, fid: int, ports: frozenset[int]) -> tuple[int, set[int]]:
        """Insert a flow; returns (new_pid, set of merged old pids)."""
        assert fid not in self.flow_pid, f"flow {fid} already present"
        affected = self.affected_partitions(ports)
        merged_flows = {fid}
        for pid in affected:
            merged_flows |= self.parts.pop(pid)
            self.granularity.pop(pid, None)
        self.flow_ports[fid] = ports
        new_pid = next(self._pid)
        self.parts[new_pid] = merged_flows
        self.granularity[new_pid] = "packet"
        # sorted: flow_pid/port_pid insertion order becomes a pure function
        # of the flow ids, not of set-merge history
        for g in sorted(merged_flows):
            self.flow_pid[g] = new_pid
            for p in sorted(self.flow_ports[g]):
                self.port_pid[p] = new_pid
        if self.observer is not None:
            self.observer.on_partition_merge(fid, new_pid, affected)
        return new_pid, affected

    def remove_flow(self, fid: int) -> tuple[int, list[tuple[int, set[int]]]]:
        """Remove a flow; returns (old_pid, [(new_pid, flows)...] splits)."""
        old_pid = self.flow_pid.pop(fid)
        ports = self.flow_ports.pop(fid)
        rest = self.parts.pop(old_pid)
        gran = self.granularity.pop(old_pid, "packet")
        rest.discard(fid)
        for p in ports:
            if self.port_pid.get(p) == old_pid:
                del self.port_pid[p]
        new_parts: list[tuple[int, set[int]]] = []
        if rest:
            # residual may split: rerun Algorithm 1 locally (Appendix E)
            # sorted: component discovery order (and therefore pid
            # assignment) is a pure function of the flow ids
            for comp in network_partitioner(
                    {g: self.flow_ports[g] for g in sorted(rest)}):
                new_pid = next(self._pid)
                self.parts[new_pid] = comp
                self.granularity[new_pid] = gran
                for g in sorted(comp):
                    self.flow_pid[g] = new_pid
                    for p in sorted(self.flow_ports[g]):
                        self.port_pid[p] = new_pid
                new_parts.append((new_pid, comp))
        if self.observer is not None:
            self.observer.on_partition_split(fid, old_pid, new_parts)
        return old_pid, new_parts

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        """Partition invariants (used by property tests):
        1. partitions are disjoint and cover every flow;
        2. no port is traversed by flows of two different partitions;
        3. incremental state matches a from-scratch Algorithm 1 run."""
        seen: set[int] = set()
        for pid, flows in self.parts.items():
            assert flows, f"empty partition {pid}"
            assert not (flows & seen), "partitions overlap"
            seen |= flows
            for f in flows:
                assert self.flow_pid[f] == pid
        assert seen == set(self.flow_pid)
        port_seen: dict[int, int] = {}
        for fid, ports in self.flow_ports.items():
            pid = self.flow_pid[fid]
            for p in ports:
                assert port_seen.setdefault(p, pid) == pid, "port shared across partitions"
        fresh = {frozenset(c) for c in network_partitioner(self.flow_ports)}
        incr = {frozenset(c) for c in self.parts.values()}
        assert fresh == incr, "incremental drifted from Algorithm 1"
        assert set(self.granularity) == set(self.parts), \
            "granularity tags out of sync with partitions"
        assert all(g in self.GRANULARITIES for g in self.granularity.values())
