"""Flow Conflict Graph (paper §4.2) and weighted-isomorphism matching (§4.4).

FCG abstracts an unsteady partition: vertices are flows (labelled with a
bucketised instantaneous rate + CCA + bottleneck-bandwidth class), edges join
flows sharing ≥1 link (weight = number of shared links).  Absolute paths and
spatial positions are deliberately dropped (§4.2: "the resulting error is
negligible") — that is what makes recurring collective phases collide into
the same key.

Matching = two stages, as in the paper:
  1. cheap structural filter — a Weisfeiler-Leman canonical hash buckets
     candidates (mismatched vertex/edge counts or label multisets never meet);
  2. exact weighted graph isomorphism (VF2-style backtracking over WL colors)
     that also returns the vertex mapping needed to apply the memoized value.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections.abc import Sequence

RATE_BUCKET = 0.025   # vertex rate weights quantised to 2.5% of line rate


def stable_hash(obj) -> int:
    """Process-stable 48-bit hash of a (nested) tuple of ints/strings.

    ``hash()`` is salted per interpreter (PYTHONHASHSEED), which would make
    WL colors — and therefore SimDB bucket keys — meaningless the moment an
    FCG is persisted to disk or shipped to a worker process.  Every key that
    can outlive this process must come from here."""
    digest = hashlib.blake2b(repr(obj).encode(), digest_size=6).digest()
    return int.from_bytes(digest, "big") & 0x7FFFFFFFFFFF


@dataclasses.dataclass
class FCG:
    n: int
    labels: list[tuple]                 # per-vertex (cca, rate_bucket, bw_bucket)
    edges: dict[tuple[int, int], int]   # (i<j) -> #shared links
    fids: list[int]                     # vertex -> flow id (not part of the key)
    wl_colors: list[int] = dataclasses.field(default_factory=list)
    key: int = 0

    def nbytes(self) -> int:
        """Approximate storage footprint (Fig 9b accounting)."""
        return 24 * self.n + 12 * len(self.edges) + 16

    def refresh(self) -> None:
        """(Re)derive the WL colors and the canonical bucket key from the
        labels + edges.  Deterministic across processes (stable_hash)."""
        self.wl_colors = _wl_refine(self.labels, self.edges)
        self.key = stable_hash((
            self.n, len(self.edges),
            tuple(sorted(self.wl_colors)),
            tuple(sorted(self.edges.values())),
        ))

    # ------------------------------------------------------------------ #
    # serialization (SimDB persistence): labels/edges/fids are the data,
    # colors + key are recomputed on load so a DB always matches the
    # canonicalisation of the code that reads it
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "labels": [list(l) for l in self.labels],
            "edges": [[i, j, w] for (i, j), w in sorted(self.edges.items())],
            "fids": list(self.fids),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FCG":
        g = cls(
            n=int(d["n"]),
            labels=[tuple(l) for l in d["labels"]],
            edges={(int(i), int(j)): int(w) for i, j, w in d["edges"]},
            fids=[int(f) for f in d["fids"]],
        )
        g.refresh()
        return g


def _wl_refine(labels: Sequence[tuple], edges: dict[tuple[int, int], int],
               rounds: int = 3) -> list[int]:
    n = len(labels)
    adj: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (i, j), w in edges.items():
        adj[i].append((j, w))
        adj[j].append((i, w))
    colors = [stable_hash(l) for l in labels]
    for _ in range(rounds):
        colors = [
            stable_hash((colors[i], tuple(sorted((colors[j], w) for j, w in adj[i]))))
            for i in range(n)
        ]
    return colors


def build_fcg(fids: Sequence[int], flow_ports: dict[int, frozenset[int]],
              rates: dict[int, float], line_rates: dict[int, float],
              ccas: dict[int, str],
              rtts: dict[int, float] | None = None) -> FCG:
    order = sorted(fids)
    labels: list[tuple] = []
    for fid in order:
        lr = max(line_rates[fid], 1.0)
        rb = int(round(rates[fid] / (lr * RATE_BUCKET)))
        # beyond-paper robustness: an RTT class keeps transients from being
        # replayed across very different path lengths (the paper drops path
        # length entirely — exact on its symmetric fabrics, §4.2; the class
        # collapses to one value there so hit rates are unaffected)
        rtt_b = int(round((rtts or {}).get(fid, 0.0) / 2e-6))
        labels.append((ccas[fid], rb, int(round(lr / 1e9)), rtt_b))
    edges: dict[tuple[int, int], int] = {}
    for a in range(len(order)):
        pa = flow_ports[order[a]]
        for b in range(a + 1, len(order)):
            shared = len(pa & flow_ports[order[b]])
            if shared:
                edges[(a, b)] = shared
    g = FCG(n=len(order), labels=labels, edges=edges, fids=list(order))
    g.refresh()
    return g


def isomorphism(a: FCG, b: FCG) -> dict[int, int] | None:
    """Exact weighted-isomorphism a→b respecting labels + edge weights.
    Returns {vertex_in_a: vertex_in_b} or None.  Partitions are small
    (EP degree caps them at ≤128 flows, §3.1.1) so backtracking is cheap —
    WL colors prune almost all branching."""
    if a.n != b.n or len(a.edges) != len(b.edges):
        return None
    if sorted(a.wl_colors) != sorted(b.wl_colors):
        return None

    adj_a: list[dict[int, int]] = [dict() for _ in range(a.n)]
    adj_b: list[dict[int, int]] = [dict() for _ in range(b.n)]
    for (i, j), w in a.edges.items():
        adj_a[i][j] = w
        adj_a[j][i] = w
    for (i, j), w in b.edges.items():
        adj_b[i][j] = w
        adj_b[j][i] = w

    # candidates per a-vertex: equal label AND equal WL color
    cand = [
        [v for v in range(b.n) if b.labels[v] == a.labels[u] and b.wl_colors[v] == a.wl_colors[u]]
        for u in range(a.n)
    ]
    if any(not c for c in cand):
        return None
    order = sorted(range(a.n), key=lambda u: (len(cand[u]), -len(adj_a[u])))
    mapping: dict[int, int] = {}
    used: set[int] = set()

    def bt(k: int) -> bool:
        if k == a.n:
            return True
        u = order[k]
        for v in cand[u]:
            if v in used:
                continue
            ok = True
            for un, w in adj_a[u].items():
                vn = mapping.get(un)
                if vn is not None and adj_b[v].get(vn) != w:
                    ok = False
                    break
            if ok and sum(1 for un in adj_a[u] if un in mapping) != \
                    sum(1 for vn2 in adj_b[v] if vn2 in used):
                ok = False
            if ok:
                mapping[u] = v
                used.add(v)
                if bt(k + 1):
                    return True
                del mapping[u]
                used.discard(v)
        return False

    return dict(mapping) if bt(0) else None
