"""Wormhole — the paper's contribution: a user-transparent PLDES kernel that
memoizes unsteady-state transients (keyed on Flow Conflict Graphs) and
fast-forwards steady-states (identified by windowed rate fluctuation)."""

from repro.core.wormhole import WormholeKernel, WormholeConfig
from repro.core.partition import network_partitioner, PartitionIndex
from repro.core.fcg import FCG, build_fcg
from repro.core.memo import SimDB
from repro.core.steady import fluctuation, is_steady, rate_estimate
from repro.core import theory
