"""Wormhole — the paper's contribution: a user-transparent PLDES kernel that
memoizes unsteady-state transients (keyed on Flow Conflict Graphs) and
fast-forwards steady-states (identified by windowed rate fluctuation)."""

from repro.core import theory
from repro.core.fcg import FCG, build_fcg
from repro.core.memo import SimDB
from repro.core.partition import PartitionIndex, network_partitioner
from repro.core.steady import fluctuation, is_steady, rate_estimate
from repro.core.wormhole import WormholeConfig, WormholeKernel
