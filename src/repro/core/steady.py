"""Steady-state identification (paper §5.1).

A flow is steady when the *relative* fluctuation of the monitored metric over
the last ``l`` samples is below θ (Eq. 6); the steady rate estimate is the
window mean (Eq. 7).  Theorem 1 licenses using any of {R, inflight I, queue
Q} as the single monitored metric — all are exposed (Fig 13a sensitivity).

Scalar forms are used by the event-driven oracle; the ``*_batch`` numpy forms
are the oracle for the Pallas ``steady_scan`` kernel and the JAX fluid engine.
"""
from __future__ import annotations

from collections.abc import Sequence

import numpy as np


def fluctuation(hist: Sequence[float], atol: float = 0.0) -> float:
    """ΔR_l(t) = (max - min) / mean over the window (Eq. 6).  ``atol``:
    metrics pinned near zero (e.g. an empty queue under HPCC) are steady by
    definition even though their relative fluctuation is 0/0."""
    if not len(hist):
        return float("inf")
    mx = max(hist)
    mn = min(hist)
    if mx <= atol:
        return 0.0
    mean = sum(hist) / len(hist)
    if mean <= 0:
        return float("inf")
    return (mx - mn) / mean


def is_steady(hist: Sequence[float], l: int, theta: float,
              atol: float = 0.0) -> bool:
    if len(hist) < l:
        return False
    return fluctuation(list(hist)[-l:], atol) < theta


def rate_estimate(hist: Sequence[float], l: int) -> float:
    """R̂ = window mean (Eq. 7) — *not* max-min fair allocation: converged
    rates can deviate from max-min fairness in multi-hop congestion
    [Poseidon, NSDI'23], so we estimate from the simulated samples."""
    w = list(hist)[-l:]
    return sum(w) / max(len(w), 1)


# ---------------------------------------------------------------------- #
# Vectorised forms (numpy oracle for kernels/steady_scan and fluid engine)
# ---------------------------------------------------------------------- #
def fluctuation_batch(hist: np.ndarray, atol: float = 0.0) -> np.ndarray:
    """hist: [flows, l] -> ΔR_l per flow.  ``atol`` is the same dead-band the
    scalar ``fluctuation`` applies: a metric pinned at (or below) ``atol`` —
    e.g. a zero qlen under HPCC — is steady by definition, not 0/0-unsteady."""
    mx = hist.max(axis=-1)
    mn = hist.min(axis=-1)
    mean = hist.mean(axis=-1)
    out = np.where(mean > 0, (mx - mn) / np.where(mean > 0, mean, 1.0), np.inf)
    return np.where(mx <= atol, 0.0, out)


def steady_mask_batch(hist: np.ndarray, theta: float,
                      atol: float = 0.0) -> np.ndarray:
    return fluctuation_batch(hist, atol) < theta


def rate_estimate_batch(hist: np.ndarray) -> np.ndarray:
    return hist.mean(axis=-1)
