"""Error bounds and hyper-parameter guidance (paper §5.2, Appendix B-D).

Theorem 2:  |R̂ - R̄|/R̄        <  θ/(1-θ)   when ΔR_l(t) < θ
Theorem 3:  |T̂ - T̄|/T̄        <  θ
Eq. 11:     θ  ≳ sqrt(7N / (16·C·RTT))       (DCTCP sawtooth amplitude)
Eq. 13:     Δt(l) ≥ T_C = sqrt((C·RTT+K)/(2N)) RTTs   (cover ≥1 sawtooth)

C·RTT and K are in packets (MSS units) in the DCTCP fluid model.
"""
from __future__ import annotations

import math


def rate_error_bound(theta: float) -> float:
    """Theorem 2: upper bound on steady-rate estimation error."""
    assert 0 < theta < 1
    return theta / (1 - theta)


def duration_error_bound(theta: float) -> float:
    """Theorem 3: upper bound on steady-duration estimation error."""
    assert 0 < theta < 1
    return theta


def dctcp_relative_fluctuation(n_flows: int, bw_Bps: float, rtt_s: float,
                               mss: float = 1000.0) -> float:
    """ε_relative ≈ sqrt(7N/(16·C·RTT)) with C·RTT in packets (Eq. 11)."""
    c_rtt_pkts = bw_Bps * rtt_s / mss
    return math.sqrt(7 * n_flows / (16 * max(c_rtt_pkts, 1e-9)))


def theta_guidance(n_flows: int, bw_Bps: float, rtt_s: float,
                   mss: float = 1000.0, slack: float = 1.5) -> float:
    """θ slightly above the steady-state's own sawtooth fluctuation: below it
    the detector never fires (no acceleration), far above it transients get
    misclassified (rate error)."""
    return slack * dctcp_relative_fluctuation(n_flows, bw_Bps, rtt_s, mss)


def sawtooth_period_rtts(n_flows: int, bw_Bps: float, rtt_s: float,
                         ecn_k_bytes: float, mss: float = 1000.0) -> float:
    """T_C = sqrt((C·RTT + K)/(2N)) in RTTs (DCTCP batch-drain period)."""
    c_rtt = bw_Bps * rtt_s / mss
    k = ecn_k_bytes / mss
    return math.sqrt((c_rtt + k) / (2 * max(n_flows, 1)))


def l_guidance(n_flows: int, bw_Bps: float, rtt_s: float, ecn_k_bytes: float,
               sample_interval_s: float, mss: float = 1000.0,
               periods: float = 2.0) -> int:
    """Smallest window length l whose span Δt(l) covers ``periods`` sawtooth
    periods (Eq. 13; below T_C the fluctuation estimate is biased)."""
    t_c = sawtooth_period_rtts(n_flows, bw_Bps, rtt_s, ecn_k_bytes, mss) * rtt_s
    return max(4, int(math.ceil(periods * t_c / max(sample_interval_s, 1e-12))) + 1)
