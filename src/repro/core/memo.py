"""Simulation database (paper §4.3/§4.4): memoization of unsteady-state
transients.

    key:   FCG_start            (canonical WL hash buckets + exact iso check)
    value: (FCG_end rates, {Size_f}, T_conv, end_reason)

Only entry/exit snapshots are stored, never packet traces — flow sizes
determine steady durations but are independent of the transient dynamics
(§4.3), so this is sufficient to reconstruct per-flow FCTs.  The whole DB is
O(100KB) at 1024-GPU scale (Fig 9b), lives in memory during a run, and is a
durable artifact between runs: ``save``/``load`` round-trip it through a
versioned JSON file and ``merge`` folds several DBs (e.g. the deltas of
parallel sweep workers) into one warm store (§6.1 multi-experiment reuse).

A DB is stamped with a *fingerprint* of the simulator regime it was recorded
under (MTU, ECN threshold, buffer sizing).  Those knobs shape transient
dynamics but are invisible to the FCG key, so replaying a DB across regimes
would silently corrupt results — ``bind_fingerprint`` (called when a kernel
attaches) and ``merge`` both refuse mismatches instead.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core.fcg import FCG, isomorphism

STEADY = "steady"
COMPLETION = "completion"

FORMAT_VERSION = 1

# default completion-match tolerance: ~2 packets at the scaled 1000B MTU;
# callers that know the simulation MTU pass atol=2*mtu instead (a jumbo-frame
# sim would otherwise spuriously reject, a tiny-MTU sim spuriously accept)
_DEFAULT_COMPLETION_ATOL = 2e3
# ...and the absolute slack is additionally capped relative to the flow's
# remaining bytes: 2 MTUs is packet-quantization noise for an elephant but
# ~10% of a 20KB flow, where accepting a near-miss completion transient
# (e.g. recorded under an adjacent sweep variant in a merged multi-variant
# DB) mis-fast-forwards the whole flow
_COMPLETION_RTOL = 0.02


def sim_fingerprint(mtu: float, ecn_k: float, buffer_bytes: float,
                    shared_buffer: float | None = None,
                    sample_interval: float | None = None) -> str:
    """Canonical string for the sim knobs that change transient dynamics or
    their measurement without showing up in the FCG key (CCA/link-speed/RTT
    classes do).  ``sample_interval`` paces the steady-state detector, so the
    stored t_conv / end-rate snapshots are only valid under the cadence they
    were recorded at (its default derives from mtu + line rate, so DBs from
    default-configured sims keep matching across topologies)."""
    shared = "none" if shared_buffer is None else f"{shared_buffer:g}"
    si = "default" if sample_interval is None else f"{sample_interval:g}"
    return (f"mtu={mtu:g};ecn_k={ecn_k:g};buf={buffer_bytes:g};"
            f"shared={shared};si={si}")


@dataclasses.dataclass(slots=True)
class MemoEntry:
    fcg: FCG                       # FCG_start (the key graph)
    end_rates: list[float]         # FCG_end vertex weights, by key-graph vertex
    sizes: list[float]             # bytes transferred during the transient
    t_conv: float                  # measured convergence time (s)
    end_reason: str                # STEADY | COMPLETION
    mean_backlog: float = 0.0      # mean bottleneck-port backlog at exit
    completed: tuple[int, ...] = ()  # key-graph vertices that completed at t_conv
    hits: int = 0

    def nbytes(self) -> int:
        # end_rates and sizes are equal-length float lists; completed is a
        # small int tuple — all three are stored, so all three are counted
        return (self.fcg.nbytes() + 16 * len(self.end_rates)
                + 16 * len(self.sizes) + 8 * len(self.completed) + 32)

    def to_dict(self) -> dict:
        return {
            "fcg": self.fcg.to_dict(),
            "end_rates": list(self.end_rates),
            "sizes": list(self.sizes),
            "t_conv": self.t_conv,
            "end_reason": self.end_reason,
            "mean_backlog": self.mean_backlog,
            "completed": list(self.completed),
            "hits": self.hits,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MemoEntry":
        return cls(
            fcg=FCG.from_dict(d["fcg"]),
            end_rates=[float(r) for r in d["end_rates"]],
            sizes=[float(s) for s in d["sizes"]],
            t_conv=float(d["t_conv"]),
            end_reason=str(d["end_reason"]),
            mean_backlog=float(d.get("mean_backlog", 0.0)),
            completed=tuple(int(v) for v in d.get("completed", ())),
            hits=int(d.get("hits", 0)),
        )


@dataclasses.dataclass(slots=True)
class MemoHit:
    entry: MemoEntry
    mapping: dict[int, int]        # stored vertex -> current vertex


class SimDBMismatch(ValueError):
    """The DB was recorded under a different simulator regime or an
    incompatible on-disk format — refusing to replay it silently."""


class SimDB:
    """Hash-bucketed store with exact weighted-isomorphism verification."""

    def __init__(self, fingerprint: str | None = None) -> None:
        self._buckets: dict[int, list[MemoEntry]] = {}
        self._log: list[MemoEntry] = []    # runtime inserts, in order
        self.fingerprint = fingerprint
        self.inserts = 0
        self.lookups = 0
        self.hits = 0

    # ------------------------------------------------------------------ #
    def insert(self, entry: MemoEntry) -> None:
        self._buckets.setdefault(entry.fcg.key, []).append(entry)
        self._log.append(entry)
        self.inserts += 1

    def _add(self, entry: MemoEntry) -> None:
        """Pre-existing knowledge (load/merge): bucketed but not counted as
        a runtime insert and not part of any delta."""
        self._buckets.setdefault(entry.fcg.key, []).append(entry)

    def lookup(self, fcg: FCG, remaining: list[float],
               atol: float | None = None) -> MemoHit | None:
        """Find an isomorphic stored transient whose per-flow transfer fits
        within the current flows' remaining bytes (otherwise the stored
        transient would run past a completion event and be semantically
        different — fall through to packet simulation).

        ``atol`` is the completion-match tolerance in bytes; pass ~2 MTUs of
        the running simulation (the kernel does) so the guard scales with
        the packet size instead of assuming 1500B frames."""
        if atol is None:
            atol = _DEFAULT_COMPLETION_ATOL
        self.lookups += 1
        for entry in self._buckets.get(fcg.key, ()):  # WL structural filter
            m = isomorphism(entry.fcg, fcg)
            if m is None:
                continue
            if any(entry.sizes[u] > remaining[v] + 1e-6 for u, v in m.items()):
                continue
            if entry.end_reason == COMPLETION:
                # the stored transient *ends with* these vertices completing:
                # replaying it is only semantically equivalent if the mapped
                # flows run out of bytes at the same point (within ~2 packets,
                # and never more than a few % of the flow)
                if any(abs(entry.sizes[u] - remaining[m[u]])
                       > min(atol, max(_COMPLETION_RTOL * remaining[m[u]], 1.0))
                       for u in entry.completed):
                    continue
            entry.hits += 1
            self.hits += 1
            return MemoHit(entry=entry, mapping=m)
        return None

    # ------------------------------------------------------------------ #
    # regime binding
    # ------------------------------------------------------------------ #
    def bind_fingerprint(self, fingerprint: str) -> None:
        """Adopt the simulator-regime fingerprint, or refuse if this DB was
        recorded under a different one (never silently replay across MTU /
        ECN / buffer regimes)."""
        if self.fingerprint is None:
            self.fingerprint = fingerprint
        elif self.fingerprint != fingerprint:
            raise SimDBMismatch(
                f"SimDB was recorded under {self.fingerprint!r} but the "
                f"attaching simulation runs {fingerprint!r}; load/merge a DB "
                f"from the matching regime instead")

    # ------------------------------------------------------------------ #
    # deltas (parallel sweep workers ship newly inserted entries back)
    # ------------------------------------------------------------------ #
    def mark(self) -> int:
        """Position token for ``entries_since`` — take one before a run."""
        return len(self._log)

    def entries_since(self, mark: int) -> list[MemoEntry]:
        return self._log[mark:]

    def entries(self):
        for bucket in self._buckets.values():
            yield from bucket

    # ------------------------------------------------------------------ #
    # persistence + merging
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "fingerprint": self.fingerprint,
            "entries": [e.to_dict() for e in self.entries()],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SimDB":
        version = d.get("format_version")
        if version != FORMAT_VERSION:
            raise SimDBMismatch(
                f"SimDB format_version {version!r} is not the supported "
                f"{FORMAT_VERSION}; re-record the DB with this code version")
        db = cls(fingerprint=d.get("fingerprint"))
        for ed in d.get("entries", ()):
            db._add(MemoEntry.from_dict(ed))
        return db

    def save(self, path: str) -> None:
        """Durable JSON snapshot (atomic rename so readers never see a
        half-written DB)."""
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "SimDB":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))

    @classmethod
    def load_or_new(cls, path: str | None) -> "SimDB":
        """Load ``path`` if it exists, else start a fresh DB — the shared
        open-for-warm-start semantics of campaigns and served stores."""
        if path is not None and os.path.exists(path):
            return cls.load(path)
        return cls()

    def merge(self, other: "SimDB") -> int:
        """Fold ``other``'s entries in, dropping duplicates — entries whose
        key graphs are weighted-isomorphic to an existing entry with matching
        per-flow sizes and t_conv (the same transient memoized twice, e.g.
        by two cold parallel workers).  Returns the number of entries added."""
        if other.fingerprint is not None:
            self.bind_fingerprint(other.fingerprint)
        added = 0
        for entry in other.entries():
            if self._duplicate(entry) is None:
                self._add(entry)
                added += 1
        return added

    @staticmethod
    def _sized_fcg(fcg: FCG, sizes: list[float]) -> FCG:
        """The key graph with per-vertex transient sizes folded into the
        labels, so dedup matching searches over size-respecting mappings
        (a bare isomorphism may return a mapping that misaligns sizes on
        symmetric graphs even when an aligned one exists)."""
        g = FCG(n=fcg.n,
                labels=[l + (round(s),) for l, s in zip(fcg.labels, sizes)],
                edges=dict(fcg.edges), fids=list(fcg.fids))
        g.refresh()
        return g

    def _duplicate(self, entry: MemoEntry) -> MemoEntry | None:
        sized = None
        for cand in self._buckets.get(entry.fcg.key, ()):
            if cand.end_reason != entry.end_reason:
                continue
            if abs(cand.t_conv - entry.t_conv) > 1e-6 * max(cand.t_conv,
                                                            entry.t_conv):
                continue
            if isomorphism(entry.fcg, cand.fcg) is None:
                continue
            if sized is None:
                sized = self._sized_fcg(entry.fcg, entry.sizes)
            if isomorphism(sized, self._sized_fcg(cand.fcg, cand.sizes)) \
                    is not None:
                return cand
        return None

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def nbytes(self) -> int:
        return sum(e.nbytes() for b in self._buckets.values() for e in b) + 48 * len(self._buckets)

    def stats(self) -> dict:
        return {
            "entries": len(self), "bytes": self.nbytes(),
            "lookups": self.lookups, "hits": self.hits, "inserts": self.inserts,
            "hit_rate": self.hits / max(1, self.lookups),
        }
