"""Simulation database (paper §4.3/§4.4): memoization of unsteady-state
transients.

    key:   FCG_start            (canonical WL hash buckets + exact iso check)
    value: (FCG_end rates, {Size_f}, T_conv, end_reason)

Only entry/exit snapshots are stored, never packet traces — flow sizes
determine steady durations but are independent of the transient dynamics
(§4.3), so this is sufficient to reconstruct per-flow FCTs.  The whole DB is
O(100KB) at 1024-GPU scale (Fig 9b) and lives in memory.
"""
from __future__ import annotations

import dataclasses

from repro.core.fcg import FCG, isomorphism

STEADY = "steady"
COMPLETION = "completion"


@dataclasses.dataclass
class MemoEntry:
    fcg: FCG                       # FCG_start (the key graph)
    end_rates: list[float]         # FCG_end vertex weights, by key-graph vertex
    sizes: list[float]             # bytes transferred during the transient
    t_conv: float                  # measured convergence time (s)
    end_reason: str                # STEADY | COMPLETION
    mean_backlog: float = 0.0      # mean bottleneck-port backlog at exit
    completed: tuple[int, ...] = ()  # key-graph vertices that completed at t_conv
    hits: int = 0

    def nbytes(self) -> int:
        return self.fcg.nbytes() + 16 * len(self.end_rates) + 32


@dataclasses.dataclass
class MemoHit:
    entry: MemoEntry
    mapping: dict[int, int]        # stored vertex -> current vertex


class SimDB:
    """Hash-bucketed store with exact weighted-isomorphism verification."""

    def __init__(self) -> None:
        self._buckets: dict[int, list[MemoEntry]] = {}
        self.inserts = 0
        self.lookups = 0
        self.hits = 0

    # ------------------------------------------------------------------ #
    def insert(self, entry: MemoEntry) -> None:
        self._buckets.setdefault(entry.fcg.key, []).append(entry)
        self.inserts += 1

    def lookup(self, fcg: FCG, remaining: list[float]) -> MemoHit | None:
        """Find an isomorphic stored transient whose per-flow transfer fits
        within the current flows' remaining bytes (otherwise the stored
        transient would run past a completion event and be semantically
        different — fall through to packet simulation)."""
        self.lookups += 1
        for entry in self._buckets.get(fcg.key, ()):  # WL structural filter
            m = isomorphism(entry.fcg, fcg)
            if m is None:
                continue
            if any(entry.sizes[u] > remaining[v] + 1e-6 for u, v in m.items()):
                continue
            if entry.end_reason == COMPLETION:
                # the stored transient *ends with* these vertices completing:
                # replaying it is only semantically equivalent if the mapped
                # flows run out of bytes at the same point
                if any(abs(entry.sizes[u] - remaining[m[u]]) > 2e3
                       for u in entry.completed):
                    continue
            entry.hits += 1
            self.hits += 1
            return MemoHit(entry=entry, mapping=m)
        return None

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    def nbytes(self) -> int:
        return sum(e.nbytes() for b in self._buckets.values() for e in b) + 48 * len(self._buckets)

    def stats(self) -> dict:
        return {
            "entries": len(self), "bytes": self.nbytes(),
            "lookups": self.lookups, "hits": self.hits, "inserts": self.inserts,
            "hit_rate": self.hits / max(1, self.lookups),
        }
