"""Core layers: RMSNorm, RoPE, GQA attention (dynamic sliding window, causal
or bidirectional, train and single-token-decode forms), MLA (DeepSeek
latent attention with compressed decode cache), SwiGLU MLP and top-k MoE
with capacity-based dispatch (GSPMD-shardable one-hot einsums)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import P

BIG_WINDOW = 1 << 30


# --------------------------------------------------------------------- #
# norms / rope
# --------------------------------------------------------------------- #
def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S]."""
    if not theta:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention (GQA, dynamic window)
# --------------------------------------------------------------------- #
def attn_specs(cfg, R: int) -> dict:
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "wq": P((R, d, H, hd), ("layers", "embed", "heads", "head")),
        "wk": P((R, d, Hk, hd), ("layers", "embed", "kv", "head")),
        "wv": P((R, d, Hk, hd), ("layers", "embed", "kv", "head")),
        "wo": P((R, H, hd, d), ("layers", "heads", "head", "embed")),
    }


def _sdpa(q, k, v, q_pos, k_pos, window, causal: bool):
    """q: [B,S,Hk,G,hd]; k/v: [B,T,Hk,hd]; window: dynamic scalar (0=full)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    mask = jnp.ones((), jnp.bool_)
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if causal:
        mask = kp <= qp
    w = jnp.where(window > 0, window, BIG_WINDOW)
    mask = mask & (kp > qp - w)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", p, v)


def attention(x, p, cfg, positions, window, causal: bool = True,
              kv_x=None):
    """Full-sequence attention.  kv_x: cross-attention source (whisper)."""
    B, S, d = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    G = H // Hk
    h = rms_norm(x, p["ln"])
    src = rms_norm(kv_x, p["ln"]) if kv_x is not None else h
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"])
    if kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions[0]
    else:
        k_pos = jnp.arange(src.shape[1])
    q = q.reshape(B, S, Hk, G, hd)
    o = _sdpa(q, k, v, positions[0], k_pos, window, causal and kv_x is None)
    o = o.reshape(B, S, H, hd)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_decode(x, p, cfg, cache, pos, window):
    """Single-token decode: x [B,1,d]; cache {'k','v'} [B,T,Hk,hd]."""
    B, _, d = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    G = H // Hk
    h = rms_norm(x, p["ln"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    posv = jnp.full((B, 1), pos)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    T = k.shape[1]
    scale = hd ** -0.5
    qg = q.reshape(B, 1, Hk, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    kp = jnp.arange(T)
    w = jnp.where(window > 0, window, BIG_WINDOW)
    mask = (kp <= pos) & (kp > pos - w)
    logits = jnp.where(mask[None, None, None, None], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", pr, v).reshape(B, 1, H, hd)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": k, "v": v}


# --------------------------------------------------------------------- #
# MLA (DeepSeek-V3) — latent compressed attention
# --------------------------------------------------------------------- #
def mla_specs(cfg, R: int) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    qh = cfg.nope_dim + cfg.rope_dim
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "wq_a": P((R, d, cfg.q_lora), ("layers", "embed", None)),
        "q_ln": P((R, cfg.q_lora), ("layers", None), "ones"),
        "wq_b": P((R, cfg.q_lora, H, qh), ("layers", None, "heads", "head")),
        "wkv_a": P((R, d, cfg.kv_lora + cfg.rope_dim), ("layers", "embed", None)),
        "kv_ln": P((R, cfg.kv_lora), ("layers", None), "ones"),
        "wkv_b": P((R, cfg.kv_lora, H, cfg.nope_dim + cfg.v_head_dim),
                   ("layers", None, "heads", "head")),
        "wo": P((R, H, cfg.v_head_dim, d), ("layers", "heads", "head", "embed")),
    }


def _mla_qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    h = rms_norm(x, p["ln"])
    q = jnp.einsum("bsd,dq->bsq", h, p["wq_a"])
    q = rms_norm(q, p["q_ln"])
    q = jnp.einsum("bsq,qhk->bshk", q, p["wq_b"])
    q_nope, q_rope = q[..., :cfg.nope_dim], q[..., cfg.nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dc->bsc", h, p["wkv_a"])
    c_kv, k_rope = kv[..., :cfg.kv_lora], kv[..., cfg.kv_lora:]
    c_kv = rms_norm(c_kv, p["kv_ln"])
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)  # shared head
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(x, p, cfg, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)
    kv = jnp.einsum("btc,chk->bthk", c_kv, p["wkv_b"])
    k_nope, v = kv[..., :cfg.nope_dim], kv[..., cfg.nope_dim:]
    scale = (cfg.nope_dim + cfg.rope_dim) ** -0.5
    logits = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
              + jnp.einsum("bshk,btok->bhst", q_rope,
                           jnp.broadcast_to(k_rope, (B, S, 1, cfg.rope_dim))))
    logits = logits.astype(jnp.float32) * scale
    qp = positions[0][:, None]
    kp = positions[0][None, :]
    logits = jnp.where((kp <= qp)[None, None], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthk->bshk", pr, v)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mla_decode(x, p, cfg, cache, pos):
    """Decode with the *compressed* cache {c_kv: [B,T,kv_lora],
    k_rope: [B,T,rope_dim]} — MLA's memory win."""
    B = x.shape[0]
    posv = jnp.full((B, 1), pos)
    q_nope, q_rope, c_new, kr_new = _mla_qkv(x, p, cfg, posv)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new[:, :, 0].astype(cache["k_rope"].dtype), (0, pos, 0))
    kv = jnp.einsum("btc,chk->bthk", c_kv, p["wkv_b"])
    k_nope, v = kv[..., :cfg.nope_dim], kv[..., cfg.nope_dim:]
    scale = (cfg.nope_dim + cfg.rope_dim) ** -0.5
    logits = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope))
    logits = logits.astype(jnp.float32) * scale
    kp = jnp.arange(k_nope.shape[1])
    logits = jnp.where((kp <= pos)[None, None, None], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhst,bthk->bshk", pr, v)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


# --------------------------------------------------------------------- #
# FFN: SwiGLU + MoE
# --------------------------------------------------------------------- #
def mlp_specs(cfg, R: int, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    out = {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "wi": P((R, d, f), ("layers", "embed", "mlp")),
        "wo": P((R, f, d), ("layers", "mlp", "embed")),
    }
    if cfg.mlp_kind == "swiglu":
        out["wg"] = P((R, d, f), ("layers", "embed", "mlp"))
    return out


def mlp(x, p):
    h = rms_norm(x, p["ln"])
    up = jnp.einsum("bsd,df->bsf", h, p["wi"])
    if "wg" in p:
        act = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, p["wg"])) * up
    else:
        act = jax.nn.gelu(up)
    return x + jnp.einsum("bsf,fd->bsd", act, p["wo"])


def moe_specs(cfg, R: int) -> dict:
    d, E, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff or cfg.d_ff
    out = {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "router": P((R, d, E), ("layers", "embed", None)),
        "wi": P((R, E, d, f), ("layers", "expert", "embed", "expert_mlp")),
        "wg": P((R, E, d, f), ("layers", "expert", "embed", "expert_mlp")),
        "wo": P((R, E, f, d), ("layers", "expert", "expert_mlp", "embed")),
    }
    if cfg.moe_shared:
        out["shared"] = mlp_specs(cfg, R, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.moe_shared)
    return out


def moe(x, p, cfg):
    if cfg.moe_dispatch == "gather":
        return moe_gather(x, p, cfg)
    return moe_einsum(x, p, cfg)


def moe_gather(x, p, cfg):
    """Top-k MoE with sort-based dispatch: tokens are routed with a gather
    into per-expert buffers and scattered back — zero dispatch FLOPs (the
    einsum variant's [T,E,cap] tensors are O(T·E·cap·d) FLOPs and bytes;
    see EXPERIMENTS.md §Perf iteration 'moe-dispatch')."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    f = cfg.moe_d_ff or cfg.d_ff
    T = B * S
    cap = max(1, int(cfg.capacity_factor * T * k / E))
    h = rms_norm(x, p["ln"]).reshape(T, d)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", h, p["router"]).astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                        # [T, k]
    topv = (topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    e_pair = topi.reshape(T * k)
    tok_pair = jnp.arange(T * k) // k
    gate_pair = topv.reshape(T * k)
    order = jnp.argsort(e_pair)                                  # stable
    e_s = e_pair[order]
    tok_s = tok_pair[order]
    gate_s = gate_pair[order]
    first = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(T * k) - first                              # slot in expert
    keep = pos < cap
    slot = jnp.where(keep, e_s * cap + pos, E * cap)             # dropped -> dummy
    send = h[tok_s]
    if cfg.moe_a2a_dtype:                 # quantised dispatch wire (fp8)
        send = send.astype(getattr(jnp, cfg.moe_a2a_dtype))
    xe = jnp.zeros((E * cap + 1, d), send.dtype).at[slot].set(send)
    xe = xe[:E * cap].reshape(E, cap, d).astype(x.dtype)
    he = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
          * jnp.einsum("ecd,edf->ecf", xe, p["wi"]))
    ye = jnp.einsum("ecf,efd->ecd", he, p["wo"]).reshape(E * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye[slot] * (gate_s * keep)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok_s].add(contrib).reshape(B, S, d)
    if cfg.moe_shared:
        sh = p["shared"]
        hs = rms_norm(x, sh["ln"])
        up = jnp.einsum("bsd,df->bsf", hs, sh["wi"])
        act = jax.nn.silu(jnp.einsum("bsd,df->bsf", hs, sh["wg"])) * up \
            if "wg" in sh else jax.nn.gelu(up)
        y = y + jnp.einsum("bsf,fd->bsd", act, sh["wo"])
    return x + y


def moe_einsum(x, p, cfg):
    """GShard-style dense one-hot dispatch (the §Perf baseline)."""
    B, S, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    f = cfg.moe_d_ff or cfg.d_ff
    T = B * S
    cap = max(1, int(cfg.capacity_factor * T * k / E))
    h = rms_norm(x, p["ln"]).reshape(T, d)
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", h, p["router"]).astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                       # [T, k]
    topv = (topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    onehot = jax.nn.one_hot(topi, E, dtype=x.dtype)            # [T, k, E]
    pos_in_e = (jnp.cumsum(onehot.reshape(T * k, E), axis=0) - 1).reshape(T, k, E)
    pos = jnp.einsum("tke,tke->tk", pos_in_e, onehot)          # slot per (t, k)
    keep = (pos < cap).astype(x.dtype)
    slot = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None]
    # dispatch tensor [T, E, cap]
    disp = jnp.einsum("tke,tkc->tec", onehot, slot)
    xe = jnp.einsum("td,tec->ecd", h, disp)                    # [E, cap, d]
    he = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
          * jnp.einsum("ecd,edf->ecf", xe, p["wi"]))
    ye = jnp.einsum("ecf,efd->ecd", he, p["wo"])               # [E, cap, d]
    comb = jnp.einsum("tke,tkc,tk->tec", onehot, slot, topv)
    y = jnp.einsum("ecd,tec->td", ye, comb).reshape(B, S, d)
    if cfg.moe_shared:
        sh = p["shared"]
        hs = rms_norm(x, sh["ln"])
        y = y + jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.silu(jnp.einsum("bsd,df->bsf", hs, sh["wg"]))
            * jnp.einsum("bsd,df->bsf", hs, sh["wi"]), sh["wo"])
    return x + y
