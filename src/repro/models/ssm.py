"""Recurrent mixers: Mamba (S6 selective scan), mLSTM and sLSTM (xLSTM).

Each mixer has a full-sequence (train/prefill) form and an O(1)-state decode
form — the property that makes the SSM/hybrid architectures eligible for the
long_500k cell.  The Mamba scan is chunked (associative scan within a chunk,
lax.scan across chunks) so the [S, d_inner, d_state] intermediate never
materialises for long sequences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.params import P

DT_RANK_DIV = 16


# --------------------------------------------------------------------- #
# Mamba (S6)
# --------------------------------------------------------------------- #
def mamba_specs(cfg, R: int) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    ds = cfg.d_state
    dtr = max(1, d // DT_RANK_DIV)
    k = cfg.conv_kernel
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "in_proj": P((R, d, 2 * di), ("layers", "embed", "mlp")),
        "conv_w": P((R, di, k), ("layers", "mlp", None)),
        "conv_b": P((R, di), ("layers", "mlp"), "zeros"),
        "x_proj": P((R, di, dtr + 2 * ds), ("layers", "mlp", None)),
        "dt_proj": P((R, dtr, di), ("layers", None, "mlp")),
        "dt_bias": P((R, di), ("layers", "mlp"), "zeros"),
        "a_log": P((R, di, ds), ("layers", "mlp", None), "ones"),
        "d_skip": P((R, di), ("layers", "mlp"), "ones"),
        "out_proj": P((R, di, d), ("layers", "mlp", "embed")),
    }


def _mamba_core(xz, p, cfg, h0=None, conv_state=None):
    """xz: [B, S, 2di] post in_proj.  Returns (y [B,S,di], h_last, conv_last)."""
    B, S, _ = xz.shape
    di = cfg.expand * cfg.d_model
    ds = cfg.d_state
    k = cfg.conv_kernel
    dtr = max(1, cfg.d_model // DT_RANK_DIV)
    x, z = xz[..., :di], xz[..., di:]

    # causal depthwise conv over time
    if conv_state is None:
        conv_state = jnp.zeros((B, k - 1, di), x.dtype)
    xpad = jnp.concatenate([conv_state, x], axis=1)
    conv_last = xpad[:, -(k - 1):] if k > 1 else jnp.zeros((B, 0, di), x.dtype)
    x = sum(xpad[:, i:i + S] * p["conv_w"][:, k - 1 - i] for i in range(k))
    x = jax.nn.silu(x + p["conv_b"])

    proj = jnp.einsum("bsd,dp->bsp", x, p["x_proj"])
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", proj[..., :dtr], p["dt_proj"])
                         + p["dt_bias"])                       # [B,S,di]
    Bc = proj[..., dtr:dtr + ds]                               # [B,S,ds]
    Cc = proj[..., dtr + ds:]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))               # [di,ds]
    dA = jnp.exp(dt[..., None] * A)                            # [B,S,di,ds]
    dBx = (dt * x)[..., None] * Bc[:, :, None, :]              # [B,S,di,ds]

    def chunk_scan(h, block):
        dA_c, dBx_c, C_c = block

        def combine(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])

        acc_A, acc_h = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        hs = acc_A * h[:, None] + acc_h                        # [B,C,di,ds]
        y = jnp.einsum("bcds,bcs->bcd", hs, C_c)
        return hs[:, -1], y

    C = 256 if S % 256 == 0 else (S if S <= 256 else 1)
    if S % C != 0:
        C = 1
    n_chunks = S // C
    if h0 is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    blocks = (dA.reshape(B, n_chunks, C, di, ds).swapaxes(0, 1),
              dBx.reshape(B, n_chunks, C, di, ds).swapaxes(0, 1).astype(jnp.float32),
              Cc.reshape(B, n_chunks, C, ds).swapaxes(0, 1))
    h_last, ys = jax.lax.scan(chunk_scan, h0, blocks)
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    y = y + x * p["d_skip"]
    return y * jax.nn.silu(z), h_last, conv_last


def mamba(x, p, cfg):
    h = rms_norm(x, p["ln"])
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    y, _, _ = _mamba_core(xz, p, cfg)
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def mamba_decode(x, p, cfg, cache, pos):
    """cache: {'h': [B,di,ds] f32, 'conv': [B,k-1,di]}."""
    h = rms_norm(x, p["ln"])
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    y, h_last, conv_last = _mamba_core(xz, p, cfg, h0=cache["h"],
                                       conv_state=cache["conv"])
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"h": h_last, "conv": conv_last.astype(cache["conv"].dtype)}


# --------------------------------------------------------------------- #
# mLSTM (matrix memory, parallel + recurrent forms)
# --------------------------------------------------------------------- #
def mlstm_specs(cfg, R: int) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    H = cfg.n_heads
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "up": P((R, d, 2 * di), ("layers", "embed", "mlp")),
        "wq": P((R, di, di), ("layers", "mlp", None)),
        "wk": P((R, di, di), ("layers", "mlp", None)),
        "wv": P((R, di, di), ("layers", "mlp", None)),
        "w_i": P((R, di, H), ("layers", "mlp", "heads")),
        "w_f": P((R, di, H), ("layers", "mlp", "heads")),
        "gn": P((R, di), ("layers", "mlp"), "ones"),
        "down": P((R, di, d), ("layers", "mlp", "embed")),
    }


def _mlstm_qkv(h, p, cfg):
    B, S, _ = h.shape
    di = cfg.expand * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    up = jnp.einsum("bsd,de->bse", h, p["up"])
    xin, z = up[..., :di], up[..., di:]
    q = jnp.einsum("bse,ef->bsf", xin, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", xin, p["wk"]).reshape(B, S, H, hd) / (hd ** 0.5)
    v = jnp.einsum("bse,ef->bsf", xin, p["wv"]).reshape(B, S, H, hd)
    ig = jnp.einsum("bse,eh->bsh", xin, p["w_i"]).astype(jnp.float32)
    fg = jnp.einsum("bse,eh->bsh", xin, p["w_f"]).astype(jnp.float32)
    return q, k, v, ig, fg, z


def mlstm(x, p, cfg):
    """Parallel (quadratic) form: decay matrix from cumulative log-fgates,
    stabilised by the running max m (xLSTM Eq. 19-27)."""
    B, S, d = x.shape
    di = cfg.expand * d
    H = cfg.n_heads
    hd = di // H
    h = rms_norm(x, p["ln"])
    q, k, v, ig, fg, z = _mlstm_qkv(h, p, cfg)
    logf = jax.nn.log_sigmoid(fg)                              # [B,S,H]
    csum = jnp.cumsum(logf, axis=1)
    # D[s,t] = exp(csum[s]-csum[t]+i[t]) for t<=s
    dmat = csum[:, :, None, :] - csum[:, None, :, :] + ig[:, None, :, :]
    mask = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                   # [B,S,1,H]
    dexp = jnp.exp(dmat - m).astype(x.dtype)                   # [B,S,T,H]
    scores = jnp.einsum("bshd,bthd->bsth", q, k) * dexp
    norm = jnp.maximum(jnp.abs(scores.sum(axis=2)), jnp.exp(-m[:, :, 0]).astype(x.dtype))
    hsa = jnp.einsum("bsth,bthd->bshd", scores, v) / norm[..., None]
    hsa = hsa.reshape(B, S, di) * p["gn"]
    y = hsa * jax.nn.silu(z)
    return x + jnp.einsum("bse,ed->bsd", y, p["down"])


def mlstm_decode(x, p, cfg, cache, pos):
    """Recurrent form: cache {'C': [B,H,hd,hd] f32, 'n': [B,H,hd] f32,
    'm': [B,H] f32} — O(1) in context length."""
    B = x.shape[0]
    di = cfg.expand * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    h = rms_norm(x, p["ln"])
    q, k, v, ig, fg, z = _mlstm_qkv(h, p, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                        # [B,H,hd]
    ig, fg = ig[:, 0], fg[:, 0]                                # [B,H]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + cache["m"], ig)
    fdec = jnp.exp(logf + cache["m"] - m_new)[..., None]
    iexp = jnp.exp(ig - m_new)[..., None]
    C = cache["C"] * fdec[..., None] + iexp[..., None] * (
        k[..., :, None] * v[..., None, :])                     # [B,H,hd,hd]
    n = cache["n"] * fdec + iexp * k
    num = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n)),
                      jnp.exp(-m_new))
    hsa = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype) * p["gn"]
    y = hsa * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", y, p["down"])
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------- #
# sLSTM (scalar memory, strictly recurrent)
# --------------------------------------------------------------------- #
def slstm_specs(cfg, R: int) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    H = cfg.n_heads
    hd = di // H
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "w_in": P((R, d, 4 * di), ("layers", "embed", "mlp")),
        "r": P((R, H, hd, 4 * hd), ("layers", "heads", None, None), scale=0.5),
        "gn": P((R, di), ("layers", "mlp"), "ones"),
        "down": P((R, di, d), ("layers", "mlp", "embed")),
    }


def _slstm_step(p, cfg, carry, gates_t):
    """carry: (c, n, h, m) each [B,H,hd] f32; gates_t: [B,4di] pre-recurrent."""
    B = gates_t.shape[0]
    di = cfg.expand * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    c, n, h, m = carry
    rec = jnp.einsum("bhk,hkg->bhg", h.astype(gates_t.dtype), p["r"])  # [B,H,4hd]
    g = gates_t.reshape(B, 4, H, hd).transpose(0, 2, 1, 3).reshape(B, H, 4 * hd) + rec
    zi, ii, fi, oi = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    zt = jnp.tanh(zi)
    it = ii                                   # log-space input gate
    ft = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(ft + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c2 = f_ * c + i_ * zt
    n2 = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
    h2 = jax.nn.sigmoid(oi) * (c2 / n2)
    return (c2, n2, h2, m_new), h2


def slstm(x, p, cfg):
    B, S, d = x.shape
    di = cfg.expand * d
    H = cfg.n_heads
    hd = di // H
    h = rms_norm(x, p["ln"])
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_in"])            # [B,S,4di]
    carry = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(3)) + (
        jnp.full((B, H, hd), -1e30, jnp.float32),)
    carry, hs = jax.lax.scan(lambda c, g: _slstm_step(p, cfg, c, g),
                             carry, gates.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype) * p["gn"]
    return x + jnp.einsum("bse,ed->bsd", y, p["down"])


def slstm_decode(x, p, cfg, cache, pos):
    """cache: {'c','n','h','m'} each [B,H,hd] f32."""
    h = rms_norm(x, p["ln"])
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_in"])[:, 0]
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    carry, h_out = _slstm_step(p, cfg, carry, gates)
    B = x.shape[0]
    di = cfg.expand * cfg.d_model
    y = h_out.reshape(B, 1, di).astype(x.dtype) * p["gn"]
    out = x + jnp.einsum("bse,ed->bsd", y, p["down"])
    return out, {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
