"""Encoder-decoder (Whisper-style).  The conv1d audio stem is a stub per the
assignment: ``input_specs`` supplies precomputed log-mel frame embeddings at
d_model.  Encoder: bidirectional attention + MLP with learned positions.
Decoder: causal self-attention + cross-attention to encoder states + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.params import P

MAX_POS = 1 << 20


def _xattn_specs(cfg, R):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "ln": P((R, d), ("layers", "embed"), "ones"),
        "wq": P((R, d, H, hd), ("layers", "embed", "heads", "head")),
        "wk": P((R, d, H, hd), ("layers", "embed", "heads", "head")),
        "wv": P((R, d, H, hd), ("layers", "embed", "heads", "head")),
        "wo": P((R, H, hd, d), ("layers", "heads", "head", "embed")),
    }


def encdec_specs(cfg: ArchConfig) -> dict:
    d, V, Le = cfg.d_model, cfg.vocab, cfg.n_layers
    return {
        "embed": P((V, d), ("vocab", "embed")),
        "dec_pos": P((4096, d), (None, "embed"), scale=0.02),
        "enc_pos": P((4096, d), (None, "embed"), scale=0.02),
        "enc": {"attn": L.attn_specs(cfg, Le), "mlp": L.mlp_specs(cfg, Le)},
        "enc_ln": P((d,), ("embed",), "ones"),
        "dec": {"self": L.attn_specs(cfg, cfg.n_layers),
                "cross": _xattn_specs(cfg, cfg.n_layers),
                "mlp": L.mlp_specs(cfg, cfg.n_layers)},
        "final_ln": P((d,), ("embed",), "ones"),
        "unembed": P((d, V), ("embed", "vocab")),
    }


def _pos_add(x, table):
    T = x.shape[1]
    idx = jnp.arange(T) % table.shape[0]
    return x + table[idx]


def encode(cfg: ArchConfig, params, frames):
    """frames: [B, S_enc, d] (stub frontend output)."""
    x = _pos_add(frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
                 params["enc_pos"])
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(h, layer_p):
        h = L.attention(h, layer_p["attn"], cfg, positions, window=0, causal=False)
        h = L.mlp(h, layer_p["mlp"])
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rms_norm(x, params["enc_ln"])


def _decoder(cfg, params, tokens, enc_out):
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    x = _pos_add(x, params["dec_pos"])
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(h, layer_p):
        h = L.attention(h, layer_p["self"], cfg, positions, window=0)
        h = L.attention(h, layer_p["cross"], cfg, positions, window=0,
                        causal=False, kv_x=enc_out)
        h = L.mlp(h, layer_p["mlp"])
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"])
    return L.rms_norm(x, params["final_ln"])


def loss_fn(cfg: ArchConfig, params, batch):
    enc_out = encode(cfg, params, batch["prefix_embeds"])
    h = _decoder(cfg, params, batch["tokens"], enc_out)
    labels = batch["labels"]
    B, S = labels.shape
    C = min(cfg.loss_chunk, S)
    n = S // C
    hc = h[:, :n * C].reshape(B, n, C, -1).swapaxes(0, 1)
    lc = labels[:, :n * C].reshape(B, n, C).swapaxes(0, 1)

    def chunk(tot, xs):
        hh, ll = xs
        logits = jnp.einsum("bcd,dv->bcv", hh, params["unembed"]).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (B * S)


# ------------------------------------------------------------------ #
# decode: self-attn KV cache + precomputed cross KV
# ------------------------------------------------------------------ #
def cache_specs(cfg: ArchConfig, B: int, S: int, S_enc: int, dtype):
    Ld = cfg.n_layers
    H, hd = cfg.n_heads, cfg.hd
    Hk = cfg.n_kv
    return {
        "self": {"k": ((Ld, B, S, Hk, hd), dtype), "v": ((Ld, B, S, Hk, hd), dtype)},
        "cross": {"k": ((Ld, B, S_enc, H, hd), dtype),
                  "v": ((Ld, B, S_enc, H, hd), dtype)},
    }


def cache_axes(cfg: ArchConfig):
    a = ("layers", "act_batch", "cache_seq", "kv", "head")
    ax = ("layers", "act_batch", "cache_seq", "heads", "head")
    return {"self": {"k": a, "v": a}, "cross": {"k": ax, "v": ax}}


def prefill(cfg: ArchConfig, params, batch):
    """Encode the audio, precompute cross-attention KV, and (for the dry-run
    prefill cell) return first-token logits + an empty self cache."""
    enc_out = encode(cfg, params, batch["prefix_embeds"])
    B, S_enc, _ = enc_out.shape

    def cross_kv(carry, layer_p):
        h = L.rms_norm(enc_out, layer_p["cross"]["ln"])
        k = jnp.einsum("btd,dhk->bthk", h, layer_p["cross"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", h, layer_p["cross"]["wv"])
        return carry, (k, v)

    _, (ck, cv) = jax.lax.scan(cross_kv, None, params["dec"])
    h = _decoder(cfg, params, batch["tokens"], enc_out)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], params["unembed"])
    return logits, {"cross": {"k": ck, "v": cv}}


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    x = x + params["dec_pos"][pos % params["dec_pos"].shape[0]]

    def body(h, xs):
        layer_p, sk, sv, ck, cv = xs
        h, nc = L.attention_decode(h, layer_p["self"], cfg, {"k": sk, "v": sv},
                                   pos, window=0)
        # cross attention against the precomputed encoder KV
        hq = L.rms_norm(h, layer_p["cross"]["ln"])
        q = jnp.einsum("bsd,dhk->bshk", hq, layer_p["cross"]["wq"])
        scale = cfg.hd ** -0.5
        lg = jnp.einsum("bshk,bthk->bhst", q, ck).astype(jnp.float32) * scale
        pr = jax.nn.softmax(lg, axis=-1).astype(h.dtype)
        o = jnp.einsum("bhst,bthk->bshk", pr, cv)
        h = h + jnp.einsum("bshk,hkd->bsd", o, layer_p["cross"]["wo"])
        h = L.mlp(h, layer_p["mlp"])
        return h, (nc["k"], nc["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec"], cache["self"]["k"], cache["self"]["v"],
                  cache["cross"]["k"], cache["cross"]["v"]))
    h = L.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["unembed"])[:, 0]
    return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
