"""Parameter specs: every leaf carries a shape, logical axis names, and an
initialiser.  The same tree yields (a) initialised arrays, (b) the logical-
axis tree the sharding rules consume, and (c) ShapeDtypeStructs for
allocation-free dry-runs."""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | eye-ish
    scale: float = 1.0            # stddev multiplier (normal: 1/sqrt(fan_in))

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(spec_tree, key, dtype):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: P, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return treedef.unflatten([mk(s, k) for s, k in zip(leaves, keys)])


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def shape_structs(spec_tree, dtype):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(spec_tree, is_leaf=is_spec))
