"""Generic decoder-only LM covering the dense / moe / ssm / hybrid / vlm
families.  The layer pattern comes from cfg.stages(); parameters are stacked
over each stage's repeat count and the stack is applied with lax.scan
(compact HLO for 60+-layer models), optionally rematerialised.

Entry points:
  loss_fn(params, batch)                      — next-token xent (seq-chunked)
  prefill(params, batch)                      — (last-token logits, cache)
  decode_step(params, cache, tokens, pos)     — one token with cache update
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import (ATTN, ATTN_GLOBAL, ATTN_LOCAL, MAMBA, MLP,
                                MLSTM, MOE, NONE, SLSTM, ArchConfig)
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.params import P
from repro.parallel.act_sharding import constrain


# --------------------------------------------------------------------- #
# specs
# --------------------------------------------------------------------- #
_MIXER_SPECS = {
    ATTN: L.attn_specs, ATTN_LOCAL: L.attn_specs, ATTN_GLOBAL: L.attn_specs,
    MAMBA: S.mamba_specs, MLSTM: S.mlstm_specs, SLSTM: S.slstm_specs,
}


def lm_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    specs: dict = {
        "embed": P((V, d), ("vocab", "embed")),
        "final_ln": P((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P((d, V), ("embed", "vocab"))
    for si, stage in enumerate(cfg.stages()):
        st: dict = {}
        for bi, blk in enumerate(stage.blocks):
            mixer_fn = L.mla_specs if (cfg.mla and blk.mixer == ATTN) \
                else _MIXER_SPECS[blk.mixer]
            b = {"mixer": mixer_fn(cfg, stage.repeat)}
            if blk.ffn == MLP:
                b["ffn"] = L.mlp_specs(cfg, stage.repeat)
            elif blk.ffn == MOE:
                b["ffn"] = L.moe_specs(cfg, stage.repeat)
            st[f"b{bi}"] = b
        specs[f"stage{si}"] = st
    return specs


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def _apply_mixer(kind: str, x, p, cfg: ArchConfig, positions):
    if cfg.mla and kind == ATTN:
        return L.mla_attention(x, p, cfg, positions)
    if kind in (ATTN, ATTN_GLOBAL):
        w = cfg.window if cfg.attn_kind == "swa" else 0
        return L.attention(x, p, cfg, positions, window=w)
    if kind == ATTN_LOCAL:
        return L.attention(x, p, cfg, positions, window=cfg.window)
    if kind == MAMBA:
        return S.mamba(x, p, cfg)
    if kind == MLSTM:
        return S.mlstm(x, p, cfg)
    if kind == SLSTM:
        return S.slstm(x, p, cfg)
    raise ValueError(kind)


def _apply_ffn(kind: str, x, p, cfg: ArchConfig):
    if kind == MLP:
        return L.mlp(x, p)
    if kind == MOE:
        y = L.moe(x, p, cfg)
        if cfg.remat_policy == "save_moe":
            y = checkpoint_name(y, "moe_out")
        return y
    assert kind == NONE
    return x


def forward_hidden(cfg: ArchConfig, params, x, positions):
    for si, stage in enumerate(cfg.stages()):
        sp = params[f"stage{si}"]

        def body(h, layer_p, _stage=stage):
            h = constrain(h)   # sequence-parallel activation checkpoints
            for bi, blk in enumerate(_stage.blocks):
                bp = layer_p[f"b{bi}"]
                h = _apply_mixer(blk.mixer, h, bp["mixer"], cfg, positions)
                if blk.ffn != NONE:
                    h = _apply_ffn(blk.ffn, h, bp["ffn"], cfg)
            return constrain(h), None

        if cfg.remat:
            policy = (jax.checkpoint_policies.save_only_these_names("moe_out")
                      if cfg.remat_policy == "save_moe" else None)
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        x, _ = jax.lax.scan(body, x, sp)
    return L.rms_norm(x, params["final_ln"])


def embed_tokens(cfg, params, tokens):
    return params["embed"][tokens] * (cfg.d_model ** 0.5)


def unembed_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def assemble_input(cfg: ArchConfig, params, batch):
    """tokens (+ optional modality-prefix embeds) -> (x, positions,
    label_offset).  The stub frontend supplies ``prefix_embeds`` directly
    (precomputed patch/frame embeddings, per the assignment)."""
    x = embed_tokens(cfg, params, batch["tokens"])
    offset = 0
    if cfg.frontend and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
        offset = pre.shape[1]
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    return x, positions, offset


def loss_fn(cfg: ArchConfig, params, batch):
    """Next-token cross-entropy, sequence-chunked so the [B,S,V] logits
    tensor never materialises (vocab up to 262k)."""
    x, positions, offset = assemble_input(cfg, params, batch)
    h = forward_hidden(cfg, params, x, positions)
    h = h[:, offset:]
    labels = batch["labels"]
    B, S_lab = labels.shape
    h = h[:, :S_lab]
    C = min(cfg.loss_chunk, S_lab)
    n = S_lab // C
    hc = h[:, :n * C].reshape(B, n, C, -1).swapaxes(0, 1)
    lc = labels[:, :n * C].reshape(B, n, C).swapaxes(0, 1)

    unemb = unembed_matrix(cfg, params)

    def chunk(tot, xs):
        hh, ll = xs
        logits = jnp.einsum("bcd,dv->bcv", hh, unemb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (hc, lc))
    tail = S_lab - n * C
    if tail:
        logits = jnp.einsum("bcd,dv->bcv", h[:, n * C:],
                            unemb).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, n * C:][..., None],
                                   axis=-1)[..., 0]
        tot = tot + jnp.sum(lse - gold)
    return tot / (B * S_lab)


# --------------------------------------------------------------------- #
# decode caches
# --------------------------------------------------------------------- #
def _mixer_cache_spec(kind: str, cfg: ArchConfig, R: int, B: int, S: int,
                      dtype) -> dict:
    H, Hk, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    di = cfg.expand * cfg.d_model
    if cfg.mla and kind == ATTN:
        return {"c_kv": ((R, B, S, cfg.kv_lora), dtype),
                "k_rope": ((R, B, S, cfg.rope_dim), dtype)}
    if kind in (ATTN, ATTN_GLOBAL):
        w = cfg.window if cfg.attn_kind == "swa" else 0
        T = min(S, w) if w else S
        return {"k": ((R, B, T, Hk, hd), dtype), "v": ((R, B, T, Hk, hd), dtype)}
    if kind == ATTN_LOCAL:
        T = min(S, cfg.window)
        return {"k": ((R, B, T, Hk, hd), dtype), "v": ((R, B, T, Hk, hd), dtype)}
    if kind == MAMBA:
        return {"h": ((R, B, di, cfg.d_state), jnp.float32),
                "conv": ((R, B, cfg.conv_kernel - 1, di), dtype)}
    if kind == MLSTM:
        hdm = di // H
        return {"C": ((R, B, H, hdm, hdm), jnp.float32),
                "n": ((R, B, H, hdm), jnp.float32),
                "m": ((R, B, H), jnp.float32)}
    if kind == SLSTM:
        hdm = di // H
        return {k: ((R, B, H, hdm), jnp.float32) for k in ("c", "n", "h", "m")}
    raise ValueError(kind)


def cache_specs(cfg: ArchConfig, B: int, S: int, dtype):
    """Returns pytree of (shape, dtype) tuples mirroring the cache."""
    out = {}
    for si, stage in enumerate(cfg.stages()):
        st = {}
        for bi, blk in enumerate(stage.blocks):
            st[f"b{bi}"] = _mixer_cache_spec(blk.mixer, cfg, stage.repeat, B, S, dtype)
        out[f"stage{si}"] = st
    return out


def cache_axes(cfg: ArchConfig, ring: bool = False):
    """Logical axes for the cache pytree (mirrors cache_specs)."""
    def ax(kind):
        if cfg.mla and kind == ATTN:
            return {"c_kv": ("layers", "act_batch", "cache_seq", None),
                    "k_rope": ("layers", "act_batch", "cache_seq", None)}
        if kind in (ATTN, ATTN_GLOBAL, ATTN_LOCAL):
            a = ("layers", "act_batch", "cache_seq", "kv", "head")
            return {"k": a, "v": a}
        if kind == MAMBA:
            return {"h": ("layers", "act_batch", "mlp", None),
                    "conv": ("layers", "act_batch", None, "mlp")}
        if kind == MLSTM:
            return {"C": ("layers", "act_batch", "heads", None, None),
                    "n": ("layers", "act_batch", "heads", None),
                    "m": ("layers", "act_batch", "heads")}
        if kind == SLSTM:
            return {k: ("layers", "act_batch", "heads", None)
                    for k in ("c", "n", "h", "m")}
        raise ValueError(kind)

    out = {}
    for si, stage in enumerate(cfg.stages()):
        out[f"stage{si}"] = {f"b{bi}": ax(blk.mixer)
                             for bi, blk in enumerate(stage.blocks)}
    return out


def _decode_mixer(kind: str, x, p, cfg, cache, pos):
    if cfg.mla and kind == ATTN:
        return L.mla_decode(x, p, cfg, cache, pos)
    if kind in (ATTN, ATTN_GLOBAL, ATTN_LOCAL):
        w = cfg.window if (kind == ATTN_LOCAL or cfg.attn_kind == "swa") else 0
        T = cache["k"].shape[1]
        if w and T <= w:  # ring buffer over the window
            return _decode_ring(x, p, cfg, cache, pos, w)
        return L.attention_decode(x, p, cfg, cache, pos, window=w)
    if kind == MAMBA:
        return S.mamba_decode(x, p, cfg, cache, pos)
    if kind == MLSTM:
        return S.mlstm_decode(x, p, cfg, cache, pos)
    if kind == SLSTM:
        return S.slstm_decode(x, p, cfg, cache, pos)
    raise ValueError(kind)


def _decode_ring(x, p, cfg, cache, pos, w):
    """Sliding-window decode with a ring-buffer cache: slot j holds the most
    recent token t ≡ j (mod buffer size); validity enforces the window w."""
    B = x.shape[0]
    H, Hk, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    G = H // Hk
    h = L.rms_norm(x, p["ln"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    posv = jnp.full((B, 1), pos)
    q = L.rope(q, posv, cfg.rope_theta)
    k_new = L.rope(k_new, posv, cfg.rope_theta)
    tbuf = cache["k"].shape[1]
    slot = pos % tbuf
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    slots = jnp.arange(tbuf)
    slot_pos = pos - jnp.mod(pos - slots, tbuf)   # absolute token per slot
    valid = (slot_pos >= 0) & (slot_pos > pos - w) & (slot_pos <= pos)
    scale = hd ** -0.5
    qg = q.reshape(B, 1, Hk, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, None], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", pr, v).reshape(B, 1, H, hd)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"]), {"k": k, "v": v}


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """tokens: [B,1]; pos: scalar int (current position).  Returns
    (logits [B,V], new cache)."""
    x = embed_tokens(cfg, params, tokens)
    new_cache = {}
    for si, stage in enumerate(cfg.stages()):
        sp = params[f"stage{si}"]
        cs = cache[f"stage{si}"]

        def body(h, xs, _stage=stage):
            layer_p, layer_c = xs
            new_c = {}
            for bi, blk in enumerate(_stage.blocks):
                h, nc = _decode_mixer(blk.mixer, h, layer_p[f"b{bi}"]["mixer"],
                                      cfg, layer_c[f"b{bi}"], pos)
                if blk.ffn != NONE:
                    h = _apply_ffn(blk.ffn, h, layer_p[f"b{bi}"]["ffn"], cfg)
                new_c[f"b{bi}"] = nc
            return h, new_c

        x, nc = jax.lax.scan(body, x, (sp, cs))
        new_cache[f"stage{si}"] = nc
    h = L.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,dv->bsv", h, unembed_matrix(cfg, params))[:, 0]
    return logits, new_cache


def prefill(cfg: ArchConfig, params, batch):
    """Full-context forward returning (last-token logits, populated cache).
    Implemented as forward_hidden + per-layer cache extraction."""
    x, positions, offset = assemble_input(cfg, params, batch)
    B, T, _ = x.shape
    cache = {}
    for si, stage in enumerate(cfg.stages()):
        sp = params[f"stage{si}"]

        def body(h, layer_p, _stage=stage):
            caches = {}
            for bi, blk in enumerate(_stage.blocks):
                bp = layer_p[f"b{bi}"]
                h, c = _prefill_mixer(blk.mixer, h, bp["mixer"], cfg, positions)
                if blk.ffn != NONE:
                    h = _apply_ffn(blk.ffn, h, bp["ffn"], cfg)
                caches[f"b{bi}"] = c
            return h, caches

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, stage_cache = jax.lax.scan(body, x, sp)
        cache[f"stage{si}"] = stage_cache
    h = L.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bd,dv->bv", h[:, -1], unembed_matrix(cfg, params))
    return logits, cache


def _prefill_mixer(kind: str, x, p, cfg, positions):
    """Apply mixer over the full sequence AND return its decode cache."""
    if cfg.mla and kind == ATTN:
        q_nope, q_rope, c_kv, k_rope = L._mla_qkv(x, p, cfg, positions)
        out = L.mla_attention(x, p, cfg, positions)
        return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0]}
    if kind in (ATTN, ATTN_GLOBAL, ATTN_LOCAL):
        w = cfg.window if (kind == ATTN_LOCAL or cfg.attn_kind == "swa") else 0
        h = L.rms_norm(x, p["ln"])
        k = jnp.einsum("btd,dhk->bthk", h, p["wk"])
        v = jnp.einsum("btd,dhk->bthk", h, p["wv"])
        k = L.rope(k, positions, cfg.rope_theta)
        out = L.attention(x, p, cfg, positions, window=w)
        T = x.shape[1]
        if w and w < T:
            # ring-buffer layout: slot j <- last token with t ≡ j (mod w)
            last = T - w + jnp.mod(jnp.arange(w) - T, w)
            k = k[:, last]
            v = v[:, last]
        return out, {"k": k, "v": v}
    if kind == MAMBA:
        h = L.rms_norm(x, p["ln"])
        xz = jnp.einsum("bsd,de->bse", h, p["in_proj"])
        y, h_last, conv_last = S._mamba_core(xz, p, cfg)
        out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return out, {"h": h_last, "conv": conv_last}
    if kind in (MLSTM, SLSTM):
        # recurrent prefill: run decode steps via scan over time to build
        # exact state (parallel-form state extraction kept simple)
        B, T, _ = x.shape
        fn = S.mlstm_decode if kind == MLSTM else S.slstm_decode
        di = cfg.expand * cfg.d_model
        H = cfg.n_heads
        hdm = di // H
        if kind == MLSTM:
            c0 = {"C": jnp.zeros((B, H, hdm, hdm), jnp.float32),
                  "n": jnp.zeros((B, H, hdm), jnp.float32),
                  "m": jnp.full((B, H), -1e30, jnp.float32)}
        else:
            c0 = {"c": jnp.zeros((B, H, hdm), jnp.float32),
                  "n": jnp.zeros((B, H, hdm), jnp.float32),
                  "h": jnp.zeros((B, H, hdm), jnp.float32),
                  "m": jnp.full((B, H, hdm), -1e30, jnp.float32)}
            c0 = {"c": c0["c"], "n": c0["n"], "h": c0["h"], "m": c0["m"]}

        def step(c, xt):
            y, c2 = fn(xt[:, None], p, cfg, c, 0)
            return c2, y[:, 0]

        cT, ys = jax.lax.scan(step, c0, x.swapaxes(0, 1))
        return ys.swapaxes(0, 1), cT
    raise ValueError(kind)
