"""Composable JAX model zoo: the 10 assigned architectures as config-driven
stacks (scan-over-layers), with train/prefill/decode entry points and
logical-axis sharding annotations consumed by the dry-run."""

from repro.models.api import Model, build_model
