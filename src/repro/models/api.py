"""Unified model facade: ``build_model(cfg)`` returns a Model whose
loss/prefill/decode entry points and input specs drive both the CPU smoke
tests and the multi-pod dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeCell
from repro.models import encdec, lm
from repro.models.params import axes_tree, count_params, init_params, shape_structs


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    def __post_init__(self):
        self.is_encdec = self.cfg.enc_dec
        self.specs = (encdec.encdec_specs if self.is_encdec else lm.lm_specs)(self.cfg)

    # -- params -------------------------------------------------------- #
    def init(self, key) -> Any:
        return init_params(self.specs, key, _dt(self.cfg.param_dtype))

    def param_axes(self):
        return axes_tree(self.specs)

    def param_structs(self):
        return shape_structs(self.specs, _dt(self.cfg.param_dtype))

    @property
    def n_params(self) -> int:
        return count_params(self.specs)

    # -- steps ---------------------------------------------------------- #
    def loss(self, params, batch):
        fn = encdec.loss_fn if self.is_encdec else lm.loss_fn
        return fn(self.cfg, params, batch)

    def prefill(self, params, batch):
        fn = encdec.prefill if self.is_encdec else lm.prefill
        return fn(self.cfg, params, batch)

    def decode_step(self, params, cache, tokens, pos):
        fn = encdec.decode_step if self.is_encdec else lm.decode_step
        return fn(self.cfg, params, cache, tokens, pos)

    # -- caches ---------------------------------------------------------- #
    def cache_specs(self, B: int, S: int):
        dtype = _dt(self.cfg.dtype)
        if self.is_encdec:
            return encdec.cache_specs(self.cfg, B, S, S_enc=min(S, 4096), dtype=dtype)
        return lm.cache_specs(self.cfg, B, S, dtype)

    def cache_axes(self):
        return (encdec.cache_axes if self.is_encdec else lm.cache_axes)(self.cfg)

    def cache_structs(self, B: int, S: int):
        return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(*sd),
                            self.cache_specs(B, S),
                            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                            and isinstance(x[0], tuple))

    def init_cache(self, B: int, S: int):
        return jax.tree.map(lambda sd: jnp.zeros(*sd), self.cache_specs(B, S),
                            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                            and isinstance(x[0], tuple))

    # -- input specs per shape cell -------------------------------------- #
    def input_specs(self, shape: ShapeCell | str) -> dict:
        """ShapeDtypeStruct stand-ins for every input of the cell's step
        function (weak-type correct, shardable, no allocation)."""
        cell = SHAPES[shape] if isinstance(shape, str) else shape
        B, S = cell.global_batch, cell.seq_len
        cfg = self.cfg
        i32 = jnp.int32
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), i32)
        emb = lambda b, s: jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg.dtype))
        if cell.kind == "train":
            if self.is_encdec:
                return {"prefix_embeds": emb(B, S), "tokens": tok(B, S),
                        "labels": tok(B, S)}
            if cfg.frontend == "vision_patches":
                return {"prefix_embeds": emb(B, cfg.n_patches),
                        "tokens": tok(B, S - cfg.n_patches),
                        "labels": tok(B, S - cfg.n_patches)}
            return {"tokens": tok(B, S), "labels": tok(B, S)}
        if cell.kind == "prefill":
            if self.is_encdec:
                return {"prefix_embeds": emb(B, S), "tokens": tok(B, min(S, 448))}
            if cfg.frontend == "vision_patches":
                return {"prefix_embeds": emb(B, cfg.n_patches),
                        "tokens": tok(B, S - cfg.n_patches)}
            return {"tokens": tok(B, S)}
        assert cell.kind == "decode"
        return {"cache": self.cache_structs(B, S),
                "tokens": tok(B, 1),
                "pos": jax.ShapeDtypeStruct((), i32)}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
