"""The ``@hot_path`` marker.

A function carrying this decorator is on the per-packet/per-event path: the
``reprolint`` H-rules forbid logging, ``itertools.count``, closure/lambda
allocation, and attribute writes to un-slotted instances inside it (see
README "Static analysis gates").  The decorator itself is a zero-cost
identity — it exists so the performance contract is visible at the
definition and machine-checkable in CI, not buried in a PR description.
"""
from __future__ import annotations

from typing import TypeVar

F = TypeVar("F")


def hot_path(fn: F) -> F:
    """Mark ``fn`` as hot-path code.  Identity at runtime; reprolint keys
    its H-rules off the decorator name."""
    return fn
