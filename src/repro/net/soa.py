"""Struct-of-arrays hot-path state shared by every fast lane.

Two small structures carry the m4-style array-native substrate the
packet/sharded/hybrid/analytic layers now share:

* :class:`FlowTable` — per-flow *static* routing data in CSR form (one
  int64 port-id row per flow).  Every max-min solve — the hybrid
  demotion lane, the analytic backend's event loop, the learned feature
  extractor — concatenates the relevant rows and calls the vectorized
  solver (``repro.kernels.maxmin``) directly, instead of rebuilding a
  ``{fid: [ports]}`` dict per solve.  Row order is preserved exactly as
  the caller iterates fids: link first-appearance order seeds the
  solver's tie-breaks, which is part of the bit-identity contract with
  the historical dict solver.

* :class:`LaneState` — one partition's event lane (binary heap + lane-
  local seq counter) with *batched run draining*: :meth:`LaneState.pop_run`
  pops the maximal run of same-timestamp events at the heap top in one
  call, so the lane executors process a whole burst (a collective's
  same-instant SEND wave, an ACK-triggered send at the ACK's own
  timestamp) per guard check instead of re-validating the window bounds
  event by event — the event-loop analogue of how ``steady_scan``
  replaced the scalar steady detector.  Within a run the serial
  ``(t, seq)`` order is preserved verbatim, which is what keeps the
  sharded/hybrid loops bit-identical to the seed serial loop.

Per-flow *dynamic* state stays on :class:`~repro.net.packet_sim.FlowRT`
(now ``slots=True``): CCA state machines are inherently scalar per-ACK
recursions, so vectorizing them would change the simulated events —
the hard invariant this refactor must not touch.
"""
from __future__ import annotations

import heapq
from collections.abc import Iterable, Mapping

import numpy as np

from repro.hotpath import hot_path
from repro.kernels.maxmin.ops import maxmin_rates_arrays


class FlowTable:
    """CSR flow→path table: the struct-of-arrays face of the solver.

    ``add`` is called once per flow at admission; ``solve_rates`` is the
    hot entry — called per hybrid demotion/re-solve and per analytic
    event — and is bit-identical to
    ``maxmin_rates({fid: path for fid in fids}, link_bw)``.
    """

    __slots__ = ("_paths",)

    def __init__(self) -> None:
        self._paths: dict[int, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self._paths)

    def __contains__(self, fid: int) -> bool:
        return fid in self._paths

    def add(self, fid: int, path) -> None:
        self._paths[fid] = np.asarray(path, dtype=np.int64)

    def path_links(self, fid: int) -> np.ndarray:
        return self._paths[fid]

    @hot_path
    def csr(self, fids: Iterable[int]) -> tuple[list[int], np.ndarray, np.ndarray]:
        """(fids, path_links, path_off) over ``fids`` in iteration order."""
        fids = list(fids)
        paths = self._paths
        off = np.zeros(len(fids) + 1, dtype=np.int64)
        chunks = []
        n = 0
        for i, fid in enumerate(fids):
            p = paths[fid]
            n += len(p)
            off[i + 1] = n
            if len(p):
                chunks.append(p)
        links = (np.concatenate(chunks) if chunks
                 else np.zeros(0, dtype=np.int64))
        return fids, links, off

    @hot_path
    def solve_rates(self, fids: Iterable[int], link_bw) -> dict[int, float]:
        """Max-min fair rates for ``fids`` (iteration order preserved —
        it seeds the solver's link tie-breaks) over ``link_bw``."""
        fids, links, off = self.csr(fids)
        rates = maxmin_rates_arrays(links, off, link_bw)
        return dict(zip(fids, rates.tolist()))

    def verify_against(self, flows: Mapping[int, object]) -> None:
        """Parity guard for property tests: every registered row must
        mirror its flow object's ``path`` exactly."""
        for fid, row in self._paths.items():
            f = flows.get(fid)
            if f is None:
                continue
            assert list(row) == list(f.path), \
                f"FlowTable row for flow {fid} diverged from FlowRT.path"


class LaneState:
    """One partition's event stream: a local heap + lane-local seq counter.
    Seqs only break same-timestamp ties *within* the lane; cross-lane
    ordering is irrelevant because partitions share no ports."""

    __slots__ = ("pid", "heap", "seq")

    def __init__(self, pid: int) -> None:
        self.pid = pid
        self.heap: list = []
        self.seq = 0

    @hot_path
    def push(self, t: float, kind: int, payload: tuple) -> None:
        self.seq += 1
        heapq.heappush(self.heap, (t, self.seq, kind, payload))

    @hot_path
    def pop_run(self, max_seq: int | None = None) -> list:
        """Pop the maximal same-timestamp run at the heap top, in (t, seq)
        order.  The caller has already admitted the top event against its
        window bounds; every same-``t`` follow-up passes the same ``t``
        checks by construction, so the whole run drains under one guard.
        ``max_seq`` carries the serial loop's shrunk-barrier watermark:
        events at the barrier timestamp scheduled *after* the shrink
        (seq > watermark) must rest in the lane."""
        heap = self.heap
        ev = heapq.heappop(heap)
        run = [ev]
        t0 = ev[0]
        if max_seq is None:
            while heap and heap[0][0] == t0:
                run.append(heapq.heappop(heap))
        else:
            while heap and heap[0][0] == t0 and heap[0][1] <= max_seq:
                run.append(heapq.heappop(heap))
        return run
