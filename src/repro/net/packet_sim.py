"""Packet-level discrete-event simulation oracle (the "ns-3 stand-in").

Faithful per-packet, per-hop event processing with FIFO ports, ECN marking at
threshold K, buffer drops, per-ACK CCA state machines and INT telemetry for
HPCC.  The event loop exposes a *kernel* plug-in interface — a no-op kernel
gives baseline ns-3 behavior, Wormhole (repro.core.wormhole) layers
partitioning + memoization + fast-forwarding on top **without the workload
noticing** ("user-transparent", §1).

Mechanism hooks mirroring the paper's implementation (§6):
  * ``park_flows`` / ``unpark_flows``: packet pausing + per-partition
    timestamp offsetting.  A parked flow's pending events are stashed when
    they pop and re-injected at +ΔT on unpark (with their RTT-measurement
    timestamps shifted too); in-flight packets therefore resume seamlessly —
    no restart burst.  Port ``busy_until`` is shifted by the same ΔT so
    buffer occupancy is held constant across the skip (§6.2).  The global
    clock is never touched, only partition-local timestamps (§6.3).
  * the paper's "size and sequence number must be modified accordingly"
    (§6.3) is the analytic advance in ``_materialize``: ``delivered`` and
    ``sent`` both slide forward by R̂·Δt (capped so the frozen in-flight
    window keeps representing the newest unacked bytes).
  * skip-back (§6.3) is lazy: a parked partition's state is an analytic
    function of time, so an earlier-than-expected interrupt simply
    materializes state at its own timestamp — exact by construction.
"""
from __future__ import annotations

import gc
import heapq
from collections import deque
from dataclasses import dataclass, field
from collections.abc import Callable

from repro.hotpath import hot_path
from repro.net.cca import CCA, MTU, INTInfo, make_cca
from repro.net.flows import FlowResult, FlowSpec
from repro.net.soa import FlowTable
from repro.net.topology import Topology

# event kinds
START, SEND, ARRIVE, ACK, LOSS, SAMPLE, KERNEL, CALL = range(8)


class SimKernel:
    """No-op kernel == plain packet-level DES (the ns-3 baseline)."""

    def attach(self, sim: PacketSim) -> None:
        self.sim = sim

    def on_flow_start(self, flow: FlowRT) -> None: ...

    def on_flows_start(self, flows: list[FlowRT]) -> None:
        # flows launched at the same instant (one collective) are announced
        # together so a kernel can treat them as one partition event
        for f in flows:
            self.on_flow_start(f)

    def on_flow_finish(self, flow: FlowRT, now: float) -> None: ...
    def on_sample(self, now: float) -> None: ...
    def on_kernel_event(self, now: float, payload) -> None: ...

    def on_chaos(self, now: float, ports) -> None:
        # a chaos injector retargeted these ports' capacities
        # (repro.net.chaos); adaptive kernels re-measure affected partitions
        ...


@dataclass(slots=True)
class FlowRT:
    spec: FlowSpec
    path: list[int]                      # port ids src->dst
    ports: frozenset[int]
    cca: CCA
    ack_delay: float                     # reverse-path propagation
    started: bool = False
    done: bool = False
    start_actual: float = 0.0
    finish_t: float = 0.0
    sent_new: float = 0.0                # unique bytes handed to the wire
    delivered: float = 0.0               # bytes that reached the receiver
    inflight: float = 0.0
    retx: float = 0.0                    # bytes queued for retransmission
    blocked: bool = False
    send_scheduled: bool = False
    last_ack_t: float = 0.0
    # Wormhole bookkeeping ------------------------------------------------
    parked: bool = False
    epoch: int = 0
    void_before: int = 0                 # events from epochs < this are dead
    cum_shift: float = 0.0               # total timestamp offset applied
    shift_at_epoch: dict[int, float] = field(default_factory=dict)
    paused_events: list = field(default_factory=list)
    vrate: float = 0.0                   # analytic steady rate while parked
    park_t: float = 0.0                  # when analytic advance started
    # monitoring -----------------------------------------------------------
    rate_hist: deque = field(default_factory=deque)
    last_sample_delivered: float = 0.0
    last_sample_t: float = 0.0
    int_prev: dict = field(default_factory=dict)  # HPCC per-hop (txBytes, ts)
    rtt_samples: list = field(default_factory=list)  # (t, rtt) if recorded

    @property
    def fid(self) -> int:
        return self.spec.fid

    def remaining(self) -> float:
        return max(0.0, self.spec.size - self.delivered)


class PacketSim:
    # hot class (reprolint H205/C304): every per-event attribute store is a
    # slot write, never an instance-__dict__ store
    __slots__ = (
        "topo", "mtu", "ecn_k", "buffer_bytes", "window", "shared_buffer",
        "busy_until", "port_txbytes", "_link_bw", "_link_delay", "_link_src",
        "flow_table", "now", "events_processed", "packet_hop_events",
        "timeouts", "flows", "results", "_heap", "_seq",
        "sample_interval_explicit", "sample_interval", "kernel",
        "finish_listeners", "_sample_pending", "time_limit",
        "record_rtt_fids",
    )

    def __init__(
        self,
        topo: Topology,
        kernel: SimKernel | None = None,
        mtu: float = MTU,
        ecn_k: float = 64_000.0,          # bytes
        buffer_bytes: float = 512_000.0,  # per-port
        sample_interval: float | None = None,
        window: int = 16,                 # rate-history length l
        shared_buffer: float | None = None,  # per-switch shared pool (optional)
    ) -> None:
        self.topo = topo
        self.mtu = mtu
        self.ecn_k = ecn_k
        self.buffer_bytes = buffer_bytes
        self.window = window
        self.shared_buffer = shared_buffer
        # struct-of-arrays port state, plain Python lists: the hot handlers
        # index these per packet hop, and a list read returns a float where
        # an ndarray read allocates a fresh np scalar (same IEEE doubles —
        # results stay bit-identical, the allocation and boxing go away)
        self.busy_until = [0.0] * topo.n_links
        self.port_txbytes = [0.0] * topo.n_links   # INT counters
        self._link_bw = [float(v) for v in topo.link_bw]
        self._link_delay = [float(v) for v in topo.link_delay]
        self._link_src = [int(v) for v in topo.link_src]
        self.flow_table = FlowTable()
        self.now = 0.0
        self.events_processed = 0
        self.packet_hop_events = 0
        self.timeouts = 0
        self.flows: dict[int, FlowRT] = {}
        self.results: dict[int, FlowResult] = {}
        self._heap: list = []
        # plain-int tie-break counter (next value to use); an itertools
        # counter costs a C call per event on the hottest line in the sim
        self._seq = 0
        min_bw = float(topo.link_bw.min())
        # remembered for the SimDB regime fingerprint: an explicit override
        # changes the steady-detector cadence, the derived default does not
        self.sample_interval_explicit = sample_interval is not None
        self.sample_interval = sample_interval if sample_interval is not None else max(
            8e-6, 24 * mtu / min_bw)
        self.kernel = kernel or SimKernel()
        self.kernel.attach(self)   # reads the sim knobs above
        self.finish_listeners: list[Callable[[FlowRT, float], None]] = []
        self._sample_pending = False
        self.time_limit = float("inf")
        self.record_rtt_fids: set[int] = set()

    # ------------------------------------------------------------------ #
    # scheduling
    # ------------------------------------------------------------------ #
    def schedule(self, t: float, kind: int, *payload) -> None:
        s = self._seq
        self._seq = s + 1
        heapq.heappush(self._heap, (max(t, self.now), s, kind, payload))

    def call_at(self, t: float, fn) -> None:
        """Run ``fn(now)`` at simulated time t (workload-driver timers —
        compute barriers between communication phases)."""
        self.schedule(t, CALL, fn)

    def add_flow(self, spec: FlowSpec) -> FlowRT:
        path = self.topo.route(spec.src, spec.dst, spec.fid)
        if not path:
            raise ValueError(f"flow {spec.fid}: src==dst ({spec.src})")
        bw = float(self.topo.link_bw[path].min())
        prop = float(self.topo.link_delay[path].sum())
        base_rtt = 2 * prop + (len(path) + 1) * self.mtu / bw
        f = FlowRT(
            spec=spec, path=path, ports=frozenset(path),
            cca=make_cca(spec.cca, bw, base_rtt), ack_delay=prop,
        )
        self.flows[spec.fid] = f
        self.flow_table.add(spec.fid, path)
        self.schedule(max(spec.start, self.now), START, spec.fid)
        return f

    # ------------------------------------------------------------------ #
    # Wormhole mechanism hooks (packet pausing + timestamp offsetting)
    # ------------------------------------------------------------------ #
    @hot_path
    def park_flows(self, fids, now: float, vrates: dict[int, float]) -> None:
        """Freeze the partition's flows: pending events stash as they pop,
        in-flight packets stay frozen in the queues, state advances
        analytically at the steady rate (packet pausing, §6.2)."""
        for fid in fids:
            f = self.flows[fid]
            if f.done:
                continue
            f.shift_at_epoch[f.epoch] = f.cum_shift
            f.epoch += 1            # events from before the park become stale
            f.parked = True
            f.vrate = max(vrates.get(fid, f.cca.rate()), 1e-3)
            f.park_t = now

    @hot_path
    def update_parked_rates(self, fids, now: float, vrates: dict[int, float]) -> None:
        """Retarget the analytic rates of already-parked flows (memo replay →
        steady transition without an intermediate unpark)."""
        for fid in fids:
            f = self.flows[fid]
            if f.done or not f.parked:
                continue
            self._materialize(f, now)
            f.vrate = max(vrates.get(fid, f.vrate), 1e-3)
            f.park_t = now

    @hot_path
    def unpark_flows(self, fids, ports, now: float, shift: float) -> None:
        """End a steady period: advance analytic state to ``now``, re-inject
        the stashed events at +ΔT (with RTT timestamps equally shifted) and
        shift the frozen port backlogs (timestamp offsetting, §6.3)."""
        for fid in fids:
            f = self.flows[fid]
            if f.done:
                continue
            self._materialize(f, now)
            f.parked = False
            f.cum_shift += shift
            f.int_prev = {p: (txb, ts + shift, q) for p, (txb, ts, q) in f.int_prev.items()}
            f.last_ack_t = now
            f.last_sample_t = now
            f.last_sample_delivered = f.delivered
            f.send_scheduled = False
            for (t, kind, payload) in f.paused_events:
                self.schedule(t + shift, kind, *self._shift_payload(kind, payload, shift, f.epoch))
                if kind == SEND:
                    f.send_scheduled = True
            f.paused_events.clear()
            if (not f.done and not f.send_scheduled and f.inflight <= 0
                    and f.remaining() > 0):
                f.send_scheduled = True
                self.schedule(now, SEND, fid, f.epoch)
        for p in ports:
            if self.busy_until[p] > now - shift:
                # preserve the frozen backlog: whatever was queued at park
                # time is still queued now (packet pausing, §6.2)
                self.busy_until[p] += shift
        self._ensure_sampler(now)

    @staticmethod
    def _shift_int(int_vec, shift: float):
        if not int_vec:
            return int_vec
        return tuple((p, txb, ts + shift, q) for (p, txb, ts, q) in int_vec)

    @classmethod
    def _shift_payload(cls, kind: int, payload: tuple, shift: float, epoch: int) -> tuple:
        if kind == ARRIVE:   # (fid, hop, pkt, t_sent, ecn, int_vec, epoch)
            fid, hop, pkt, t_sent, ecn, iv, _ = payload
            return (fid, hop, pkt, t_sent + shift, ecn, cls._shift_int(iv, shift), epoch)
        if kind == ACK:      # (fid, pkt, t_sent, ecn, int_vec, epoch)
            fid, pkt, t_sent, ecn, iv, _ = payload
            return (fid, pkt, t_sent + shift, ecn, cls._shift_int(iv, shift), epoch)
        if kind == LOSS:     # (fid, pkt, epoch)
            fid, pkt, _ = payload
            return (fid, pkt, epoch)
        if kind == SEND:     # (fid, epoch)
            return (payload[0], epoch)
        return payload

    @hot_path
    def _materialize(self, f: FlowRT, t: float) -> None:
        """Lazy analytic state at time t for a parked flow.  ``delivered``
        and ``sent`` slide forward together (the paper's sequence-number
        modification, §6.3): the frozen in-flight window keeps representing
        the newest unacked bytes, so nothing is double-counted when the
        stashed packets resume.  If the analytic advance reaches the end of
        the flow, the frozen pipeline *is* the tail — it is absorbed into
        the analytic stream and the flow completes at the exact time the
        delivery front hits the last byte (re-serializing the in-flight
        window after unpark would cost a spurious extra RTT)."""
        if not f.parked or f.done:
            return
        budget = f.vrate * max(0.0, t - f.park_t)
        size = f.spec.size
        if f.delivered + budget >= size - 1e-6:
            t_fin = t - max(0.0, f.delivered + budget - size) / f.vrate
            f.sent_new = size
            f.inflight = 0.0
            f.retx = 0.0
            f.paused_events.clear()
            f.park_t = t
            self.finish_flow(f, max(t_fin, 0.0))
            return
        adv = min(budget, max(0.0, size - f.sent_new))
        f.delivered += adv
        f.sent_new += adv
        f.park_t = t

    def virtual_completion(self, f: FlowRT) -> float:
        """Absolute time the parked flow completes at its steady rate."""
        return f.park_t + f.remaining() / max(f.vrate, 1e-3)

    def finish_flow(self, f: FlowRT, t: float) -> None:
        f.done = True
        f.finish_t = t
        f.delivered = f.spec.size
        self.results[f.fid] = FlowResult(
            fid=f.fid, start=f.start_actual, fct=t - f.start_actual,
            bytes=f.spec.size, tag=f.spec.tag)
        self.kernel.on_flow_finish(f, t)
        for cb in self.finish_listeners:
            cb(f, t)

    # ------------------------------------------------------------------ #
    # main loop
    # ------------------------------------------------------------------ #
    @hot_path
    def run(self, until: float = float("inf")) -> None:
        """Serial event loop, specialized for the hot path.

        The packet kinds (ARRIVE — the per-hop walk, ~2/3 of all events —
        plus SEND and ACK) are inlined below with direct heap pushes and
        hoisted locals; the authoritative copies stay in :meth:`_do_arrive`
        / :meth:`_do_send` / :meth:`_do_ack` for the sharded lane
        executors, and a subclass that overrides scheduling or any packet
        handler gets :meth:`_run_generic` instead.  Both loops pop, count
        and order events identically — bit-identical event streams, which
        tests/test_maxmin.py and the CI counter gate pin.

        ``events_processed`` / ``packet_hop_events`` / ``_seq`` accumulate
        in locals and flush to the instance before every call-out (flow
        completion, kernel hooks, driver callbacks — anything that may
        observe a count or schedule an event) and on exit; ``seq`` reloads
        after each call-out since callees schedule through it.  The cyclic
        GC is paused for the duration of the loop: the millions of
        short-lived event tuples otherwise trigger a gen-0 collection every
        ~700 allocations, and none of them can form cycles.
        """
        cls = type(self)
        if (cls.schedule is not PacketSim.schedule
                or cls._do_arrive is not PacketSim._do_arrive
                or cls._do_send is not PacketSim._do_send
                or cls._do_ack is not PacketSim._do_ack):
            return self._run_generic(until)
        self.time_limit = until
        heap = self._heap
        heappop = heapq.heappop
        heappush = heapq.heappush
        flows = self.flows
        link_bw = self._link_bw
        link_delay = self._link_delay
        busy_until = self.busy_until
        port_txbytes = self.port_txbytes
        ecn_k = self.ecn_k
        mtu = self.mtu
        cca_mtu = MTU  # the CCA rate/cwnd floor (≠ self.mtu in principle)
        buffer_bytes = self.buffer_bytes
        shared = self.shared_buffer
        record_rtt = self.record_rtt_fids
        nev = self.events_processed
        nhop = self.packet_hop_events
        seq = self._seq
        gc_was_on = gc.isenabled()
        if gc_was_on:
            gc.disable()
        try:
            while heap:
                t, s, kind, payload = heappop(heap)
                if t > until:
                    # reinsert the same (t, seq, ...) tuple — identical seq,
                    # so a resumed run pops the exact order an uninterrupted
                    # one would (a fresh seq would reorder same-time ties)
                    heappush(heap, (t, s, kind, payload))
                    break
                self.now = t
                nev += 1
                if kind == ARRIVE:
                    fid, hop, pkt, t_sent, ecn, int_vec, epoch = payload
                    f = flows[fid]
                    if epoch != f.epoch:
                        self._seq = seq
                        stale = self._stale(f, epoch, t, ARRIVE, payload)
                        seq = self._seq
                        if stale:
                            continue
                    if f.done:
                        continue
                    nhop += 1
                    path = f.path
                    if hop >= len(path):  # delivered: turn around an ACK
                        heappush(heap, (t + f.ack_delay, seq, ACK,
                                        (fid, pkt, t_sent, ecn, int_vec,
                                         f.epoch)))
                        seq += 1
                        continue
                    port = path[hop]
                    bw = link_bw[port]
                    busy = busy_until[port]
                    depart = busy if busy > t else t
                    backlog = (depart - t) * bw
                    cap = (buffer_bytes if shared is None
                           else self._buffer_cap(port))
                    if backlog + pkt > cap:
                        # drop: sender learns after ~RTT
                        heappush(heap, (t + f.cca.srtt, seq, LOSS,
                                        (fid, pkt, f.epoch)))
                        seq += 1
                        continue
                    if backlog > ecn_k:
                        ecn = True
                    tx_end = depart + pkt / bw
                    busy_until[port] = tx_end
                    txb = port_txbytes[port] + pkt
                    port_txbytes[port] = txb
                    if int_vec is not None:
                        int_vec = int_vec + ((port, txb, tx_end, backlog),)
                    heappush(heap, (tx_end + link_delay[port], seq, ARRIVE,
                                    (fid, hop + 1, pkt, t_sent, ecn, int_vec,
                                     f.epoch)))
                    seq += 1
                elif kind == SEND:
                    fid, epoch = payload
                    f = flows[fid]
                    if epoch != f.epoch:
                        self._seq = seq
                        stale = self._stale(f, epoch, t, SEND, payload)
                        seq = self._seq
                        if stale:
                            continue
                    f.send_scheduled = False
                    if f.done or f.parked or not f.started:
                        continue
                    retx = f.retx
                    if retx > 0:
                        want = retx
                    else:
                        want = f.spec.size - f.sent_new
                        if mtu <= want:
                            want = mtu
                    if want <= 0:
                        continue
                    cca = f.cca
                    inflight = f.inflight
                    if inflight > 0:
                        # cwnd() inlined: the base-class accessor (w floored
                        # at one MTU); no registry CCA overrides it
                        w = cca.w
                        if inflight + mtu > (w if w >= cca_mtu else cca_mtu):
                            f.blocked = True
                            continue
                    pkt = mtu if mtu <= want else want
                    if retx > 0:
                        f.retx = retx - pkt
                    else:
                        f.sent_new += pkt
                    f.inflight = inflight + pkt
                    int_vec = () if cca.uses_int else None
                    heappush(heap, (t, seq, ARRIVE,
                                    (fid, 0, pkt, t, False, int_vec,
                                     f.epoch)))
                    seq += 1
                    if f.sent_new < f.spec.size or f.retx > 0:
                        f.send_scheduled = True
                        r = cca.r  # rate() inlined, same one-MTU floor
                        heappush(heap, (t + pkt / (r if r >= cca_mtu
                                                   else cca_mtu), seq, SEND,
                                        (fid, f.epoch)))
                        seq += 1
                elif kind == ACK:
                    fid, pkt, t_sent, ecn, int_vec, epoch = payload
                    f = flows[fid]
                    if epoch != f.epoch:
                        self._seq = seq
                        stale = self._stale(f, epoch, t, ACK, payload)
                        seq = self._seq
                        if stale:
                            continue
                    if f.done:
                        continue
                    inflight = f.inflight - pkt
                    f.inflight = inflight if inflight > 0.0 else 0.0
                    f.delivered += pkt
                    f.last_ack_t = t
                    rtt = t - t_sent
                    if record_rtt and fid in record_rtt:
                        f.rtt_samples.append((t, rtt))
                    cca = f.cca
                    info = None
                    if int_vec is not None:
                        # sender-side HPCC telemetry (see _do_ack)
                        int_prev = f.int_prev
                        base_rtt = cca.base_rtt
                        u_max = 0.0
                        for (port, txb, ts, qlen) in int_vec:
                            bw = link_bw[port]
                            prev = int_prev.get(port)
                            if prev is not None and ts > prev[1] + 1e-12:
                                pq = prev[2]
                                u = ((qlen if qlen <= pq else pq)
                                     / (bw * base_rtt)
                                     + (txb - prev[0])
                                     / ((ts - prev[1]) * bw))
                            else:
                                u = 0.95 + qlen / (bw * base_rtt)
                            int_prev[port] = (txb, ts, qlen)
                            if u > u_max:
                                u_max = u
                        info = INTInfo(u_max)
                    cca.on_ack(t, pkt, ecn, rtt, info)
                    if f.delivered >= f.spec.size:
                        self.events_processed = nev
                        self.packet_hop_events = nhop
                        self._seq = seq
                        self.finish_flow(f, t)
                        seq = self._seq
                        continue
                    if (f.blocked or not f.send_scheduled) and (
                            f.sent_new < f.spec.size or f.retx > 0):
                        f.blocked = False
                        f.send_scheduled = True
                        heappush(heap, (t, seq, SEND, (fid, f.epoch)))
                        seq += 1
                elif kind == START:
                    batch = [payload[0]]
                    while heap and heap[0][0] == t and heap[0][2] == START:
                        _, _, _, pl = heappop(heap)
                        nev += 1
                        batch.append(pl[0])
                    self.events_processed = nev
                    self.packet_hop_events = nhop
                    self._seq = seq
                    self._do_start_batch(t, batch)
                    seq = self._seq
                elif kind == LOSS:
                    self.events_processed = nev
                    self.packet_hop_events = nhop
                    self._seq = seq
                    self._do_loss(t, *payload)
                    seq = self._seq
                elif kind == SAMPLE:
                    self.events_processed = nev
                    self.packet_hop_events = nhop
                    self._seq = seq
                    self._do_sample(t)
                    seq = self._seq
                elif kind == KERNEL:
                    self.events_processed = nev
                    self.packet_hop_events = nhop
                    self._seq = seq
                    self.kernel.on_kernel_event(t, payload[0])
                    seq = self._seq
                elif kind == CALL:
                    self.events_processed = nev
                    self.packet_hop_events = nhop
                    self._seq = seq
                    payload[0](t)
                    seq = self._seq
        finally:
            self.events_processed = nev
            self.packet_hop_events = nhop
            # on an exceptional exit mid-call-out the instance counter may
            # already be ahead of the local — never roll it back
            if seq > self._seq:
                self._seq = seq
            if gc_was_on:
                gc.enable()

    def _run_generic(self, until: float = float("inf")) -> None:
        self.time_limit = until
        heap = self._heap
        while heap:
            if heap[0][0] > until:
                break
            t, _, kind, payload = heapq.heappop(heap)
            self.now = t
            self.events_processed += 1
            if kind == ARRIVE:
                self._do_arrive(t, *payload)
            elif kind == START:
                batch = [payload[0]]
                while heap and heap[0][0] == t and heap[0][2] == START:
                    _, _, _, pl = heapq.heappop(heap)
                    self.events_processed += 1
                    batch.append(pl[0])
                self._do_start_batch(t, batch)
            elif kind == SEND:
                self._do_send(t, *payload)
            elif kind == ACK:
                self._do_ack(t, *payload)
            elif kind == LOSS:
                self._do_loss(t, *payload)
            elif kind == SAMPLE:
                self._do_sample(t)
            elif kind == KERNEL:
                self.kernel.on_kernel_event(t, payload[0])
            elif kind == CALL:
                payload[0](t)

    # -- handlers --------------------------------------------------------- #
    def _stale(self, f: FlowRT, epoch: int, t: float, kind: int, payload: tuple) -> bool:
        """Timestamp-offsetting machinery (§6.3): an event from an older
        epoch is stashed while its flow is parked, or re-offset by the shift
        accumulated since it was scheduled if the flow has resumed."""
        if epoch == f.epoch:
            return False
        if f.done or epoch < f.void_before:
            # void epochs: events superseded by the timeout safety net must
            # die, not re-offset — their bytes already moved to ``retx``
            return True
        if f.parked:
            f.paused_events.append((t, kind, payload))
        else:
            shift = f.cum_shift - f.shift_at_epoch.get(epoch, f.cum_shift)
            self.schedule(t + shift, kind, *self._shift_payload(kind, payload, shift, f.epoch))
        return True

    def _do_start_batch(self, t: float, fids: list[int]) -> None:
        flows = []
        for fid in fids:
            f = self.flows[fid]
            f.started = True
            f.start_actual = t
            f.last_sample_t = t
            f.last_ack_t = t
            flows.append(f)
        self.kernel.on_flows_start(flows)
        for f in flows:
            if not f.parked and not f.send_scheduled and not f.done:
                f.send_scheduled = True
                self.schedule(t, SEND, f.fid, f.epoch)
        self._ensure_sampler(t)

    def _do_send(self, t: float, fid: int, epoch: int) -> None:
        f = self.flows[fid]
        if epoch != f.epoch and self._stale(f, epoch, t, SEND, (fid, epoch)):
            return
        f.send_scheduled = False
        if f.done or f.parked or not f.started:
            return
        want = f.retx if f.retx > 0 else min(self.mtu, f.spec.size - f.sent_new)
        if want <= 0:
            return
        # allow one packet in flight even when cwnd < mtu (TCP's one-MSS
        # floor): with nothing outstanding no ACK/LOSS can ever reopen the
        # window, so blocking here would stall the flow forever — reachable
        # since the timeout safety net voids all in-flight events
        if f.inflight > 0 and f.inflight + self.mtu > f.cca.cwnd():
            f.blocked = True
            return
        pkt = min(self.mtu, want)
        if f.retx > 0:
            f.retx -= pkt
        else:
            f.sent_new += pkt
        f.inflight += pkt
        int_vec = () if f.cca.uses_int else None
        # NOTE: sends stay on self.schedule — ShardedPacketSim overrides it
        # to route packet events into per-partition lanes
        self.schedule(t, ARRIVE, fid, 0, pkt, t, False, int_vec, f.epoch)
        if f.sent_new < f.spec.size or f.retx > 0:
            f.send_scheduled = True
            self.schedule(t + pkt / f.cca.rate(), SEND, fid, f.epoch)

    def _do_arrive(self, t: float, fid: int, hop: int, pkt: float, t_sent: float,
                   ecn: bool, int_vec, epoch: int) -> None:
        f = self.flows[fid]
        # the stale-payload tuple is only materialized on an epoch mismatch
        # (parks/timeouts) — the overwhelmingly common fresh path skips it
        if epoch != f.epoch and self._stale(
                f, epoch, t, ARRIVE, (fid, hop, pkt, t_sent, ecn, int_vec, epoch)):
            return
        if f.done:
            return
        self.packet_hop_events += 1
        if hop >= len(f.path):  # delivered: turn around an ACK
            self.schedule(t + f.ack_delay, ACK, fid, pkt, t_sent, ecn, int_vec, f.epoch)
            return
        port = f.path[hop]
        bw = self._link_bw[port]
        busy = self.busy_until[port]
        depart = busy if busy > t else t
        backlog = (depart - t) * bw
        cap = (self.buffer_bytes if self.shared_buffer is None
               else self._buffer_cap(port))
        if backlog + pkt > cap:
            # drop: sender learns after ~RTT
            self.schedule(t + f.cca.srtt, LOSS, fid, pkt, f.epoch)
            return
        if backlog > self.ecn_k:
            ecn = True
        tx_end = depart + pkt / bw
        self.busy_until[port] = tx_end
        txb = self.port_txbytes[port] + pkt
        self.port_txbytes[port] = txb
        if int_vec is not None:
            # INT telemetry (HPCC): per-hop (port, txBytes, ts, qlen) snapshot
            int_vec = int_vec + ((port, txb, tx_end, backlog),)
        self.schedule(tx_end + self._link_delay[port], ARRIVE,
                      fid, hop + 1, pkt, t_sent, ecn, int_vec, f.epoch)

    def _buffer_cap(self, port: int) -> float:
        if self.shared_buffer is None:
            return self.buffer_bytes
        sw = self._link_src[port]
        if sw < self.topo.n_hosts:
            return self.buffer_bytes
        used = 0.0
        now = self.now
        for lid, _ in self.topo.adj[sw]:
            backlog = (self.busy_until[lid] - now) * self._link_bw[lid]
            if backlog > 0.0:
                used += backlog
        return min(self.buffer_bytes, max(self.mtu, self.shared_buffer - used))

    def _do_ack(self, t: float, fid: int, pkt: float, t_sent: float, ecn: bool,
                int_vec, epoch: int) -> None:
        f = self.flows[fid]
        if epoch != f.epoch and self._stale(
                f, epoch, t, ACK, (fid, pkt, t_sent, ecn, int_vec, epoch)):
            return
        if f.done:
            return
        inflight = f.inflight - pkt
        f.inflight = inflight if inflight > 0.0 else 0.0
        f.delivered += pkt
        f.last_ack_t = t
        rtt = t - t_sent
        if self.record_rtt_fids and fid in self.record_rtt_fids:
            f.rtt_samples.append((t, rtt))
        info = None
        if int_vec is not None:
            # sender-side HPCC: U_hop = txRate/bw + qlen/(bw*T) from deltas
            # against the previous ACK's snapshots (Li et al., SIGCOMM'19)
            link_bw = self._link_bw
            int_prev = f.int_prev
            base_rtt = f.cca.base_rtt
            u_max = 0.0
            for (port, txb, ts, qlen) in int_vec:
                bw = link_bw[port]
                prev = int_prev.get(port)
                if prev is not None and ts > prev[1] + 1e-12:
                    pq = prev[2]
                    u = ((qlen if qlen <= pq else pq) / (bw * base_rtt)
                         + (txb - prev[0]) / ((ts - prev[1]) * bw))
                else:
                    u = 0.95 + qlen / (bw * base_rtt)  # no delta yet
                int_prev[port] = (txb, ts, qlen)
                if u > u_max:
                    u_max = u
            info = INTInfo(u_max)
        f.cca.on_ack(t, pkt, ecn, rtt, info)
        if f.delivered >= f.spec.size:
            self.finish_flow(f, t)
            return
        if (f.blocked or not f.send_scheduled) and (
                f.sent_new < f.spec.size or f.retx > 0):
            f.blocked = False
            f.send_scheduled = True
            self.schedule(t, SEND, fid, f.epoch)

    def _do_loss(self, t: float, fid: int, pkt: float, epoch: int) -> None:
        f = self.flows[fid]
        if epoch != f.epoch and self._stale(f, epoch, t, LOSS, (fid, pkt, epoch)):
            return
        if f.done:
            return
        f.inflight = max(0.0, f.inflight - pkt)
        f.retx += pkt
        f.cca.on_ack(t, 0.0, True, f.cca.srtt * 2,
                     INTInfo(2.0) if f.cca.uses_int else None)  # loss == severe congestion
        if not f.send_scheduled:
            f.send_scheduled = True
            self.schedule(t, SEND, fid, f.epoch)

    def _ensure_sampler(self, t: float) -> None:
        if not self._sample_pending and self._any_active_unparked():
            self._sample_pending = True
            self.schedule(t + self.sample_interval, SAMPLE)

    def _any_active_unparked(self) -> bool:
        return any(f.started and not f.done and not f.parked for f in self.flows.values())

    def _do_sample(self, t: float) -> None:
        self._sample_pending = False
        for f in self.flows.values():
            if not f.started or f.done or f.parked:
                continue
            dt = t - f.last_sample_t
            if dt <= 0:
                continue
            rate = (f.delivered - f.last_sample_delivered) / dt
            if len(f.rate_hist) >= self.window:
                f.rate_hist.popleft()
            f.rate_hist.append(rate)
            f.last_sample_delivered = f.delivered
            f.last_sample_t = t
            # timeout safety net: everything in flight counted lost.  The
            # superseded ARRIVE/ACK/LOSS events are still live in the heap;
            # void their epoch, or a late ACK would count bytes that are
            # *also* queued for retransmission and finish the flow early.
            if f.inflight > 0 and t - f.last_ack_t > max(10 * f.cca.srtt, 20 * self.sample_interval):
                f.retx += f.inflight
                f.inflight = 0.0
                f.shift_at_epoch[f.epoch] = f.cum_shift
                f.epoch += 1
                f.void_before = f.epoch
                f.last_ack_t = t   # restart the timer (RTO semantics) or
                #                    every later sample would void the fresh
                #                    retransmission again — livelock
                self.timeouts += 1
                # any pending SEND was voided with its epoch — re-arm
                f.blocked = False
                f.send_scheduled = True
                self.schedule(t, SEND, f.fid, f.epoch)
        self.kernel.on_sample(t)
        self._ensure_sampler(t)

    # ------------------------------------------------------------------ #
    def all_done(self) -> bool:
        return all(f.done for f in self.flows.values())
