"""Network substrate: topologies, flows, congestion control, and the
packet-level discrete-event simulation oracle (the "ns-3 stand-in") plus the
vectorized JAX fluid engine."""

from repro.net.flows import FlowSpec
from repro.net.topology import (Topology, fat_tree, leaf_spine_clos,
                                rail_optimized_fat_tree)
