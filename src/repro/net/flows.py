"""Flow specifications handed to the simulator by the workload layer."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class FlowSpec:
    fid: int
    src: int
    dst: int
    size: float                 # bytes
    start: float = 0.0          # seconds (may be rescheduled by the traffic DAG)
    cca: str = "dctcp"
    tag: str = ""               # e.g. "dp.allreduce.l3" — used for grouping in reports
    phase: int = -1             # traffic-program phase index (-1: standalone)

    def __post_init__(self) -> None:
        assert self.size > 0, "flow size must be positive"


@dataclasses.dataclass
class FlowResult:
    fid: int
    start: float
    fct: float                  # flow completion time (seconds, absolute finish - start)
    bytes: float
    tag: str = ""

    @property
    def finish(self) -> float:
        return self.start + self.fct
