"""Flow specifications handed to the simulator by the workload layer,
plus the flow-level max-min fair-share solver both the analytic backend
and the hybrid engine's flow lanes are driven by."""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping, Sequence

from repro.kernels.maxmin.ops import solve_paths as _solve_paths


def maxmin_rates(paths: Mapping[int, Sequence[int]], link_bw) -> dict[int, float]:
    """Progressive water-filling: max-min fair-share rates (bytes/s) for
    ``paths`` (flow id -> port ids) over capacities ``link_bw`` (indexable
    by port id).  Repeatedly saturates the most-contended link and freezes
    its flows at the fair share.  Shared by the analytic backend
    (``repro.api.analytic``) and the hybrid backend's flow-level lane
    (``repro.net.hybrid_sim``).

    Since the struct-of-arrays refactor this delegates to the vectorized
    solver in ``repro.kernels.maxmin`` (bit-identical outputs — asserted
    against :func:`maxmin_rates_dict` by ``tests/test_maxmin.py``)."""
    return _solve_paths(paths, link_bw)


def maxmin_rates_dict(paths: Mapping[int, Sequence[int]], link_bw) -> dict[int, float]:
    """The historical scalar dict/set water-filling loop, kept verbatim as
    the parity oracle for the array/Pallas solvers.  Quirks the array
    solver reproduces bit-for-bit: links enter in first-appearance order
    and ties break toward the earliest link; a link repeated within one
    path counts a single user but has its capacity decremented once per
    occurrence."""
    cap: dict[int, float] = {}
    users: dict[int, set[int]] = {}
    for fid, path in paths.items():
        for l in path:
            users.setdefault(l, set()).add(fid)
            cap.setdefault(l, float(link_bw[l]))
    rates: dict[int, float] = {}
    unfrozen = set(paths)
    while unfrozen:
        best_share, best_link = None, None
        for l, us in users.items():
            if not us:
                continue
            share = cap[l] / len(us)
            if best_share is None or share < best_share:
                best_share, best_link = share, l
        if best_link is None:
            for fid in sorted(unfrozen):  # unconstrained (cannot happen:
                rates[fid] = 1e12         # every flow crosses >= 1 link)
            break
        share = max(best_share, 0.0)
        for fid in list(users[best_link]):
            rates[fid] = share
            unfrozen.discard(fid)
            for l in paths[fid]:
                users[l].discard(fid)
                cap[l] -= share
    return rates


@dataclasses.dataclass
class FlowSpec:
    fid: int
    src: int
    dst: int
    size: float                 # bytes
    start: float = 0.0          # seconds (may be rescheduled by the traffic DAG)
    cca: str = "dctcp"
    tag: str = ""               # e.g. "dp.allreduce.l3" — used for grouping in reports
    phase: int = -1             # traffic-program phase index (-1: standalone)

    def __post_init__(self) -> None:
        assert self.size > 0, "flow size must be positive"


@dataclasses.dataclass
class FlowResult:
    fid: int
    start: float
    fct: float                  # flow completion time (seconds, absolute finish - start)
    bytes: float
    tag: str = ""

    @property
    def finish(self) -> float:
        return self.start + self.fct
