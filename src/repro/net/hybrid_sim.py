"""Adaptive packet/flow hybrid engine (HyGra-style granularity switching).

Wormhole (``repro.core.wormhole``) parks a partition only once its flows are
*provably* steady — transient-but-smooth traffic still burns full packet
fidelity.  The hybrid backend opens the accuracy/speed axis the pure-packet
engines cannot reach: per-partition granularity control where

* **packet granularity** — the partition runs the existing per-partition
  packet event lanes of :class:`~repro.net.sharded_sim.ShardedPacketSim`
  (the sharded loop's lane machinery is reused verbatim — with
  ``fidelity="packet"`` results are bit-identical to it);
* **flow granularity** — a partition whose flows are rate-stable (but not
  necessarily steady enough for a Wormhole park) is *demoted* to a
  flow-level lane: packets stop, per-flow state advances analytically, and
  the lane is driven by the progressive max-min rate solver
  (:func:`repro.net.flows.maxmin_rates`) — the solver gates demotion
  (measured rates must be consistent with the solved shares, which rejects
  mid-ramp convergence transients) and supplies the relative share updates
  when contention inside the lane changes (a member flow completes).

Demotion/promotion preserve simulation consistency by converting flow state
at the boundary exactly the way Wormhole park/unpark does: demote ==
``PacketSim.park_flows`` (pending events stash as they pop, in-flight bytes
stay frozen in the queues, ``delivered``/``sent`` advance analytically),
promote == ``PacketSim.unpark_flows`` (stashed events re-inject at +ΔT,
port backlogs shift, retx/cwnd state resumes untouched).  Promotion back to
packet granularity happens on any contention-pattern change the flow lane
cannot absorb: a new flow arriving on the partition's ports (merge), or the
``max_demote`` horizon expiring (a probe that re-measures at packet
fidelity).  While at packet granularity, the demotion detector is the
shared steady-state machinery of ``repro.core.steady`` — a partition whose
rate fluctuation leaves the detector's ``atol``/band over the rolling
``demote_after``-sample window simply loses its demotion eligibility until
it re-stabilises.

State machine per partition (cf. Wormhole's UNSTEADY/REPLAY/PARKED):

    form ──(auto: ``demote_after`` stable samples + solver-consistent)──> FLOW
      ^                                                                    │
      │<── promote: flow entry / horizon probe / solver-inconsistent split ┘
      └──── completion inside the lane: re-solve shares, stay FLOW ────────┘
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import theory
from repro.core.partition import PartitionIndex
from repro.core.steady import is_steady, rate_estimate
from repro.net.packet_sim import KERNEL, FlowRT, SimKernel
from repro.net.sharded_sim import ShardedPacketSim

PACKET, FLOW = "packet", "flow"
FIDELITIES = ("packet", "auto", "flow")


@dataclasses.dataclass
class HybridConfig:
    """Granularity-controller knobs (engine opts ``fidelity`` and
    ``demote_after`` override the corresponding fields)."""
    fidelity: str = "auto"         # packet | auto | flow
    demote_after: int = 6          # stable samples before a demotion
    # relative rate-fluctuation band for "rate-stable" (Eq. 6 over the last
    # ``demote_after`` samples).  band_auto lifts it per partition to the
    # CCA's steady sawtooth amplitude (Eq. 11 / steady_eps_hint), as the
    # Wormhole detector does for θ — below that a sawtooth never looks flat.
    band: float = 0.05
    band_auto: bool = True
    band_slack: float = 1.3
    band_cap: float = 0.12
    atol: float = 0.0              # steady detector dead-band (core/steady)
    # a demotion is only taken when the measured rates agree with the
    # max-min solve within this relative band: a mid-ramp flow sits well
    # below its fair share, so the solver check rejects convergence
    # transients that merely *look* flat over a short window
    solver_band: float = 0.15
    max_demote: float = 0.5        # flow-lane dwell bound (s) before a probe
    resolve_on_completion: bool = True   # re-solve + stay FLOW across finishes

    @classmethod
    def from_knobs(cls, knobs: dict) -> "HybridConfig":
        """Build from a scenario ``kernel`` dict, ignoring foreign keys —
        scenarios share one kernel-knob dict across backends (a Wormhole
        scenario's ``theta`` must not break the hybrid engine)."""
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in knobs.items() if k in known})


@dataclasses.dataclass(slots=True)
class HPart:
    """Granularity-controller state for one live partition."""
    pid: int
    gen: int
    fids: set[int]
    ports: frozenset[int]
    state: str = PACKET
    formed_at: float = 0.0
    samples: int = 0               # detector samples since formation
    band: float = 0.10
    park_t: float = 0.0
    park_delivered: dict[int, float] = dataclasses.field(default_factory=dict)
    # drift confirm (the Wormhole guard against slow convergence ramps that
    # stay inside the band per window yet are not converged): a stable
    # window only *arms* the demotion; it fires half a window later if the
    # fresh means agree with the armed ones
    pending: dict[int, float] | None = None
    confirm_at: int = 0


class HybridKernel(SimKernel):
    """Per-partition granularity controller, plugged into the sharded
    packet loop through the same :class:`SimKernel` seam Wormhole uses."""

    def __init__(self, cfg: HybridConfig | None = None) -> None:
        self.cfg = cfg or HybridConfig()
        if self.cfg.fidelity not in FIDELITIES:
            raise ValueError(f"unknown fidelity {self.cfg.fidelity!r}; "
                             f"have {FIDELITIES}")
        self.index = PartitionIndex()
        self.parts: dict[int, HPart] = {}
        self._gen = 0
        self._corr: dict[int, float] = {}   # measured/solved at demote time
        self._finish_queue: list[int] = []
        self._draining = False
        self.stats = {
            "demotions": 0, "promotions": 0, "resolves": 0, "probes": 0,
            "solves": 0, "solver_rejects": 0, "flow_events": 0,
            "est_events_skipped": 0.0, "flow_lane_seconds": 0.0,
        }

    def attach(self, sim) -> None:
        super().attach(sim)
        sim.window = max(sim.window, self.cfg.demote_after)
        # the sharded sim keys its packet event lanes off this kernel's live
        # PartitionIndex — one lifecycle drives lanes and granularity both
        adopt = getattr(sim, "adopt_partition_index", None)
        if adopt is not None:
            adopt(self.index)

    # ------------------------------------------------------------------ #
    # finish-drain plumbing (the Wormhole pattern: reshapes triggered by
    # completions inside kernel callbacks run after the callback returns)
    # ------------------------------------------------------------------ #
    def _with_drain(self, fn, now: float) -> None:
        if self._draining:
            fn()
            return
        self._draining = True
        try:
            fn()
            while self._finish_queue:
                self._finish_reshape(self._finish_queue.pop(0), now)
        finally:
            self._draining = False

    # ------------------------------------------------------------------ #
    # flow entry: promote affected flow lanes, merge, re-form
    # ------------------------------------------------------------------ #
    def on_flow_start(self, flow: FlowRT) -> None:
        self.on_flows_start([flow])

    def on_flows_start(self, flows: list[FlowRT]) -> None:
        now = self.sim.now
        self._with_drain(lambda: self._admit(flows, now), now)

    def _admit(self, flows: list[FlowRT], now: float) -> None:
        all_ports: set[int] = set()
        for f in flows:
            all_ports |= f.ports
        for pid in self.index.affected_partitions(all_ports):
            part = self.parts.get(pid)
            if part is not None and part.state == FLOW:
                # contention-pattern change: the flow lane's solved shares
                # are stale the moment a new flow lands on these ports
                self._promote(part, now)
        for f in flows:
            _, merged = self.index.add_flow(f.fid, f.ports)
            for pid in merged:
                self.parts.pop(pid, None)
        # sorted: partitions form in pid order, not set order
        for pid in sorted({self.index.flow_pid[f.fid] for f in flows}):
            self._form(pid, self.index.parts[pid], now)

    # ------------------------------------------------------------------ #
    # chaos: port capacity retargeted — solved shares are stale
    # ------------------------------------------------------------------ #
    def on_chaos(self, now: float, ports) -> None:
        """A chaos injector changed these ports' capacities: demoted flow
        lanes solved their shares against the old capacities — promote them
        back to packet fidelity so the detector re-measures (and the solver
        re-solves) under the new regime."""
        affected = set(ports)

        def go() -> None:
            for pid in self.index.affected_partitions(affected):
                part = self.parts.get(pid)
                if part is not None and part.state == FLOW:
                    self._promote(part, now)
        self._with_drain(go, now)

    # ------------------------------------------------------------------ #
    # flow completion: reshape; flow lanes re-solve and stay demoted
    # ------------------------------------------------------------------ #
    def on_flow_finish(self, flow: FlowRT, now: float) -> None:
        self._corr.pop(flow.fid, None)
        self._finish_queue.append(flow.fid)
        if not self._draining:
            self._with_drain(lambda: None, now)

    def _finish_reshape(self, fid: int, now: float) -> None:
        pid = self.index.flow_pid.get(fid)
        if pid is None:
            return
        part = self.parts.get(pid)
        if part is not None:
            if part.state == FLOW:
                # unpark the survivors at the boundary (the canonical
                # Wormhole conversion); the residual partitions inherit the
                # "flow" granularity tag through the index split and are
                # re-demoted at solver-rescaled rates in _form
                self._account_skip(part, now)
                sim = self.sim
                for g in list(part.fids):
                    sim._materialize(sim.flows[g], now)
                alive = [g for g in part.fids if not sim.flows[g].done]
                self.stats["flow_events"] += len(part.fids)
                sim.unpark_flows(alive, part.ports, now, now - part.park_t)
            part.gen = -1
            self.parts.pop(pid, None)
        _, splits = self.index.remove_flow(fid)
        for new_pid, flows in splits:
            self._form(new_pid, flows, now)

    # ------------------------------------------------------------------ #
    # partition formation
    # ------------------------------------------------------------------ #
    def _form(self, pid: int, fids: set[int], now: float) -> None:
        sim = self.sim
        # fids is iterated sorted throughout: every derived ordering (alive,
        # vrates, rate-history resets) is a pure function of the flow ids,
        # never of set-insertion history
        ordered = sorted(fids)
        ports: set[int] = set()
        for fid in ordered:
            ports |= self.index.flow_ports[fid]
        self._gen += 1
        part = HPart(pid=pid, gen=self._gen, fids=set(fids),
                     ports=frozenset(ports), formed_at=now)
        part.band = self._band_for(fids)
        self.parts[pid] = part
        alive = [fid for fid in ordered if not sim.flows[fid].done]
        inherited_flow = (self.index.granularity.get(pid) == FLOW and alive
                          and self.cfg.resolve_on_completion
                          and self.cfg.fidelity != "packet")
        if inherited_flow:
            # completion split of a demoted partition: survivors go straight
            # back into the flow lane at solver-rescaled rates — the solver
            # supplies the new shares, the demote-time measured/solved
            # correction factor carries the CCA's deviation from max-min
            solved = self._solve(part)
            vrates = {}
            for fid in alive:
                f = sim.flows[fid]
                v = self._corr.get(fid, 1.0) * solved.get(fid, f.cca.rate())
                vrates[fid] = min(max(v, 1e-3), f.cca.line_rate)
            self.stats["resolves"] += 1
            self._demote(part, now, vrates)
            return
        self.index.set_granularity(pid, PACKET)
        for fid in ordered:
            f = sim.flows[fid]
            f.rate_hist.clear()
            f.last_sample_delivered = f.delivered
            f.last_sample_t = now
        if self.cfg.fidelity == "flow" and alive:
            # everything rides the flow lane: pure solver rates from t=0
            # (the coarse end of the fidelity axis — analytic-grade error)
            solved = self._solve(part)
            vrates = {fid: max(solved.get(fid, 1e-3), 1e-3) for fid in alive}
            self._demote(part, now, vrates)

    def _band_for(self, fids) -> float:
        cfg = self.cfg
        if not cfg.band_auto:
            return cfg.band
        eps = 0.0
        for fid in fids:
            cca = self.sim.flows[fid].cca
            if cca.steady_eps_hint is not None:
                eps = max(eps, cca.steady_eps_hint)
            else:      # window/sawtooth CCAs: the Eq. 11 amplitude guidance
                crtt = cca.line_rate * cca.base_rtt / self.sim.mtu
                eps = max(eps, theory.dctcp_relative_fluctuation(
                    len(fids), 1.0, crtt, mss=1.0))
        return min(max(cfg.band, cfg.band_slack * eps), cfg.band_cap)

    # ------------------------------------------------------------------ #
    # demotion detector (runs on monitor samples, packet partitions only)
    # ------------------------------------------------------------------ #
    def on_sample(self, now: float) -> None:
        if self.cfg.fidelity != "auto":
            return
        self._with_drain(lambda: self._detect(now), now)

    def _detect(self, now: float) -> None:
        cfg = self.cfg
        sim = self.sim
        for part in list(self.parts.values()):
            if part.state != PACKET or part.pid not in self.parts:
                continue
            flows = [sim.flows[fid] for fid in part.fids]
            if any(not f.started or f.done or f.parked for f in flows):
                continue
            part.samples += 1
            if part.samples < cfg.demote_after:
                continue
            # rolling window: one out-of-band fluctuation and the partition
            # keeps packet granularity (and loses its armed confirm) until
            # the window is clean again
            if not all(is_steady(f.rate_hist, cfg.demote_after, part.band,
                                 cfg.atol) for f in flows):
                part.pending = None
                continue
            means = {f.fid: rate_estimate(f.rate_hist, cfg.demote_after)
                     for f in flows}
            if part.pending is None:
                part.pending = means
                part.confirm_at = part.samples + max(cfg.demote_after // 2, 2)
                continue
            if part.samples < part.confirm_at:
                continue
            prev = part.pending
            drifting = not all(
                fid in prev and abs(m - prev[fid]) <= (part.band / 2)
                * max(m, 1e-9) for fid, m in means.items())
            if drifting:
                # a ramp moved the means across the half window: re-arm
                part.pending = means
                part.confirm_at = part.samples + max(cfg.demote_after // 2, 2)
                continue
            solved = self._solve(part)
            vrates: dict[int, float] = {}
            corr: dict[int, float] = {}
            ok = True
            for f in flows:
                measured = means[f.fid]
                s = solved.get(f.fid, 0.0)
                if abs(measured - s) > cfg.solver_band * max(s, 1e-9):
                    ok = False
                    break
                # stability is judged over the full window, but the lane
                # rate comes from the freshest half: a decelerating ramp
                # tail that slipped past the drift guard still biases the
                # full-window mean low, while the newest samples sit on the
                # converged value
                fresh = rate_estimate(f.rate_hist, max(cfg.demote_after // 2, 2))
                vrates[f.fid] = max(fresh, 1e-3)
                corr[f.fid] = min(max(fresh / max(s, 1e-9), 0.25), 4.0)
            if not ok:
                self.stats["solver_rejects"] += 1
                part.pending = means        # stay armed; re-check as it moves
                part.confirm_at = part.samples + max(cfg.demote_after // 2, 2)
                continue
            self._corr.update(corr)
            self._demote(part, now, vrates)

    def _solve(self, part: HPart) -> dict[int, float]:
        """Max-min shares for the partition's live flows, straight off the
        sim's struct-of-arrays :class:`~repro.net.soa.FlowTable` (iteration
        order matches the historical ``{fid: path}`` dict comprehension, so
        the solver's link tie-breaks — and every downstream vrate — are
        bit-identical to the dict-solver era)."""
        sim = self.sim
        self.stats["solves"] += 1
        flows = sim.flows
        # _link_bw, not topo.link_bw: chaos injectors retarget port
        # capacities mid-run, and a post-chaos demotion must solve against
        # what the port actually drains now (same float values when no
        # injector fired)
        return sim.flow_table.solve_rates(
            (fid for fid in part.fids if not flows[fid].done),
            sim._link_bw)

    # ------------------------------------------------------------------ #
    # granularity transitions
    # ------------------------------------------------------------------ #
    def _demote(self, part: HPart, now: float, vrates: dict[int, float]) -> None:
        """packet -> flow: park the partition's flows at the given analytic
        rates and schedule the lane horizon (earliest virtual completion,
        bounded by ``max_demote``)."""
        sim = self.sim
        part.state = FLOW
        part.park_t = now
        part.park_delivered = {fid: sim.flows[fid].delivered
                               for fid in part.fids}
        self.index.set_granularity(part.pid, FLOW)
        alive = [fid for fid in part.fids if not sim.flows[fid].done]
        sim.park_flows(alive, now, vrates)
        self.stats["demotions"] += 1
        self.stats["flow_events"] += len(alive)
        # in "flow" fidelity there is no packet-level detector to hand the
        # partition back to, so the max_demote re-measure probe would strand
        # it at packet granularity forever — the lane runs to its virtual
        # completions (entries still promote-and-re-demote through _admit)
        horizon = (math.inf if self.cfg.fidelity == "flow"
                   else now + self.cfg.max_demote)
        for fid in alive:
            f = sim.flows[fid]
            if not f.done:
                horizon = min(horizon, sim.virtual_completion(f))
        self._gen += 1
        part.gen = self._gen
        sim.schedule(max(horizon, now + 1e-9), KERNEL,
                     ("hybrid", part.pid, part.gen))

    def _promote(self, part: HPart, now: float) -> None:
        """flow -> packet: materialize analytic state at ``now`` and resume
        packet simulation (stashed events re-inject at +ΔT, port backlogs
        shift — ``unpark_flows``), then re-arm the demotion detector."""
        sim = self.sim
        self._account_skip(part, now)
        for fid in list(part.fids):
            sim._materialize(sim.flows[fid], now)
        alive = [fid for fid in part.fids if not sim.flows[fid].done]
        self.stats["flow_events"] += len(part.fids)
        sim.unpark_flows(alive, part.ports, now, now - part.park_t)
        part.state = PACKET
        part.samples = 0
        part.formed_at = now
        if part.pid in self.index.parts:
            self.index.set_granularity(part.pid, PACKET)
        for fid in part.fids:
            self._corr.pop(fid, None)
            f = sim.flows[fid]
            f.rate_hist.clear()
            f.last_sample_delivered = f.delivered
            f.last_sample_t = now
        self.stats["promotions"] += 1

    # ------------------------------------------------------------------ #
    # flow-lane horizon (virtual completion or max_demote probe)
    # ------------------------------------------------------------------ #
    def on_kernel_event(self, now: float, payload) -> None:
        kind, pid, gen = payload
        part = self.parts.get(pid)
        if part is None or part.gen != gen or part.state != FLOW:
            return
        self._with_drain(lambda: self._horizon(part, now), now)

    def _horizon(self, part: HPart, now: float) -> None:
        sim = self.sim
        for fid in list(part.fids):
            sim._materialize(sim.flows[fid], now)
        self.stats["flow_events"] += len(part.fids)
        if any(sim.flows[fid].done for fid in part.fids):
            return     # completion reshape (drain) re-solves the survivors
        # max_demote dwell bound: promote and re-measure at packet fidelity
        self.stats["probes"] += 1
        self._promote(part, now)

    # ------------------------------------------------------------------ #
    def _account_skip(self, part: HPart, now: float) -> None:
        """Events the flow lane avoided, estimated exactly as Wormhole does
        (bytes analytically advanced x per-MTU hop/ack event cost)."""
        sim = self.sim
        for fid in part.fids:
            f = sim.flows[fid]
            end = min(now, f.finish_t) if f.done else now
            self.stats["flow_lane_seconds"] += max(0.0, end - part.park_t)
            prev = part.park_delivered.get(fid, f.delivered)
            cur = f.spec.size if f.done else (
                f.delivered + max(0.0, (min(now, sim.now) - f.park_t)) * f.vrate)
            adv = max(0.0, min(cur, f.spec.size) - prev)
            self.stats["est_events_skipped"] += (adv / sim.mtu) * (len(f.path) + 3)

    def report(self) -> dict:
        out = dict(self.stats)
        out["fidelity"] = self.cfg.fidelity
        out["events_processed"] = self.sim.events_processed
        out["partitions"] = self._gen
        out["flow_partitions_live"] = sum(
            1 for p in self.parts.values() if p.state == FLOW)
        return out


class HybridSim(ShardedPacketSim):
    """Sharded packet loop + per-granularity event accounting.  With no
    kernel (``fidelity="packet"``) this *is* the sharded serial loop — the
    counters are the only addition, so results stay bit-identical."""

    # hot class (reprolint H205/C304)
    __slots__ = ("packet_lane_events",)

    def __init__(self, topo, kernel=None, **knobs) -> None:
        super().__init__(topo, kernel=kernel, **knobs)
        self.packet_lane_events = 0

    # every packet-kind execution funnels through these four handlers, in
    # the serial lane loops and in serial redos alike
    def _do_send(self, t, *a) -> None:
        self.packet_lane_events += 1
        super()._do_send(t, *a)

    def _do_arrive(self, t, *a) -> None:
        self.packet_lane_events += 1
        super()._do_arrive(t, *a)

    def _do_ack(self, t, *a) -> None:
        self.packet_lane_events += 1
        super()._do_ack(t, *a)

    def _do_loss(self, t, *a) -> None:
        self.packet_lane_events += 1
        super()._do_loss(t, *a)

    def _merge(self, lanes, results) -> None:
        # worker-executed events are packet-kind by construction (workers
        # only run lane heaps); fold their counts in at merge time
        before = self.events_processed
        super()._merge(lanes, results)
        self.packet_lane_events += self.events_processed - before

    def granularity_report(self) -> dict:
        rep = {
            "packet_lane_events": self.packet_lane_events,
            "flow_lane_events": 0,
            "demotions": 0, "promotions": 0, "resolves": 0, "probes": 0,
            "est_events_skipped": 0.0, "flow_lane_seconds": 0.0,
        }
        if isinstance(self.kernel, HybridKernel):
            st = self.kernel.stats
            rep.update(
                flow_lane_events=st["flow_events"],
                demotions=st["demotions"], promotions=st["promotions"],
                resolves=st["resolves"], probes=st["probes"],
                est_events_skipped=st["est_events_skipped"],
                flow_lane_seconds=st["flow_lane_seconds"])
        return rep
