"""Data-center topologies and deterministic ECMP routing.

A topology is a directed multigraph.  Every *directed* link is a "port" in the
paper's terminology (§3.1.1: partitioning happens at port granularity); the
forward and reverse directions of a cable are distinct ports with independent
FIFO queues.

Units: bandwidth in bytes/s, delay in seconds.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import numpy as np

HOST = 0
SWITCH = 1


@dataclasses.dataclass
class Topology:
    name: str
    n_hosts: int
    n_nodes: int                      # hosts + switches; hosts are 0..n_hosts-1
    link_src: np.ndarray              # int32 [n_links]
    link_dst: np.ndarray              # int32 [n_links]
    link_bw: np.ndarray               # float64 [n_links] bytes/s
    link_delay: np.ndarray            # float64 [n_links] seconds
    # Optional metadata used by placement (rail-optimized topologies).
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.n_links = len(self.link_src)
        # adjacency[node] = list of (link_id, neighbor)
        adj: list[list[tuple[int, int]]] = [[] for _ in range(self.n_nodes)]
        for lid in range(self.n_links):
            adj[int(self.link_src[lid])].append((lid, int(self.link_dst[lid])))
        self.adj = adj
        self._dist_cache: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------ #
    # Routing
    # ------------------------------------------------------------------ #
    def _dist_to(self, dst: int) -> np.ndarray:
        """BFS hop distance from every node to ``dst`` (reverse graph ==
        forward graph here because every cable is bidirectional)."""
        cached = self._dist_cache.get(dst)
        if cached is not None:
            return cached
        dist = np.full(self.n_nodes, np.iinfo(np.int32).max, dtype=np.int32)
        dist[dst] = 0
        frontier = [dst]
        # reverse adjacency equals adjacency for our symmetric builders
        while frontier:
            nxt = []
            for u in frontier:
                du = dist[u]
                for _, v in self.adj[u]:
                    if dist[v] > du + 1:
                        dist[v] = du + 1
                        nxt.append(v)
            frontier = nxt
        self._dist_cache[dst] = dist
        return dist

    def route(self, src: int, dst: int, flow_id: int) -> list[int]:
        """Deterministic ECMP: shortest path, ties broken by a hash of
        (flow_id, hop) — the same flow always takes the same path, different
        flows spread over the equal-cost fan-out (standard 5-tuple ECMP
        behavior, which is what makes contention patterns *reproducible*,
        the property Wormhole's memoization exploits)."""
        if src == dst:
            return []
        dist = self._dist_to(dst)
        if dist[src] >= np.iinfo(np.int32).max:
            raise ValueError(f"no path {src}->{dst} in {self.name}")
        path: list[int] = []
        node = src
        step = 0
        while node != dst:
            cands = [(lid, v) for lid, v in self.adj[node] if dist[v] == dist[node] - 1]
            h = (flow_id * 1000003 + node * 10007 + step * 101) % len(cands)
            lid, node = cands[h]
            path.append(lid)
            step += 1
        return path

    def port_name(self, lid: int) -> str:
        return f"{int(self.link_src[lid])}->{int(self.link_dst[lid])}"


# ---------------------------------------------------------------------- #
# Builders.  All create bidirectional cables (two directed links each).
# ---------------------------------------------------------------------- #
def _finish(name: str, n_hosts: int, n_nodes: int, cables: list[tuple[int, int, float, float]],
            meta: dict | None = None) -> Topology:
    src, dst, bw, dly = [], [], [], []
    for a, b, c, d in cables:
        src += [a, b]
        dst += [b, a]
        bw += [c, c]
        dly += [d, d]
    return Topology(
        name=name, n_hosts=n_hosts, n_nodes=n_nodes,
        link_src=np.asarray(src, np.int32), link_dst=np.asarray(dst, np.int32),
        link_bw=np.asarray(bw, np.float64), link_delay=np.asarray(dly, np.float64),
        meta=meta or {},
    )


def fat_tree(k: int, bw: float = 12.5e9, delay: float = 1e-6) -> Topology:
    """Classic 3-tier k-ary fat-tree [Al-Fares et al., SIGCOMM'08]:
    k pods, (k/2)^2 hosts/pod, (k/2)^2 core switches.  Requires even k."""
    assert k % 2 == 0, "fat-tree arity must be even"
    half = k // 2
    n_hosts = k * half * half
    n_edge = k * half
    n_agg = k * half
    n_core = half * half
    edge0 = n_hosts
    agg0 = edge0 + n_edge
    core0 = agg0 + n_agg
    n_nodes = core0 + n_core
    cables: list[tuple[int, int, float, float]] = []
    for pod in range(k):
        for e in range(half):
            edge = edge0 + pod * half + e
            for h in range(half):
                host = pod * half * half + e * half + h
                cables.append((host, edge, bw, delay))
            for a in range(half):
                agg = agg0 + pod * half + a
                cables.append((edge, agg, bw, delay))
        for a in range(half):
            agg = agg0 + pod * half + a
            for c in range(half):
                core = core0 + a * half + c
                cables.append((agg, core, bw, delay))
    return _finish(f"fat_tree_k{k}", n_hosts, n_nodes, cables,
                   meta={"kind": "fat_tree", "k": k, "hosts_per_pod": half * half})


def rail_optimized_fat_tree(n_servers: int, gpus_per_server: int = 8,
                            leaf_radix: int = 32, n_spines: int = 8,
                            bw: float = 12.5e9, delay: float = 1e-6) -> Topology:
    """Rail-optimized fat-tree [NVIDIA SuperPod]: GPU ``r`` of every server
    attaches to rail-``r`` leaves; DP traffic (same GPU index across servers)
    stays inside one rail; cross-rail traffic (EP all-to-all, some PP) rides
    the shared spine layer.  Each GPU is its own host (multi-NIC servers, as
    in the paper's setup §7)."""
    n_hosts = n_servers * gpus_per_server
    leaves_per_rail = max(1, -(-n_servers // leaf_radix))
    n_leaves = gpus_per_server * leaves_per_rail
    leaf0 = n_hosts
    spine0 = leaf0 + n_leaves
    n_nodes = spine0 + n_spines
    cables: list[tuple[int, int, float, float]] = []
    for s in range(n_servers):
        for r in range(gpus_per_server):
            host = s * gpus_per_server + r
            leaf = leaf0 + r * leaves_per_rail + (s // leaf_radix)
            cables.append((host, leaf, bw, delay))
    for leaf in range(leaf0, spine0):
        for sp in range(n_spines):
            cables.append((leaf, spine0 + sp, bw * 2, delay))  # 2x uplink trunks
    return _finish(
        f"roft_s{n_servers}x{gpus_per_server}", n_hosts, n_nodes, cables,
        meta={"kind": "roft", "gpus_per_server": gpus_per_server,
              "n_servers": n_servers, "leaves_per_rail": leaves_per_rail},
    )


def leaf_spine_clos(n_hosts: int, leaf_down: int = 16, n_spines: int = 4,
                    bw: float = 12.5e9, delay: float = 1e-6) -> Topology:
    """2-tier folded Clos (leaf-spine)."""
    n_leaves = -(-n_hosts // leaf_down)
    leaf0 = n_hosts
    spine0 = leaf0 + n_leaves
    n_nodes = spine0 + n_spines
    cables: list[tuple[int, int, float, float]] = []
    for h in range(n_hosts):
        cables.append((h, leaf0 + h // leaf_down, bw, delay))
    for l in range(n_leaves):
        for sp in range(n_spines):
            cables.append((leaf0 + l, spine0 + sp, bw * 2, delay))
    return _finish(f"clos_h{n_hosts}", n_hosts, n_nodes, cables,
                   meta={"kind": "clos", "leaf_down": leaf_down})


TOPOLOGY_BUILDERS: dict[str, Callable[..., Topology]] = {
    "fat_tree": fat_tree,
    "roft": rail_optimized_fat_tree,
    "clos": leaf_spine_clos,
}
