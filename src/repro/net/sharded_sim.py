"""Partition-sharded event loop with intra-run parallel fan-out (§6.1).

The serial :class:`~repro.net.packet_sim.PacketSim` loop pops one global
heap.  But partitions — connected components of the flow↔port bipartite
graph (`repro.core.partition`) — share no ports, so their packet events
commute between the global synchronization points: flow entry (START/CALL),
flow completion reshape, sample ticks and kernel (unpark) events.

:class:`ShardedPacketSim` exploits that: per-partition *event lanes* (one
local heap + seq counter each, keyed by the live ``PartitionIndex``) plus
one global lane for START/SAMPLE/KERNEL/CALL events.  The loop runs in
*windows*: every lane advances independently up to the next global event's
timestamp (the barrier), then the barrier event executes against the merged
state.  Within a lane, events keep the serial loop's relative `(t, seq)`
order, so results are identical to the serial loop — the property the
equivalence tests pin down.

Intra-run fan-out (``intra_workers >= 2``) dispatches *heavy* lanes — big
UNSTEADY partitions that provably cannot complete a flow inside the window
— to a spawn-based process pool, while parked/replaying partitions stay
analytic and light lanes run in the parent.  Lane state (flows + port
backlogs + pending events) ships to the worker and back; the lane-local seq
counter travels with it, so the merged execution is bit-identical to the
serial sharded loop no matter how many workers run.

Two conservative guards keep the parallel path exact:

* a lane is only dispatched if no member flow can finish inside the window
  (remaining bytes > in-flight + retx + 2·line_rate·window); a worker that
  *does* hit a completion aborts, and the parent re-runs the whole window
  serially from its own (unmutated) state;
* if a parent-side completion schedules a new global event *inside* the
  window (flow-entry reshape: the driver launching a dependent phase), the
  barrier shrinks to it before any heavy lane is dispatched.

``shared_buffer`` couples ports of co-located partitions through the switch
pool, which breaks Definition 1 exclusivity — sharded mode refuses it.
"""
from __future__ import annotations

import heapq
import itertools
import math
import pickle
from collections import deque
from concurrent.futures import ProcessPoolExecutor

from repro.core.partition import PartitionIndex
from repro.net.packet_sim import (ACK, ARRIVE, CALL, KERNEL, LOSS, SAMPLE,
                                  SEND, START, PacketSim)
from repro.net.soa import LaneState
from repro.net.topology import Topology

PACKET_KINDS = frozenset((SEND, ARRIVE, ACK, LOSS))
GRAVE = 0   # lane id for residual events of completed flows


def _exec_packet_event(sim: PacketSim, t: float, kind: int,
                       payload: tuple) -> None:
    """The one packet-event dispatch switch every lane executor (parent
    serial/tight loops, worker loop) shares — keeping a single source of
    truth is what the sharded loop's identical-to-serial guarantee hangs
    on."""
    sim.now = t
    sim.events_processed += 1
    if kind == ARRIVE:
        sim._do_arrive(t, *payload)
    elif kind == SEND:
        sim._do_send(t, *payload)
    elif kind == ACK:
        sim._do_ack(t, *payload)
    elif kind == LOSS:
        sim._do_loss(t, *payload)
    else:
        raise RuntimeError(f"non-packet event kind {kind} in a lane")


# lane state lives in the struct-of-arrays module now (shared with the
# hybrid loop and the SoA parity tests); the old private name stays an
# alias because it *is* the same structure
_Lane = LaneState


class ShardedPacketSim(PacketSim):
    """Drop-in :class:`PacketSim` with a partition-sharded scheduler.

    intra_workers      worker processes for heavy-lane fan-out (1 = serial
                       sharded execution, still lane-structured and exact)
    intra_min_events   a lane is dispatched only if it holds at least this
                       many pending events (smaller lanes aren't worth IPC)
    validate           check lane/partition invariants per event + barrier
                       (property tests; slow)
    """

    # hot class (reprolint H205/C304): slots on top of PacketSim's
    __slots__ = (
        "_lanes", "_grave", "_split_log", "_fid_lane", "intra_workers",
        "intra_min_events", "validate", "_adopted_index", "_pindex",
        "_own_index", "_pool", "shard_stats", "_shell_key", "_shell_blob",
    )

    def __init__(self, topo: Topology, kernel=None, *,
                 intra_workers: int = 1, intra_min_events: int = 64,
                 validate: bool = False, **knobs) -> None:
        self._lanes: dict[int, _Lane] = {}
        self._grave = _Lane(GRAVE)
        self._split_log: list[tuple[int, list[int]]] = []
        self._fid_lane: dict[int, _Lane] = {}   # hot-path cache, see schedule
        # must exist before super().__init__: kernel.attach (called there)
        # may adopt_partition_index(), which writes this slot
        self._adopted_index: PartitionIndex | None = None
        super().__init__(topo, kernel=kernel, **knobs)
        if self.shared_buffer is not None:
            raise ValueError(
                "sharded mode needs per-port buffers: shared_buffer couples "
                "partitions through the switch pool (Definition 1 breaks)")
        self.intra_workers = max(1, int(intra_workers))
        self.intra_min_events = intra_min_events
        self.validate = validate
        if self._adopted_index is not None:
            self._pindex = self._adopted_index        # kernel-owned lifecycle
            self._own_index = False
        else:
            self._pindex = PartitionIndex()           # kernel-less: mirror it
            self._own_index = True
        self._pindex.observer = self
        self._pool: ProcessPoolExecutor | None = None
        self.shard_stats = {
            "windows": 0, "dispatches": 0, "dispatched_events": 0,
            "window_shrinks": 0, "serial_redos": 0, "merges": 0, "splits": 0,
            # batched run draining (LaneState.pop_run): runs of >= 2
            # same-timestamp events drained under one window-bound check
            "batched_drains": 0, "max_batch_width": 0,
        }

    # ------------------------------------------------------------------ #
    # partition lifecycle -> lane lifecycle (PartitionObserver protocol)
    # ------------------------------------------------------------------ #
    def adopt_partition_index(self, index: PartitionIndex) -> None:
        """Called by a kernel (Wormhole) during attach: its live
        PartitionIndex drives lane creation/merge/split instead of a
        duplicate one."""
        self._adopted_index = index

    def on_partition_merge(self, fid: int, new_pid: int,
                           merged_pids: set[int]) -> None:
        self._fid_lane.clear()
        olds = [self._lanes.pop(p) for p in sorted(merged_pids)
                if p in self._lanes]
        if not olds:
            return
        self.shard_stats["merges"] += 1
        merged = _Lane(new_pid)
        # Deterministic interleave: within one old lane, (t, seq) order is
        # preserved; across old lanes same-t events commute (their port sets
        # were disjoint pre-merge), ordered by lane rank for reproducibility.
        # New events get larger seqs — exactly the serial loop's "scheduled
        # after the merge" ordering.
        items: list = []
        for rank, ln in enumerate(olds):
            items.extend((t, s, rank, kind, payload)
                         for (t, s, kind, payload) in ln.heap)
        items.sort(key=lambda e: (e[0], e[1], e[2]))
        for (t, _s, _r, kind, payload) in items:
            merged.seq += 1
            merged.heap.append((t, merged.seq, kind, payload))
        self._lanes[new_pid] = merged

    def on_partition_split(self, fid: int, old_pid: int,
                           new_parts: list[tuple[int, set[int]]]) -> None:
        self._fid_lane.clear()
        old = self._lanes.pop(old_pid, None)
        if old is None:
            return
        self.shard_stats["splits"] += 1
        owner: dict[int, int] = {}
        for new_pid, flows in new_parts:
            for g in flows:
                owner[g] = new_pid
        buckets: dict[int, list] = {}
        for ev in sorted(old.heap, key=lambda e: (e[0], e[1])):
            pid2 = owner.get(ev[3][0])
            if pid2 is None:
                # the departing flow is finished (reshape interrupt ②): its
                # residual stale events drain through the graveyard lane
                self._grave.push(ev[0], ev[2], ev[3])
            else:
                buckets.setdefault(pid2, []).append(ev)
        for pid2, evs in buckets.items():
            ln = _Lane(pid2)
            for (t, _s, kind, payload) in evs:
                ln.seq += 1
                ln.heap.append((t, ln.seq, kind, payload))
            self._lanes[pid2] = ln
        self._split_log.append((old_pid, [p for p, _ in new_parts]))

    # ------------------------------------------------------------------ #
    # scheduling: packet events go to their partition's lane
    # ------------------------------------------------------------------ #
    def schedule(self, t: float, kind: int, *payload) -> None:
        t = max(t, self.now)
        if kind in PACKET_KINDS:
            # fid -> lane cache (invalidated wholesale on any merge/split —
            # partition reshapes are rare next to per-packet scheduling)
            lane = self._fid_lane.get(payload[0])
            if lane is None:
                pid = self._pindex.flow_pid.get(payload[0])
                if pid is None:
                    self._grave.push(t, kind, payload)
                    return
                lane = self._lanes.get(pid)
                if lane is None:
                    lane = self._lanes[pid] = _Lane(pid)
                self._fid_lane[payload[0]] = lane
            lane.push(t, kind, payload)
        else:
            s = self._seq
            self._seq = s + 1
            heapq.heappush(self._heap, (t, s, kind, payload))

    def _do_start_batch(self, t: float, fids: list[int]) -> None:
        if self._own_index:
            for fid in fids:
                self._pindex.add_flow(fid, self.flows[fid].ports)
        super()._do_start_batch(t, fids)

    def finish_flow(self, f, t: float) -> None:
        super().finish_flow(f, t)
        if self._own_index and f.fid in self._pindex.flow_pid:
            self._pindex.remove_flow(f.fid)

    # ------------------------------------------------------------------ #
    # main loop: lane windows between global barriers
    # ------------------------------------------------------------------ #
    def run(self, until: float = float("inf")) -> None:
        self.time_limit = until
        heap = self._heap
        while True:
            gtop = heap[0][0] if heap else math.inf
            if not self._lanes_have_events(until) and (not heap or gtop > until):
                break
            self._run_window(gtop, until)
            if not heap or heap[0][0] > until:
                continue
            # a clamped dispatch window may stop short of the barrier: the
            # global event must not run until every lane has drained up to it
            gtop = heap[0][0]
            if self._lanes_behind(gtop, until):
                continue
            t, _, kind, payload = heapq.heappop(heap)
            self.now = t
            self.events_processed += 1
            if kind == START:
                batch = [payload[0]]
                while heap and heap[0][0] == t and heap[0][2] == START:
                    _, _, _, pl = heapq.heappop(heap)
                    self.events_processed += 1
                    batch.append(pl[0])
                self._do_start_batch(t, batch)
            elif kind == SAMPLE:
                self._do_sample(t)
            elif kind == KERNEL:
                self.kernel.on_kernel_event(t, payload[0])
            elif kind == CALL:
                payload[0](t)
            else:  # a packet kind can only land here through a kernel bug
                raise RuntimeError(f"packet event kind {kind} in global lane")
            # splits during barrier processing (kernel reshapes on
            # completion/unpark) are fully applied by the observer itself;
            # the log is only for executors adopting splits *they* cause —
            # a stale entry would make the next window run a freshly
            # re-keyed lane in two executors at once
            self._split_log.clear()
            if self.validate:
                self.check_invariants()

    def _lanes_have_events(self, until: float) -> bool:
        if self._grave.heap and self._grave.heap[0][0] <= until:
            return True
        return any(ln.heap and ln.heap[0][0] <= until
                   for ln in self._lanes.values())

    def _lanes_behind(self, W: float, until: float) -> bool:
        """Any lane event strictly before the barrier still pending?"""
        if self._grave.heap and self._grave.heap[0][0] < W \
                and self._grave.heap[0][0] <= until:
            return True
        return any(ln.heap and ln.heap[0][0] < W and ln.heap[0][0] <= until
                   for ln in self._lanes.values())

    def _run_window(self, W: float, until: float) -> None:
        active = [ln for ln in itertools.chain(self._lanes.values(),
                                               (self._grave,))
                  if ln.heap and ln.heap[0][0] < W and ln.heap[0][0] <= until]
        if not active:
            return
        self.shard_stats["windows"] += 1
        if self.intra_workers <= 1:
            self._run_lanes_serial(active, W, until)
            return
        # Completion horizons clamp the dispatch barrier instead of pulling
        # whole lanes into the parent: windows thin out just before a flow
        # can possibly finish and fatten again right after, so the bulk of
        # every UNSTEADY partition's events still runs in the workers.
        heavy, light = [], []
        W_disp = W
        for ln in active:
            if ln is self._grave or math.isinf(W):
                light.append(ln)
                continue
            horizon = self._lane_safe_horizon(ln)
            if horizon <= ln.heap[0][0] + 0.25 * (W - ln.heap[0][0]):
                # completion-imminent: the parent runs this lane for the
                # whole window (completions + reshape are exact there);
                # clamping the shared barrier under it instead would
                # fragment everyone's window geometrically near each finish
                light.append(ln)
                continue
            W_disp = min(W_disp, horizon)
            heavy.append(ln)
        # cheap lanes aren't worth shipping: estimate the events the lane
        # will actually process inside the window (pending heap size is just
        # the in-flight set — a ramping flow holds 1 SEND yet generates
        # thousands of events per window)
        if heavy and not math.isinf(W_disp):
            still = []
            for ln in heavy:
                if self._lane_window_cost(ln, W_disp) >= self.intra_min_events:
                    still.append(ln)
                else:
                    light.append(ln)
            heavy = still
        if len(heavy) < 2 or self.intra_workers < 2:
            self._run_lanes_serial(active, W, until)
            return
        if W_disp < W:
            self.shard_stats["window_shrinks"] += 1
        self._run_window_parallel(heavy, light, W_disp, until)

    def _run_window_parallel(self, heavy: list[_Lane], light: list[_Lane],
                             W: float, until: float) -> None:
        """The parent is one of the ``intra_workers`` executors: it ships
        ``intra_workers - 1`` bins of heavy lanes to the pool, then runs the
        light lanes plus its own bin concurrently through the exact
        interleaved loop.  Worker results are merged only if the parent saw
        no barrier shrink (a completion spawning a global event inside the
        window); otherwise they are discarded unmerged and the worker lanes
        re-run serially — exactness is never at stake, only wall-clock."""
        cost = {ln.pid: self._lane_window_cost(ln, W) for ln in heavy}
        costed = sorted(heavy, key=lambda ln: -cost[ln.pid])
        nbins = min(self.intra_workers, len(costed))
        bins: list[list[_Lane]] = [[] for _ in range(nbins)]
        # the parent's bin (index 0) starts pre-loaded with the light lanes'
        # cost so the greedy packer hands it proportionally less heavy work
        loads = [0.0] * nbins
        loads[0] = sum(self._lane_window_cost(ln, W) for ln in light)
        for ln in costed:
            i = loads.index(min(loads))
            bins[i].append(ln)
            loads[i] += cost[ln.pid]
        futures = self._dispatch(bins[1:], W, until)
        W_eff = self._run_lanes_serial(light, W, until) if light else W
        if W_eff < W:
            # a light-lane completion spawned a global event inside the
            # window: the parent bin must stop there too, with the exact
            # (watermarked) loop — the tight path has no barrier bookkeeping
            self._run_lanes_serial(bins[0], W, until)
        else:
            self._run_lanes_tight(bins[0], W, until)
        gheap = self._heap
        shrunk = (W_eff < W) or (bool(gheap) and gheap[0][0] < W)
        results = [pickle.loads(f.result()) for f in futures]
        worker_lanes = [ln for group in bins[1:] for ln in group]
        if shrunk or any(res is None for res in results):
            # barrier moved (or a worker hit an "impossible" completion):
            # nothing was merged, so the worker lanes re-run exactly in the
            # parent, stopping at the (possibly shrunk) barrier
            self.shard_stats["serial_redos"] += 1
            self._run_lanes_serial(worker_lanes, W, until)
            return
        self._merge(worker_lanes, results)

    def _run_lanes_tight(self, lanes: list[_Lane], W: float,
                         until: float) -> None:
        """Lane-major fast path for the parent's own bin of heavy lanes —
        the in-process mirror of the worker loop.  No frontier interleaving
        (the lanes are port-disjoint, so their events commute) and no
        watermarks: the safe-horizon bound excludes completions below W.
        Should one fire anyway, the split is adopted, execution stops at
        the new global event, and the caller's shrink check re-runs the
        worker lanes; lanes of this bin that finished *before* the
        completion have then overrun the new barrier — the one residual
        inexactness, reachable only if the physical delivery bound
        (delivered <= inflight + retx + 1.05*line_rate*dur) is violated."""
        gheap = self._heap
        stats = self.shard_stats
        work = deque(ln.pid for ln in lanes)
        while work:
            pid = work.popleft()
            ln = self._lanes.get(pid)
            if ln is None:
                continue
            heap = ln.heap
            defunct = False
            while heap and heap[0][0] < W and heap[0][0] <= until:
                # drain the whole same-timestamp run under this one bound
                # check; popped events execute in (t, seq) order regardless
                # of a mid-run split (they are the top of the run with the
                # smallest seqs — anything redistributed or newly scheduled
                # orders after them, exactly as in the serial loop)
                run = ln.pop_run()
                width = len(run)
                if width > 1:
                    stats["batched_drains"] += 1
                    if width > stats["max_batch_width"]:
                        stats["max_batch_width"] = width
                for (t, _s, kind, payload) in run:
                    _exec_packet_event(self, t, kind, payload)
                    if self._split_log:
                        # an "impossible" completion split this lane: its
                        # remaining events moved to the residual lanes
                        for old_pid, new_pids in self._split_log:
                            if old_pid == pid:
                                defunct = True
                            work.extend(new_pids)
                        self._split_log.clear()
                if defunct:
                    break
            if gheap and gheap[0][0] < W:
                return        # barrier moved under us: stop at it

    def _lane_window_cost(self, ln: _Lane, W: float) -> float:
        """Rough events-in-window estimate: pending events plus ~4 hop/ack
        events per MTU the lane's live flows deliver over the window."""
        dur = max(0.0, W - ln.heap[0][0])
        rate = 0.0
        for fid in self._pindex.parts.get(ln.pid, ()):
            f = self.flows[fid]
            if not f.done and not f.parked and f.started:
                rate += f.cca.rate()
        return len(ln.heap) + 4.0 * rate * dur / self.mtu

    def _lane_safe_horizon(self, ln: _Lane) -> float:
        """Latest barrier up to which no member flow can possibly finish:
        ``delivered`` grows by ACKed bytes, physically capped by what was
        already in flight plus what the flow's bottleneck port can drain
        (line_rate · dur; 1.05x margin).  A worker that finishes a flow
        anyway aborts the dispatch, so this bound is a fast path, not a
        correctness axiom."""
        t0 = ln.heap[0][0]
        horizon = math.inf
        for fid in self._pindex.parts.get(ln.pid, ()):
            f = self.flows[fid]
            if f.done or f.parked or not f.started:
                continue
            slack = f.remaining() - f.inflight - f.retx - 2 * self.mtu
            if slack <= 0:
                return t0
            horizon = min(horizon, t0 + slack / (1.05 * f.cca.line_rate))
        return horizon

    # -- exact interleaved execution (parent side) ----------------------- #
    def _run_lanes_serial(self, lanes: list[_Lane], W: float,
                          until: float) -> float:
        """Run ``lanes`` in merged time order up to the barrier ``W``
        (exclusive).  If processing spawns a *new* global event below W, the
        barrier shrinks to it; lane events at exactly the shrunk barrier are
        processed only if they were already scheduled when it appeared
        (seq watermark) — precisely the serial loop's (t, seq) tie order."""
        gheap = self._heap
        pids = {ln.pid for ln in lanes}
        frontier = [(ln.heap[0][0], ln.heap[0][1], ln.pid) for ln in lanes]
        heapq.heapify(frontier)
        W_eff = W
        snap: dict[int, int] | None = None   # pid -> seq watermark at shrink
        if gheap and gheap[0][0] < W_eff:
            # a global event already sits inside the window (serial redo
            # after a shrink): everything pending predates it, anything
            # generated from here on is younger — watermark accordingly
            W_eff = gheap[0][0]
            snap = {ln.pid: ln.seq for ln in lanes}
        stats = self.shard_stats
        while frontier:
            _t, _s, pid = heapq.heappop(frontier)
            ln = self._lanes.get(pid) if pid != GRAVE else self._grave
            if ln is None or pid not in pids or not ln.heap:
                continue
            # batch: stay on this lane while its top is not later than any
            # other lane's (same-t cross-lane order commutes — no shared
            # ports), skipping the frontier churn for event bursts
            nb_t = frontier[0][0] if frontier else math.inf
            rebalance = False
            defunct = False
            while ln.heap:
                t, s, _kind, _payload = ln.heap[0]
                if t > until or t > W_eff or (
                        t == W_eff and (snap is None or s > snap.get(pid, -1))):
                    break          # lane rests at the barrier
                if t > nb_t:
                    rebalance = True
                    break          # another lane is earlier now
                # drain the whole same-timestamp run under the one bound
                # check above; at the shrunk barrier the seq watermark rides
                # into pop_run so post-shrink events rest in the lane
                run = ln.pop_run(snap.get(pid, -1)
                                 if (snap is not None and t == W_eff)
                                 else None)
                width = len(run)
                if width > 1:
                    stats["batched_drains"] += 1
                    if width > stats["max_batch_width"]:
                        stats["max_batch_width"] = width
                for (t, s, kind, payload) in run:
                    if self.validate and not defunct and ln is not self._grave:
                        assert payload[0] in self._pindex.parts.get(pid, ()), \
                            f"lane {pid} executed foreign flow {payload[0]}"
                    _exec_packet_event(self, t, kind, payload)
                    if self._split_log:
                        # a completion split this (or another) lane: adopt
                        # the residual lanes into the window's working set.
                        # Already-popped run events still execute here, in
                        # order — they are same-t with the smallest seqs, so
                        # everything the split redistributed (renumbered
                        # compactly, order-preserving) and everything newly
                        # scheduled sorts after them, exactly as serially.
                        for old_pid, new_pids in self._split_log:
                            if old_pid not in pids:
                                continue
                            pids.discard(old_pid)
                            defunct = defunct or old_pid == pid
                            for p2 in new_pids:
                                pids.add(p2)
                                l2 = self._lanes.get(p2)
                                if l2 is not None and l2.heap:
                                    heapq.heappush(
                                        frontier,
                                        (l2.heap[0][0], l2.heap[0][1], p2))
                        self._split_log.clear()
                    # a new global event inside the window shrinks the
                    # barrier; the watermark freezes "scheduled before it"
                    # per lane (the run's own events predate the shrink by
                    # construction, so finishing it stays exact)
                    if gheap and gheap[0][0] < W_eff:
                        W_eff = gheap[0][0]
                        snap = {}
                        # sorted: watermark snapshot order is pid order
                        for p2 in sorted(pids):
                            l2 = (self._lanes.get(p2) if p2 != GRAVE
                                  else self._grave)
                            if l2 is not None:
                                snap[p2] = l2.seq
                if defunct:
                    break          # this lane object is defunct now
            if rebalance and ln.heap:
                heapq.heappush(frontier, (ln.heap[0][0], ln.heap[0][1], pid))
        return W_eff

    # -- parallel fan-out (worker side lives at module level) ------------ #
    def _ensure_pool(self) -> ProcessPoolExecutor:
        if self._pool is None:
            # pools are shared process-wide by worker count (spawn startup
            # is ~0.5 s/worker — per run it would dominate short scenarios
            # and every sweep iteration); the per-topology shell rides with
            # each task and is cached worker-side by shell key
            self._shell_key = next(_SHELL_KEYS)
            self._shell_blob = pickle.dumps(
                (self.topo, {"mtu": self.mtu, "ecn_k": self.ecn_k,
                             "buffer_bytes": self.buffer_bytes,
                             "window": self.window,
                             "sample_interval": self.sample_interval}),
                protocol=pickle.HIGHEST_PROTOCOL)
            self._pool = _shared_pool(max(1, self.intra_workers - 1))
        return self._pool

    def _dispatch(self, bins: list[list[_Lane]], W: float, until: float):
        """Ship each bin of heavy lanes as one worker task (a single
        submit/collect round-trip per worker per window) and return the
        futures — the parent overlaps its own bin while they run."""
        pool = self._ensure_pool()
        futures = []
        for group in bins:
            tasks = []
            for ln in group:
                fids = sorted(self._pindex.parts[ln.pid])
                ports = set()
                for fid in fids:
                    ports |= self._pindex.flow_ports[fid]
                # sorted ports: the pickled task payload is byte-stable
                # across runs, not a function of set order
                tasks.append((ln.pid,
                              {fid: self.flows[fid] for fid in fids},
                              ln.heap, ln.seq,
                              {p: float(self.busy_until[p])
                               for p in sorted(ports)},
                              {p: float(self.port_txbytes[p])
                               for p in sorted(ports)},
                              self.record_rtt_fids.intersection(fids)))
            futures.append(pool.submit(
                _worker_run_lanes, self._shell_key, self._shell_blob,
                pickle.dumps((W, until, tasks),
                             protocol=pickle.HIGHEST_PROTOCOL)))
        return futures

    def _merge(self, lanes: list[_Lane], results) -> None:
        lane_by_pid = {ln.pid: ln for ln in lanes}
        stats = self.shard_stats
        for res in results:
            for (pid, flows, lheap, seq, busy, txb, nev, nhop,
                 ndrain, wmax) in res:
                ln = lane_by_pid[pid]
                for fid, f in flows.items():
                    self.flows[fid] = f
                ln.heap = lheap
                ln.seq = seq
                for p, v in busy.items():
                    self.busy_until[p] = v
                for p, v in txb.items():
                    self.port_txbytes[p] = v
                self.events_processed += nev
                self.packet_hop_events += nhop
                stats["dispatched_events"] += nev
                stats["batched_drains"] += ndrain
                if wmax > stats["max_batch_width"]:
                    stats["max_batch_width"] = wmax
        stats["dispatches"] += len(lane_by_pid)

    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        """Lane/partition exclusivity (property tests): every pending lane
        event belongs to its partition's flows, graveyard events only to
        finished flows, and the index satisfies Definition 1."""
        self._pindex.check_invariants()
        for pid, ln in self._lanes.items():
            fids = self._pindex.parts.get(pid)
            if fids is None:
                assert not ln.heap, f"orphan lane {pid} holds events"
                continue
            for (_t, _s, _k, payload) in ln.heap:
                assert payload[0] in fids, \
                    f"lane {pid} holds event of foreign flow {payload[0]}"
        for (_t, _s, _k, payload) in self._grave.heap:
            f = self.flows.get(payload[0])
            assert f is None or f.done, "graveyard holds a live flow's event"

    def shard_report(self) -> dict:
        out = dict(self.shard_stats)
        out["intra_workers"] = self.intra_workers
        out["lanes_live"] = sum(1 for ln in self._lanes.values() if ln.heap)
        return out

    def close(self) -> None:
        # the pool is shared process-wide (see _shared_pool) — just drop
        # the reference; shutdown_pools() tears the executors down
        self._pool = None


# ---------------------------------------------------------------------- #
# shared worker pools: spawn startup (~0.5 s/worker: fresh interpreter +
# numpy import) amortizes across every sharded run in the process instead
# of recurring per ShardedPacketSim
# ---------------------------------------------------------------------- #
_SHELL_KEYS = itertools.count(1)
_POOLS: dict[int, ProcessPoolExecutor] = {}


def _shared_pool(n_workers: int) -> ProcessPoolExecutor:
    pool = _POOLS.get(n_workers)
    if pool is None:
        import atexit
        import multiprocessing
        # spawn, not fork: the parent may hold live jax/XLA threads (fluid
        # sweeps earlier in the session); workers import only the
        # packet-path modules
        ctx = multiprocessing.get_context("spawn")
        pool = _POOLS[n_workers] = ProcessPoolExecutor(
            max_workers=n_workers, mp_context=ctx)
        if len(_POOLS) == 1:
            atexit.register(shutdown_pools)
    return pool


def shutdown_pools() -> None:
    """Tear down the process-wide lane-worker pools (atexit does this too)."""
    for pool in _POOLS.values():
        pool.shutdown(wait=False, cancel_futures=True)
    _POOLS.clear()


# ---------------------------------------------------------------------- #
# worker side: a bare PacketSim shell executes one lane group per task
# ---------------------------------------------------------------------- #
class _LaneCompleted(Exception):
    """A flow finished inside a worker — the completion-reshape barrier
    belongs to the parent, so the task aborts and the window is redone."""


class _LaneSim(PacketSim):
    # hot class (reprolint H205/C304): adds no attributes of its own
    __slots__ = ()

    def finish_flow(self, f, t: float) -> None:
        raise _LaneCompleted


_SHELLS: dict[int, _LaneSim] = {}   # per-worker cache, keyed by shell key


def _worker_shell(key: int, shell_blob: bytes) -> _LaneSim:
    shell = _SHELLS.get(key)
    if shell is None:
        topo, knobs = pickle.loads(shell_blob)
        if len(_SHELLS) >= 4:        # a handful of live sims is plenty
            _SHELLS.pop(next(iter(_SHELLS)))
        shell = _SHELLS[key] = _LaneSim(topo, **knobs)
    return shell


def _worker_run_lanes(key: int, shell_blob: bytes, blob: bytes) -> bytes:
    """Execute a group of lanes' packet events up to the barrier W
    (exclusive), one lane after another.  Lane state rides in and out
    through pickle; each lane-local seq counter continues exactly where the
    parent left it, so ordering is identical to parent-side execution.
    Returns None (abort) if any lane completes a flow — the completion
    reshape belongs to the parent."""
    W, until, tasks = pickle.loads(blob)
    sim = _worker_shell(key, shell_blob)
    out = []
    aborted = False
    for (pid, flows, lheap, seq, busy, txb, rtt) in tasks:
        sim.flows = flows
        sim.record_rtt_fids = rtt
        sim.events_processed = 0
        sim.packet_hop_events = 0
        sim._heap = lheap             # lane heap IS the worker's only heap
        sim._seq = seq + 1                # next seq value to hand out
        for p, v in busy.items():
            sim.busy_until[p] = v
        for p, v in txb.items():
            sim.port_txbytes[p] = v
        heap = lheap
        ndrain = 0
        wmax = 0
        try:
            while heap and heap[0][0] < W and heap[0][0] <= until:
                # batched run drain (abort discards everything, so popping
                # the run ahead of execution risks nothing)
                t0 = heap[0][0]
                t, _s, kind, payload = heapq.heappop(heap)
                _exec_packet_event(sim, t, kind, payload)
                width = 1
                while heap and heap[0][0] == t0:
                    t, _s, kind, payload = heapq.heappop(heap)
                    _exec_packet_event(sim, t, kind, payload)
                    width += 1
                if width > 1:
                    ndrain += 1
                    if width > wmax:
                        wmax = width
        except _LaneCompleted:
            aborted = True
        if not aborted:
            out.append((pid, flows, heap, sim._seq - 1,
                        {p: float(sim.busy_until[p]) for p in busy},
                        {p: float(sim.port_txbytes[p]) for p in txb},
                        sim.events_processed, sim.packet_hop_events,
                        ndrain, wmax))
        # reset the shell's port state for the next lane/task
        for p in busy:
            sim.busy_until[p] = 0.0
            sim.port_txbytes[p] = 0.0
        sim.now = 0.0
        if aborted:
            break
    sim.flows = {}
    return pickle.dumps(None if aborted else out,
                        protocol=pickle.HIGHEST_PROTOCOL)
