"""Congestion-control algorithms for the packet-level oracle.

Four mainstream DC CCAs (the paper's set, §1/§7): DCTCP [SIGCOMM'10],
DCQCN [SIGCOMM'15], TIMELY [SIGCOMM'15], HPCC [SIGCOMM'19].

Unified sender model: every flow paces packets at ``rate()`` bytes/s subject
to ``inflight < cwnd()``.  Window CCAs derive the pacing rate as cwnd/srtt;
rate CCAs keep a large window and control the rate directly.  Each CCA's
``on_ack`` consumes (ecn_mark, rtt, int_info) and updates internal state.
``rate()`` is the unified metric R the steady-state detector monitors (§5.1.1).
"""
from __future__ import annotations

import dataclasses

MTU = 1000.0  # bytes per packet in the scaled oracle


@dataclasses.dataclass(slots=True)
class INTInfo:
    """In-network telemetry carried by HPCC packets: max per-hop 'inflight'
    utilisation along the path (queue + BDP share)."""
    max_util: float = 0.0


class CCA:
    """Base class.  Subclasses mutate self.r (bytes/s) and self.w (bytes).

    One instance lives per flow and its attributes churn on every ACK, so
    the whole hierarchy is slotted — no per-instance ``__dict__``, smaller
    objects, faster attribute access on the hot ``on_ack`` path."""

    __slots__ = ("line_rate", "base_rtt", "r", "w", "srtt")

    name = "base"
    uses_int = False
    # window-based CCAs control via self.w (rate derived as w/srtt); rate
    # CCAs control self.r directly and keep w as a loose in-flight cap —
    # state restoration after a memo replay must respect the difference
    window_based = True
    # steady-state relative rate-fluctuation hint for the detector's θ
    # guidance (None -> use the paper's DCTCP sawtooth formula, Eq. 11)
    steady_eps_hint: float | None = None

    def __init__(self, line_rate: float, base_rtt: float) -> None:
        self.line_rate = line_rate
        self.base_rtt = base_rtt
        self.r = line_rate            # current pacing rate (bytes/s)
        self.w = line_rate * base_rtt  # window (bytes)
        self.srtt = base_rtt

    # -- sender interface ------------------------------------------------ #
    def rate(self) -> float:
        r = self.r
        return r if r >= MTU else MTU  # floor: 1 pkt/s

    def cwnd(self) -> float:
        w = self.w
        return w if w >= MTU else MTU

    def on_ack(self, now: float, acked: float, ecn: bool, rtt: float,
               int_info: INTInfo | None = None) -> None:
        self.srtt = 0.875 * self.srtt + 0.125 * rtt
        self._update(now, acked, ecn, rtt, int_info)

    def _update(self, now, acked, ecn, rtt, int_info) -> None:  # pragma: no cover
        raise NotImplementedError


class DCTCP(CCA):
    """Window-based; ECN fraction alpha, multiplicative cut once per RTT."""

    name = "dctcp"
    __slots__ = ("g", "alpha", "_acked", "_ecn_acked", "_win_end_bytes",
                 "_total_acked")

    def __init__(self, line_rate: float, base_rtt: float, g: float = 1 / 16) -> None:
        super().__init__(line_rate, base_rtt)
        self.g = g
        self.alpha = 1.0
        self._acked = 0.0
        self._ecn_acked = 0.0
        self._win_end_bytes = self.w  # next alpha-update boundary (in acked bytes)
        self._total_acked = 0.0

    def _update(self, now, acked, ecn, rtt, int_info) -> None:
        self._acked += acked
        self._total_acked += acked
        if ecn:
            self._ecn_acked += acked
        if self._total_acked >= self._win_end_bytes:
            frac = self._ecn_acked / max(self._acked, 1.0)
            self.alpha = (1 - self.g) * self.alpha + self.g * frac
            if frac > 0:
                self.w = max(MTU, self.w * (1 - self.alpha / 2))
            else:
                self.w = min(self.line_rate * self.base_rtt * 1.2, self.w + MTU)
            self._acked = 0.0
            self._ecn_acked = 0.0
            self._win_end_bytes = self._total_acked + self.w
        s = self.srtt
        self.r = self.w / (s if s >= 1e-9 else 1e-9)


class DCQCN(CCA):
    """Rate-based; ECN-driven alpha with multiplicative decrease and
    fast-recovery/additive-increase stages (simplified NP/RP model)."""

    name = "dcqcn"
    __slots__ = ("g", "alpha", "rt", "_last_cut", "_last_inc", "_inc_stage",
                 "timer", "rai")
    window_based = False
    steady_eps_hint = 0.10   # cut/recover sawtooth amplitude

    def __init__(self, line_rate: float, base_rtt: float, g: float = 1 / 16) -> None:
        super().__init__(line_rate, base_rtt)
        self.g = g
        self.alpha = 1.0
        self.rt = line_rate           # target rate
        self._last_cut = -1.0
        self._last_inc = 0.0
        self._inc_stage = 0
        # rate-increase timer scaled to the simulated RTT (real DCQCN uses
        # 55us against ~50us fabric RTTs; keep the same ratio)
        self.timer = max(4 * base_rtt, 8e-6)
        self.rai = line_rate / 100.0  # additive increase step

    def _update(self, now, acked, ecn, rtt, int_info) -> None:
        if ecn:
            self.alpha = (1 - self.g) * self.alpha + self.g
            if now - self._last_cut > self.base_rtt:  # at most one cut per RTT
                self.rt = self.r
                self.r = max(self.r * (1 - self.alpha / 2), self.line_rate / 1000)
                self._last_cut = now
                self._inc_stage = 0
                self._last_inc = now
        else:
            self.alpha = (1 - self.g) * self.alpha
            if now - self._last_inc > self.timer:
                self._last_inc = now
                self._inc_stage += 1
                if self._inc_stage <= 5:          # fast recovery toward rt
                    self.r = (self.r + self.rt) / 2
                else:                             # additive increase
                    self.rt = min(self.line_rate, self.rt + self.rai)
                    self.r = (self.r + self.rt) / 2
            self.r = min(self.r, self.line_rate)
        self.w = 1.5 * self.line_rate * self.base_rtt  # loose cap; rate-controlled


class TIMELY(CCA):
    """Rate-based on RTT gradient [SIGCOMM'15] (no HAI mode)."""

    name = "timely"
    __slots__ = ("beta", "delta", "_prev_rtt", "t_low", "t_high",
                 "_ewma_grad")
    window_based = False
    steady_eps_hint = 0.05

    def __init__(self, line_rate: float, base_rtt: float,
                 beta: float = 0.45, delta_frac: float = 1 / 150) -> None:
        super().__init__(line_rate, base_rtt)
        self.beta = beta
        self.delta = line_rate * delta_frac
        self._prev_rtt = base_rtt
        self.t_low = base_rtt * 1.1
        self.t_high = base_rtt * 3.0
        self._ewma_grad = 0.0

    def _update(self, now, acked, ecn, rtt, int_info) -> None:
        grad = (rtt - self._prev_rtt) / max(self.base_rtt, 1e-9)
        self._prev_rtt = rtt
        self._ewma_grad = 0.875 * self._ewma_grad + 0.125 * grad
        if rtt < self.t_low:
            self.r = min(self.line_rate, self.r + self.delta)
        elif rtt > self.t_high:
            self.r = max(self.line_rate / 1000, self.r * (1 - self.beta * (1 - self.t_high / rtt)))
        elif self._ewma_grad <= 0:
            self.r = min(self.line_rate, self.r + self.delta)
        else:
            self.r = max(self.line_rate / 1000, self.r * (1 - self.beta * self._ewma_grad))
        self.w = 1.5 * self.line_rate * self.base_rtt


class HPCC(CCA):
    """INT-based [Li et al., SIGCOMM'19, Algorithm 1]: per-ACK
    ``W = Wc/(U/η) + W_AI`` against a reference window Wc updated once per
    RTT; U is the EWMA (α = ack-interval/T) of the max per-hop utilisation
    ``min(qlen, qlen_prev)/(B·T) + txRate/B`` carried back by telemetry."""

    name = "hpcc"
    __slots__ = ("eta", "w_ref", "w_ai", "max_stage", "_stage", "_u_ewma",
                 "_last_ack_t", "_total_acked", "_update_seq", "_w_cap")
    uses_int = True
    # window-based with a DCTCP-like sawtooth: use the Eq.11 guidance
    # (steady_eps_hint=None); the drift guard handles convergence ramps

    def __init__(self, line_rate: float, base_rtt: float,
                 eta: float = 0.95, max_stage: int = 5) -> None:
        super().__init__(line_rate, base_rtt)
        self.eta = eta
        self.w_ref = self.w
        self.w_ai = MTU / 2
        self.max_stage = max_stage
        self._stage = 0
        self._u_ewma = eta
        self._last_ack_t = 0.0
        self._total_acked = 0.0
        self._update_seq = 0.0          # snd_nxt proxy at last Wc update
        self._w_cap = 1.05 * line_rate * base_rtt + max_stage * self.w_ai

    def _update(self, now, acked, ecn, rtt, int_info) -> None:
        # hot per-ACK recursion: min/max spelled as conditionals (identical
        # values, including ties) — builtin-call overhead is measurable here
        self._total_acked += acked
        u = int_info.max_util if int_info is not None else (1.5 if ecn else self.eta)
        dt = now - self._last_ack_t
        if dt < 1e-12:
            dt = 1e-12
        tau = dt / self.base_rtt
        if tau > 1.0:
            tau = 1.0
        self._last_ack_t = now
        self._u_ewma = (1 - tau) * self._u_ewma + tau * u
        update_wc = self._total_acked >= self._update_seq
        if self._u_ewma >= self.eta or self._stage >= self.max_stage:
            d = self._u_ewma / self.eta
            w = self.w_ref / (d if d >= 0.2 else 0.2) + self.w_ai
            if update_wc:
                self._stage = 0
        else:
            w = self.w_ref + self.w_ai
            if update_wc:
                self._stage += 1
        if w < MTU:
            w = MTU
        cap = self._w_cap
        self.w = w = w if w <= cap else cap
        if update_wc:
            self.w_ref = w
            self._update_seq = self._total_acked + w  # ≈ snd_nxt
        s = self.srtt
        self.r = w / (s if s >= 1e-9 else 1e-9)


CCA_REGISTRY: dict[str, type[CCA]] = {
    c.name: c for c in (DCTCP, DCQCN, TIMELY, HPCC)
}


def make_cca(name: str, line_rate: float, base_rtt: float) -> CCA:
    try:
        return CCA_REGISTRY[name](line_rate, base_rtt)
    except KeyError:
        raise ValueError(f"unknown CCA {name!r}; have {sorted(CCA_REGISTRY)}") from None
