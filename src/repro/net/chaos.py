"""Deterministic chaos: declarative, seeded perturbation injectors.

``Scenario.chaos`` is a list of plain dicts that JSON round-trips with
the scenario, so perturbations are part of the content-addressed run key
and every engine derives the *same* perturbations from the same
declaration:

* phase-level injectors (``mice``, ``straggler``) are expanded by
  ``Scenario.build_phases`` into the phase DAG itself — dep-free mouse
  phases with ``compute=arrival_time``, per-rank compute multipliers —
  so packet, wormhole, hybrid, sharded, fluid, and analytic backends all
  drive identical perturbed programs;
* link-level injectors (``degrade_link``, ``link_flap``, ``link_down``)
  retarget port capacities mid-run.  They install as CALL events on the
  packet-family simulators (the sharded loop executes CALLs at global
  barriers, so every lane observes the change atomically) and notify the
  kernel via ``SimKernel.on_chaos`` — wormhole skips affected parked
  partitions back to packet fidelity, hybrid promotes affected flow
  lanes.  Flow-level backends refuse them: they have no port queues to
  degrade, and silently dropping a declared perturbation would be worse.

Injector dicts (all randomness comes from ``numpy.random.default_rng``
seeded with the injector's own ``seed`` — runs are bit-reproducible):

    {"kind": "mice", "seed": 0, "rate": 2000.0, "size": 20000.0,
     "start": 0.0, "duration": 0.01, "cca": "dctcp"}
        Poisson mouse flows (mean interarrival 1/rate) between uniformly
        random distinct hosts.

    {"kind": "straggler", "seed": 0, "count": 2, "factor": 1.5}
    {"kind": "straggler", "ranks": [3, 7], "factor": 1.5}
        Per-rank compute multipliers (workload scenarios only): explicit
        ``ranks``, or ``count`` ranks drawn without replacement.

    {"kind": "degrade_link", "link": 12, "t": 0.002, "factor": 0.25}
        Port 12 drops to 25% capacity at t=2ms; optional ``t_end``
        restores full capacity.

    {"kind": "link_flap", "link": 12, "t_down": 0.002, "t_up": 0.004}
        Capacity collapses to ``DOWN_FACTOR`` x base (arrivals overflow
        the port buffer and drop — the packet-level signature of a dead
        port) and recovers at ``t_up``.

    {"kind": "link_down", "link": 12, "t": 0.002}
        A flap that never recovers; pair with an ``until=`` horizon or a
        workload whose remaining flows avoid the port.

An empty injector list is the identity: no phases are added and nothing
is installed, so ``chaos=[]`` scenarios are bit-identical to pre-chaos
runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.net.flows import FlowSpec
from repro.workload.traffic import Phase

# Mouse-flow ids start far above any workload/collective allocation
# (FidAlloc counts up from 0) so the two id spaces can never collide.
CHAOS_FID_BASE = 1 << 20

# A "down" link keeps this fraction of its capacity: the queue horizon
# becomes astronomically long, new arrivals overflow the buffer and drop,
# but every rate stays finite (and below the lane-horizon safety bound).
DOWN_FACTOR = 1e-7

KINDS = ("mice", "straggler", "degrade_link", "link_flap", "link_down")

# backends with no port queues — link chaos is meaningless there
FLOW_LEVEL_BACKENDS = ("fluid", "analytic", "learned")


@dataclasses.dataclass(frozen=True)
class LinkEvent:
    """At time ``t``, port ``link`` runs at ``factor`` x its base capacity."""
    t: float
    link: int
    factor: float

    def __post_init__(self) -> None:
        if not 0.0 < self.factor <= 1.0:
            raise ValueError(f"link factor must be in (0, 1], got {self.factor}")
        if self.t < 0.0:
            raise ValueError(f"link event time must be >= 0, got {self.t}")


@dataclasses.dataclass
class ChaosPlan:
    """A parsed, validated ``Scenario.chaos`` declaration."""
    mice: list[dict]
    stragglers: list[dict]
    link_events: list[LinkEvent]

    @classmethod
    def parse(cls, chaos: list[dict]) -> ChaosPlan:
        mice: list[dict] = []
        stragglers: list[dict] = []
        links: list[LinkEvent] = []
        for i, inj in enumerate(chaos or []):
            if not isinstance(inj, dict) or "kind" not in inj:
                raise ValueError(
                    f"chaos[{i}]: each injector is a dict with a 'kind' key")
            kind = inj["kind"]
            if kind == "mice":
                _keys(i, inj, {"kind", "seed", "rate", "size"},
                      {"start", "duration", "cca"})
                if float(inj["rate"]) <= 0 or float(inj["size"]) <= 0:
                    raise ValueError(f"chaos[{i}]: mice rate/size must be > 0")
                mice.append(inj)
            elif kind == "straggler":
                _keys(i, inj, {"kind", "factor"}, {"seed", "count", "ranks"})
                if ("ranks" in inj) == ("seed" in inj):
                    raise ValueError(f"chaos[{i}]: straggler takes explicit "
                                     "'ranks' or a 'seed' (+ optional 'count'), "
                                     "not both / neither")
                if float(inj["factor"]) <= 0:
                    raise ValueError(f"chaos[{i}]: straggler factor must be > 0")
                stragglers.append(inj)
            elif kind == "degrade_link":
                _keys(i, inj, {"kind", "link", "t", "factor"}, {"t_end"})
                t = float(inj["t"])
                links.append(LinkEvent(t, int(inj["link"]), float(inj["factor"])))
                if "t_end" in inj:
                    t_end = float(inj["t_end"])
                    if t_end <= t:
                        raise ValueError(f"chaos[{i}]: t_end must be > t")
                    links.append(LinkEvent(t_end, int(inj["link"]), 1.0))
            elif kind == "link_flap":
                _keys(i, inj, {"kind", "link", "t_down", "t_up"}, set())
                t_down, t_up = float(inj["t_down"]), float(inj["t_up"])
                if t_up <= t_down:
                    raise ValueError(f"chaos[{i}]: t_up must be > t_down")
                links.append(LinkEvent(t_down, int(inj["link"]), DOWN_FACTOR))
                links.append(LinkEvent(t_up, int(inj["link"]), 1.0))
            elif kind == "link_down":
                _keys(i, inj, {"kind", "link", "t"}, set())
                links.append(LinkEvent(float(inj["t"]), int(inj["link"]),
                                       DOWN_FACTOR))
            else:
                raise ValueError(
                    f"chaos[{i}]: unknown kind {kind!r}; choose from {KINDS}")
        links.sort(key=lambda ev: (ev.t, ev.link))
        return cls(mice=mice, stragglers=stragglers, link_events=links)

    # ---------------- phase-level injectors ---------------- #

    def straggler_map(self, n_ranks: int) -> dict[int, float] | None:
        """Rank -> compute multiplier, merged across straggler injectors."""
        if not self.stragglers:
            return None
        out: dict[int, float] = {}
        for inj in self.stragglers:
            if "ranks" in inj:
                ranks = [int(r) for r in inj["ranks"]]
            else:
                rng = np.random.default_rng(int(inj["seed"]))
                count = min(int(inj.get("count", 1)), n_ranks)
                ranks = sorted(int(r) for r in
                               rng.choice(n_ranks, size=count, replace=False))
            factor = float(inj["factor"])
            for r in ranks:
                out[r] = out.get(r, 1.0) * factor
        return out

    def mice_phases(self, n_hosts: int,
                    fid_start: int = CHAOS_FID_BASE) -> list[Phase]:
        """Dep-free single-flow phases, one per Poisson arrival: the driver
        launches phase flows at ``t0 + compute``, so ``compute`` carries the
        arrival time."""
        phases: list[Phase] = []
        next_fid = fid_start
        for j, inj in enumerate(self.mice):
            rng = np.random.default_rng(int(inj["seed"]))
            rate = float(inj["rate"])
            size = float(inj["size"])
            start = float(inj.get("start", 0.0))
            duration = float(inj.get("duration", 0.01))
            cca = str(inj.get("cca", "dctcp"))
            t, k = start, 0
            while True:
                t += float(rng.exponential(1.0 / rate))
                if t > start + duration:
                    break
                src = int(rng.integers(n_hosts))
                dst = int(rng.integers(n_hosts - 1))
                if dst >= src:
                    dst += 1
                phases.append(Phase(
                    f"chaos.mice{j}.{k}",
                    [FlowSpec(next_fid, src, dst, size, 0.0, cca, "chaos.mice")],
                    [], t))
                next_fid += 1
                k += 1
        return phases

    # ---------------- link-level injectors ---------------- #

    @property
    def has_link_events(self) -> bool:
        return bool(self.link_events)

    def install(self, sim) -> None:
        """Arm the link events on a packet-family simulator as CALL events.

        The hot loops hoist ``_link_bw``/``busy_until`` as the same mutable
        lists, and CALL payloads run with counters flushed, so in-place item
        assignment from the closure is immediately visible — no special
        state on the simulator.
        """
        base = [float(bw) for bw in sim._link_bw]
        for ev in self.link_events:
            if not 0 <= ev.link < len(base):
                raise ValueError(f"chaos link {ev.link} out of range "
                                 f"(topology has {len(base)} ports)")
        for ev in self.link_events:
            sim.call_at(ev.t, _LinkSet(sim, ev.link, base[ev.link] * ev.factor))


class _LinkSet:
    """CALL payload: retarget one port's capacity, preserving the queued
    backlog in bytes, then tell the kernel which port changed."""

    __slots__ = ("sim", "link", "bw")

    def __init__(self, sim, link: int, bw: float) -> None:
        self.sim = sim
        self.link = link
        self.bw = bw

    def __call__(self, now: float) -> None:
        sim = self.sim
        lid = self.link
        old = sim._link_bw[lid]
        if old == self.bw:
            return
        busy = sim.busy_until[lid]
        if busy > now:
            # (busy - now) * old bytes sit queued on the port; re-express
            # that backlog at the new drain rate
            sim.busy_until[lid] = now + (busy - now) * (old / self.bw)
        sim._link_bw[lid] = self.bw
        sim.kernel.on_chaos(now, (lid,))


def plan_for(scenario) -> ChaosPlan | None:
    """Parse a scenario's chaos declaration (None when it has none)."""
    chaos = getattr(scenario, "chaos", None)
    return ChaosPlan.parse(chaos) if chaos else None


def check_backend(plan: ChaosPlan | None, backend: str,
                  intra_workers: int = 1) -> None:
    """Refuse configurations whose engine cannot honor declared link chaos."""
    if plan is None or not plan.link_events:
        return
    if backend in FLOW_LEVEL_BACKENDS:
        raise ValueError(
            f"backend {backend!r} has no port queues to degrade — link chaos "
            "(degrade_link/link_flap/link_down) needs a packet-family "
            "backend (packet/wormhole/hybrid)")
    if intra_workers > 1:
        raise ValueError(
            "link chaos requires intra_workers=1: dispatched lane workers "
            "rebuild port capacities from the pickled topology and would "
            "miss mid-run capacity changes")


def _keys(i: int, inj: dict, required: set, optional: set) -> None:
    have = set(inj)
    missing = required - have
    unknown = have - required - optional
    if missing or unknown:
        raise ValueError(
            f"chaos[{i}] ({inj.get('kind')}): "
            + (f"missing keys {sorted(missing)}" if missing else "")
            + (" and " if missing and unknown else "")
            + (f"unknown keys {sorted(unknown)}" if unknown else ""))
