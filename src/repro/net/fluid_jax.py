"""Vectorized JAX rate-dynamics engine — the TPU-native adaptation of the
packet loop (DESIGN.md §3).

A partition's contention math becomes dense linear algebra on the MXU:

    link arrivals   a = R @ M          (flows × links incidence)
    queueing        q ← clip(q + (a - C)·dt, 0, B)
    path signals    p_f = max_l  M ⊙ p_l     (ECN mark fraction)
    queue delay     d_f = (q / C) @ Mᵀ
    CCA fluid step  (DCTCP / rate-AIMD forms)

Used as (a) a fast transient solver, (b) a vmappable multi-experiment sweep
engine (the TPU analogue of running independent sims on spare cores, §6.1),
and (c) the host of the fused ``cca_step`` Pallas kernel.  It is an
*approximation* of the per-packet oracle (validated to ~10% on convergence
rates) — the paper-faithful error claims all come from Wormhole-on-oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.net.topology import Topology


@dataclasses.dataclass
class FluidScenario:
    """Dense arrays describing one partition (or a padded batch slot)."""
    incidence: np.ndarray      # [F, L] float32 0/1
    line_rate: np.ndarray      # [F] bytes/s
    base_rtt: np.ndarray       # [F] s
    size: np.ndarray           # [F] bytes
    link_bw: np.ndarray        # [L] bytes/s
    ecn_k: float = 64_000.0
    mss: float = 1000.0

    @classmethod
    def from_flows(cls, topo: Topology, flows: list[tuple[int, int, int, float]],
                   mtu: float = 1000.0, ecn_k: float = 64_000.0) -> "FluidScenario":
        """flows: (fid, src, dst, size)."""
        paths = [topo.route(s, d, fid) for fid, s, d, _ in flows]
        links = sorted({l for p in paths for l in p})
        lix = {l: i for i, l in enumerate(links)}
        M = np.zeros((len(flows), len(links)), np.float32)
        for i, p in enumerate(paths):
            for l in p:
                M[i, lix[l]] = 1.0
        bw = topo.link_bw[links].astype(np.float64)
        line = np.array([topo.link_bw[p].min() for p in paths])
        prop = np.array([topo.link_delay[p].sum() for p in paths])
        rtt = 2 * prop + (np.array([len(p) for p in paths]) + 1) * mtu / line
        return cls(incidence=M, line_rate=line, base_rtt=rtt,
                   size=np.array([f[3] for f in flows], np.float64),
                   link_bw=bw, ecn_k=ecn_k, mss=mtu)


@partial(jax.jit, static_argnames=("dt", "steps", "ecn_k", "mss", "g", "use_kernel"))
def fluid_run(M, line, rtt0, size, bw, dt: float, steps: int,
              ecn_k: float = 64_000.0, mss: float = 1000.0, g: float = 1 / 16,
              use_kernel: bool = False):
    """Advance DCTCP fluid dynamics `steps` control intervals.

    Returns dict with final rates, per-flow completion estimates, rate
    history [steps, F] and queue history [steps, L]."""
    F = M.shape[0]
    if use_kernel:
        from repro.kernels.cca_step.ops import cca_step as _step_fn

    def step(carry, _):
        R, W, alpha, delivered, q = carry
        if use_kernel:
            R2, W2, alpha2, delivered2, arrivals = _step_fn(
                R, W, alpha, delivered, size, line, rtt0, M, q, bw,
                dt=dt, g=g, ecn_k=ecn_k, mss=mss)
        else:
            p_l = jnp.clip((q - ecn_k) / (2 * ecn_k), 0.0, 1.0)
            qd = (q / bw) @ M.T                       # [F] queue delay
            rtt = rtt0 + qd
            p_f = jnp.max(M * p_l[None, :], axis=1)    # worst hop marks
            dtn = dt / rtt                             # round-trips this step
            alpha2 = (1 - g * dtn) * alpha + g * dtn * p_f
            grow = mss * dtn * (1 - p_f)
            cut = p_f * alpha * W / 2 * dtn
            W2 = jnp.clip(W + grow - cut, mss, 2 * line * rtt0)
            active = delivered < size
            R2 = jnp.where(active, jnp.minimum(W2 / rtt, line), 0.0)
            delivered2 = jnp.minimum(delivered + R2 * dt, size)
            arrivals = R2 @ M                          # [L] MXU matmul
        q2 = jnp.clip(q + (arrivals - bw) * dt, 0.0, 64 * ecn_k)
        return (R2, W2, alpha2, delivered2, q2), (R2, q2)

    R0 = line
    W0 = line * rtt0
    init = (R0, W0, jnp.ones(F), jnp.zeros(F), jnp.zeros_like(bw))
    (R, W, alpha, delivered, q), (rate_hist, q_hist) = jax.lax.scan(
        step, init, None, length=steps)
    return {"rates": R, "delivered": delivered, "queues": q,
            "rate_hist": rate_hist, "queue_hist": q_hist}


def fluid_converged_rates(scn: FluidScenario, dt: float | None = None,
                          steps: int = 400, use_kernel: bool = False):
    """Converged per-flow rates + convergence time estimate via the steady
    detector over the simulated rate history."""
    dt = dt if dt is not None else float(np.median(scn.base_rtt))
    # transient solve: rates are the question, so flows are unbounded here
    # (completion handling stays with the caller / the event kernel)
    unbounded = np.full_like(scn.size, np.inf)
    out = fluid_run(jnp.asarray(scn.incidence), jnp.asarray(scn.line_rate),
                    jnp.asarray(scn.base_rtt), jnp.asarray(unbounded),
                    jnp.asarray(scn.link_bw), dt, steps,
                    ecn_k=scn.ecn_k, mss=scn.mss, use_kernel=use_kernel)
    hist = np.asarray(out["rate_hist"])                # [steps, F]
    w = max(8, steps // 10)
    mx = hist[-w:].max(0)
    mn = hist[-w:].min(0)
    mean = hist[-w:].mean(0)
    fluct = np.where(mean > 0, (mx - mn) / np.maximum(mean, 1e-9), np.inf)
    # first step where every flow's trailing window is within 5%
    t_conv = steps * dt
    for t in range(w, steps):
        win = hist[t - w:t]
        m = win.mean(0)
        fl = np.where(m > 0, (win.max(0) - win.min(0)) / np.maximum(m, 1e-9), np.inf)
        if (fl < 0.05).all():
            t_conv = t * dt
            break
    return {"rates": mean, "fluct": fluct, "t_conv": t_conv, "hist": hist}


def sweep(scenarios: list[FluidScenario], dt: float, steps: int):
    """Multi-experiment parallelism: vmap over a padded batch of scenarios
    (the TPU analogue of Unison's spare-core experiments, §2.1)."""
    F = max(s.incidence.shape[0] for s in scenarios)
    L = max(s.incidence.shape[1] for s in scenarios)

    def pad(s: FluidScenario):
        M = np.zeros((F, L), np.float32)
        M[:s.incidence.shape[0], :s.incidence.shape[1]] = s.incidence
        def p1(x, n, fill):
            out = np.full(n, fill, np.float64)
            out[:len(x)] = x
            return out
        return (M, p1(s.line_rate, F, 1.0), p1(s.base_rtt, F, 1e-5),
                p1(s.size, F, 0.0), p1(s.link_bw, L, 1e12))

    Ms, lines, rtts, sizes, bws = (jnp.asarray(np.stack(x)) for x in
                                   zip(*[pad(s) for s in scenarios]))
    fn = jax.vmap(lambda M, l, r, s, b: fluid_run(M, l, r, s, b, dt, steps))
    return fn(Ms, lines, rtts, sizes, bws)


def sweep_converged_rates(scenarios: list[FluidScenario], dt: float = 1e-5,
                          steps: int = 200, window: int | None = None,
                          bounded: bool = False) -> list[np.ndarray]:
    """One vmapped sweep → per-scenario converged rates (trailing-window
    means), unpadded back to each scenario's true flow count.  With
    ``bounded=False`` (the default) flow sizes are lifted to ∞ so the
    answer is the contention equilibrium, not a completion artifact."""
    if not bounded:
        scenarios = [dataclasses.replace(
            s, size=np.full_like(np.asarray(s.size, np.float64), np.inf))
            for s in scenarios]
    out = sweep(scenarios, dt=dt, steps=steps)
    hist = np.asarray(out["rate_hist"])               # [n_scn, steps, F_pad]
    w = window if window is not None else max(8, steps // 10)
    means = hist[:, -w:, :].mean(axis=1)
    return [means[i, :s.incidence.shape[0]] for i, s in enumerate(scenarios)]
