"""Deterministic synthetic data pipeline (resumable, shardable)."""

from repro.data.pipeline import TokenPipeline
