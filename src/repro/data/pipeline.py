"""Synthetic token pipeline: deterministic per (seed, step, host-shard),
so (a) restarts reproduce the exact byte stream (checkpoint/restart
correctness is testable), and (b) elastic re-scales re-partition the same
global stream across a different host count (skip-ahead by global step).

The "documents" are Zipf-ish token draws with markov-ish structure so the
LM loss actually decreases during the example training runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    step: int = 0

    def __post_init__(self) -> None:
        assert self.global_batch % self.n_hosts == 0
        self.local_batch = self.global_batch // self.n_hosts
        # fixed "unigram" structure shared by every host
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._bigram_shift = rng.integers(1, self.vocab, size=257)

    def _batch_rng(self, step: int, sample: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + sample)

    def next_batch(self) -> dict:
        """dict(tokens, labels) int32 [local_batch, seq_len]."""
        out = np.empty((self.local_batch, self.seq_len + 1), np.int32)
        for i in range(self.local_batch):
            gsample = self.host_id * self.local_batch + i
            rng = self._batch_rng(self.step, gsample)
            toks = rng.choice(self.vocab, size=self.seq_len + 1, p=self._probs)
            # inject learnable bigram structure
            mask = rng.random(self.seq_len + 1) < 0.5
            shifted = (toks + self._bigram_shift[toks % 257]) % self.vocab
            toks = np.where(mask, np.roll(shifted, 1), toks)
            out[i] = toks
        self.step += 1
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}

    # ------------------------------------------------------------------ #
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict, n_hosts: int | None = None,
                host_id: int | None = None) -> None:
        """Resume; optionally re-partition over a different host count
        (elastic restart): the global stream continues identically because
        sample RNG keys are global (step, global_sample)."""
        self.step = state["step"]
        assert state["seed"] == self.seed, "seed mismatch on restore"
        if n_hosts is not None:
            assert self.global_batch % n_hosts == 0
            self.n_hosts = n_hosts
            self.host_id = host_id if host_id is not None else 0
            self.local_batch = self.global_batch // n_hosts
