"""Parallelism-group construction and rank→host placement.

Megatron-style rank order (tp fastest, then ep, dp, pp):
    rank = tp_idx + tp·(ep_idx + ep·(dp_idx + dp·pp_idx))

Each GPU is one simulated host (multi-NIC servers, paper §7).  With
tp == gpus_per_server a TP group occupies exactly one server, so TP/SP
traffic stays inside the NVLink domain and is not simulated (the paper's
setting: "existing works on LLM training simulation commonly neglect TP and
SP flows", §7); DP rings then connect the same intra-server position across
servers — i.e. they stay on one rail of a rail-optimized fabric.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    tp: int = 8
    dp: int = 1
    pp: int = 1
    ep: int = 1

    @property
    def world(self) -> int:
        return self.tp * self.dp * self.pp * self.ep

    def label(self) -> str:
        parts = [f"TP{self.tp}"]
        if self.ep > 1:
            parts.append(f"EP{self.ep}")
        parts += [f"DP{self.dp}", f"PP{self.pp}"]
        return "-".join(parts)


@dataclasses.dataclass
class Groups:
    par: ParallelismConfig
    dp_groups: list[list[int]]      # each: ranks forming one DP ring
    ep_groups: list[list[int]]      # each: ranks in one all-to-all domain
    pp_pairs: list[list[tuple[int, int]]]  # per stage boundary: (src, dst) ranks
    stage_of: dict[int, int]        # rank -> pipeline stage


def rank_of(cfg: ParallelismConfig, tp_i: int, ep_i: int, dp_i: int, pp_i: int) -> int:
    return tp_i + cfg.tp * (ep_i + cfg.ep * (dp_i + cfg.dp * pp_i))


def build_groups(cfg: ParallelismConfig) -> Groups:
    dp_groups, ep_groups = [], []
    stage_of: dict[int, int] = {}
    for pp_i in range(cfg.pp):
        for dp_i in range(cfg.dp):
            for ep_i in range(cfg.ep):
                for tp_i in range(cfg.tp):
                    stage_of[rank_of(cfg, tp_i, ep_i, dp_i, pp_i)] = pp_i
    # DP rings: fixed (tp, ep, pp), vary dp
    for pp_i in range(cfg.pp):
        for ep_i in range(cfg.ep):
            for tp_i in range(cfg.tp):
                g = [rank_of(cfg, tp_i, ep_i, dp_i, pp_i) for dp_i in range(cfg.dp)]
                if len(g) > 1:
                    dp_groups.append(g)
    # EP all-to-all domains: fixed (tp, dp, pp), vary ep
    for pp_i in range(cfg.pp):
        for dp_i in range(cfg.dp):
            for tp_i in range(cfg.tp):
                g = [rank_of(cfg, tp_i, ep_i, dp_i, pp_i) for ep_i in range(cfg.ep)]
                if len(g) > 1:
                    ep_groups.append(g)
    # PP boundaries: stage s rank -> same (tp, ep, dp) rank at stage s+1
    pp_pairs = []
    for pp_i in range(cfg.pp - 1):
        pairs = []
        for dp_i in range(cfg.dp):
            for ep_i in range(cfg.ep):
                for tp_i in range(cfg.tp):
                    pairs.append((rank_of(cfg, tp_i, ep_i, dp_i, pp_i),
                                  rank_of(cfg, tp_i, ep_i, dp_i, pp_i + 1)))
        pp_pairs.append(pairs)
    return Groups(par=cfg, dp_groups=dp_groups, ep_groups=ep_groups,
                  pp_pairs=pp_pairs, stage_of=stage_of)
