"""Paper Table 1 workloads + topology presets.

| # GPUs | GPT size, parallel      | MoE size, parallel             |
|   64   | 7B,  TP8-DP4-PP2        | 8×7B,  TP8-EP8-DP4-PP2 (*)     |
|  128   | 13B, TP8-DP4-PP4        | 8×13B, TP8-EP8-DP4-PP4 (*)     |
|  256   | 22B, TP8-DP8-PP4        | 8×22B, TP8-EP8-DP8-PP4 (*)     |
| 1024   | 175B, TP8-DP16-PP8      | 32×22B, TP8-EP8-DP16-PP8 (*)   |

(*) The paper's Table-1 MoE rows multiply out past the GPU count if EP is an
extra dimension; as in Megatron/DeepSpeed practice, EP reuses the DP ranks
(expert-parallel groups are a re-grouping of the data-parallel dimension).
We therefore carve EP groups out of DP: ep_from_dp=True splits each DP group
of size dp into dp/ep rings and forms all-to-all domains of size ep.
For the network, what matters is that all-to-all domains of size ep exist —
we model EP groups over the DP dimension with ep ≤ dp, and keep the DP ring
at full size (gradient sync is unchanged by expert placement).
"""
from __future__ import annotations

import dataclasses

from repro.net.topology import Topology, rail_optimized_fat_tree
from repro.workload.parallelism import ParallelismConfig
from repro.workload.traffic import TrafficModelSpec


@dataclasses.dataclass
class Workload:
    name: str
    spec: TrafficModelSpec
    par: ParallelismConfig
    n_gpus: int


def _gpt(name, layers, d_model, d_ff, params):
    return TrafficModelSpec(name=name, n_layers=layers, d_model=d_model,
                            d_ff=d_ff, vocab=50304, params=params)


def _moe(name, layers, d_model, d_ff, params, active, experts=8, top_k=2):
    return TrafficModelSpec(name=name, n_layers=layers, d_model=d_model,
                            d_ff=d_ff, vocab=50304, params=params,
                            active_params=active, moe_experts=experts,
                            moe_top_k=top_k, moe_layer_every=1)


GPT = {
    64: Workload("gpt-7b@64", _gpt("gpt-7b", 32, 4096, 16384, 7e9),
                 ParallelismConfig(tp=8, dp=4, pp=2), 64),
    128: Workload("gpt-13b@128", _gpt("gpt-13b", 40, 5120, 20480, 13e9),
                  ParallelismConfig(tp=8, dp=4, pp=4), 128),
    256: Workload("gpt-22b@256", _gpt("gpt-22b", 48, 6144, 24576, 22e9),
                  ParallelismConfig(tp=8, dp=8, pp=4), 256),
    1024: Workload("gpt-175b@1024", _gpt("gpt-175b", 96, 12288, 49152, 175e9),
                   ParallelismConfig(tp=8, dp=16, pp=8), 1024),
}

# EP groups are carved out of DP (ep ≤ dp): TP8-EP(≤dp)-DP-PP over the same
# GPU counts as the GPT rows.
MOE = {
    64: Workload("moe-8x7b@64", _moe("moe-8x7b", 32, 4096, 14336, 47e9, 13e9),
                 ParallelismConfig(tp=8, dp=4, pp=2, ep=1), 64),
    128: Workload("moe-8x13b@128", _moe("moe-8x13b", 40, 5120, 17920, 84e9, 23e9),
                  ParallelismConfig(tp=8, dp=4, pp=4, ep=1), 128),
    256: Workload("moe-8x22b@256", _moe("moe-8x22b", 56, 6144, 16384, 141e9, 39e9),
                  ParallelismConfig(tp=8, dp=8, pp=4, ep=1), 256),
    1024: Workload("moe-32x22b@1024", _moe("moe-32x22b", 56, 6144, 16384, 520e9, 44e9,
                                           experts=32, top_k=2),
                   ParallelismConfig(tp=8, dp=16, pp=8, ep=1), 1024),
}
# network EP domain size for MoE rows (all-to-all over this many DP ranks)
MOE_EP_DOMAIN = 8


def resolve(family: str, n_gpus: int) -> tuple[TrafficModelSpec,
                                               ParallelismConfig, int]:
    """(spec, parallelism, default ep_over_dp) for a Table-1 row.  Sizes
    off the table fall back to the 64-GPU spec with TP8-PP2 and DP grown to
    n_gpus/16 (the scaling rule the benchmarks use); MoE keeps at least two
    DP ranks so the EP all-to-all domains stay non-trivial."""
    if family == "moe":
        if n_gpus in MOE:
            wl = MOE[n_gpus]
            return wl.spec, wl.par, min(MOE_EP_DOMAIN, wl.par.dp)
        dp = max(2, n_gpus // 16)
        return (MOE[64].spec, ParallelismConfig(tp=8, dp=dp, pp=2, ep=1),
                min(MOE_EP_DOMAIN, dp))
    if family != "gpt":
        raise ValueError(f"unknown workload family {family!r}; have gpt, moe")
    if n_gpus in GPT:
        wl = GPT[n_gpus]
        return wl.spec, wl.par, 0
    dp = max(1, n_gpus // 16)
    return GPT[64].spec, ParallelismConfig(tp=8, dp=dp, pp=2), 0


def topology_for(n_gpus: int, gpus_per_server: int = 8,
                 bw: float = 12.5e9) -> Topology:
    return rail_optimized_fat_tree(
        n_servers=max(2, n_gpus // gpus_per_server),
        gpus_per_server=gpus_per_server,
        leaf_radix=32, n_spines=8, bw=bw)


def moe_with_ep(base: Workload, ep_domain: int = MOE_EP_DOMAIN) -> Workload:
    """Re-express the MoE workload with EP groups carved from DP: the traffic
    program sees ep>1 (all-to-all domains) while keeping world size fixed by
    shrinking dp."""
    par = base.par
    ep = min(ep_domain, par.dp)
    new_par = ParallelismConfig(tp=par.tp, dp=par.dp // ep, pp=par.pp, ep=ep)
    return dataclasses.replace(base, par=new_par)
