"""Per-iteration traffic programs for LLM training (paper Table 1).

A program is a DAG of phases; each phase models "compute for t seconds, then
launch these flows".  The schedule is GPipe-like with micro-batch-granular
dependencies:

    fwd(m,s)  <- fwd(m,s-1) [p2p arrival], fwd(m-1,s) [stage busy]
    bwd(m,s)  <- bwd(m,s+1), bwd(m-1,s), last fwd
    dp(s)     <- all bwd(·,s): ring all-reduce of the stage's gradients
    (MoE)     EP all-to-all bytes aggregated into each fwd/bwd phase

Flow sizes and compute times carry a common ``scale`` so GB-scale real
workloads stay runnable in the Python oracle; ratios (and therefore Wormhole
speedups/errors) are preserved.
"""
from __future__ import annotations

import dataclasses

from repro.net.flows import FlowSpec
from repro.workload import collectives as C
from repro.workload.parallelism import ParallelismConfig, build_groups, rank_of


@dataclasses.dataclass
class TrafficModelSpec:
    """The slice of a model config the network cares about."""
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    params: float                    # total parameter count
    active_params: float = 0.0      # per-token active (MoE); 0 -> = params
    seq_len: int = 4096
    micro_batch: int = 1
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_layer_every: int = 1         # every k-th layer is MoE
    dtype_bytes: float = 2.0

    def __post_init__(self) -> None:
        if not self.active_params:
            self.active_params = self.params


@dataclasses.dataclass
class Phase:
    name: str
    flows: list[FlowSpec]
    deps: list[int]
    compute: float = 0.0


def build_training_program(
    spec: TrafficModelSpec,
    par: ParallelismConfig,
    cca: str = "dctcp",
    scale: float = 1.0,
    chip_flops: float = 197e12,
    mfu: float = 0.4,
    num_microbatches: int | None = None,
    straggler: tuple[int, float] | None = None,   # (rank, compute multiplier)
    fid_start: int = 0,
    ep_over_dp: int = 0,   # expert-parallel domains carved from the DP ranks
    collective: str = "ring",        # DP gradient-sync schedule (schedules pkg)
    topo_meta: dict | None = None,   # topology params, for locality-aware schedules
    extra_stragglers: dict[int, float] | None = None,  # rank -> multiplier (chaos)
) -> list[Phase]:
    if collective != "ring":
        # deferred import: schedules.pipeline imports Phase from this module
        from repro.workload.schedules import SCHEDULES, allreduce_steps
        if collective not in SCHEDULES:
            raise ValueError(f"unknown collective {collective!r}; "
                             f"choose from {sorted(SCHEDULES)}")
    groups = build_groups(par)
    if ep_over_dp > 1 and spec.moe_experts:
        # DeepSpeed-style: EP groups reuse DP ranks; gradient rings keep the
        # full DP size, all-to-all domains span ep_over_dp consecutive DP
        # peers (paper Table 1's TP8-EP8-DP-PP overlay)
        eg = []
        for g in groups.dp_groups:
            for i in range(0, len(g), ep_over_dp):
                dom = g[i:i + ep_over_dp]
                if len(dom) > 1:
                    eg.append(dom)
        groups.ep_groups = eg
    fid = C.FidAlloc(fid_start)
    M = num_microbatches if num_microbatches is not None else max(par.pp, 1)
    tokens_mb = spec.micro_batch * spec.seq_len
    stage_layers = max(1, spec.n_layers // par.pp)
    stage_params = spec.params / par.pp
    stage_active = spec.active_params / par.pp

    # per-(microbatch, stage) compute on one rank (TP splits the math)
    t_fwd = 2 * stage_active * tokens_mb / (chip_flops * mfu * par.tp) * scale
    t_bwd = 2 * t_fwd
    act_bytes = spec.micro_batch * spec.seq_len * spec.d_model * spec.dtype_bytes \
        / par.tp * scale
    grad_bytes = stage_params / par.tp / max(par.ep, 1) * spec.dtype_bytes * scale

    moe_layers_stage = 0
    if spec.moe_experts and par.ep >= 1:
        moe_layers_stage = max(1, stage_layers // spec.moe_layer_every)
    a2a_bytes_per_rank = (
        tokens_mb * spec.d_model * spec.dtype_bytes * max(spec.moe_top_k, 1)
        * moe_layers_stage / par.tp * scale
    ) if moe_layers_stage else 0.0

    slow: dict[int, float] = {}
    if straggler:
        slow[int(straggler[0])] = float(straggler[1])
    for r, f in (extra_stragglers or {}).items():
        slow[int(r)] = slow.get(int(r), 1.0) * float(f)
    slow_ranks = sorted(slow)

    def straggle(rank_list: list[int], t: float) -> float:
        for r in slow_ranks:
            if r in rank_list:
                t = t * slow[r]
        return t

    phases: list[Phase] = []
    idx: dict[tuple, int] = {}

    def add(name: str, flows: list[FlowSpec], deps: list[int], compute: float) -> int:
        phases.append(Phase(name, flows, deps, compute))
        return len(phases) - 1

    def stage_ranks(s: int) -> list[int]:
        return [rank_of(par, t, e, d, s)
                for d in range(par.dp) for e in range(par.ep) for t in range(par.tp)]

    # ---------------- forward ---------------- #
    for m in range(M):
        for s in range(par.pp):
            deps = []
            if s > 0:
                deps.append(idx[("f", m, s - 1)])
            if m > 0:
                deps.append(idx[("f", m - 1, s)])
            flows: list[FlowSpec] = []
            if a2a_bytes_per_rank:
                for g in groups.ep_groups:
                    if groups.stage_of[g[0]] == s:
                        flows += C.all_to_all(g, 2 * a2a_bytes_per_rank, fid, cca,
                                              f"ep.fwd.m{m}.s{s}")
            if s < par.pp - 1:
                for (a, b) in groups.pp_pairs[s]:
                    flows += C.p2p(a, b, act_bytes, fid, cca, f"pp.fwd.m{m}.s{s}")
            idx[("f", m, s)] = add(f"fwd.m{m}.s{s}", flows, deps,
                                   straggle(stage_ranks(s), t_fwd))

    # ---------------- backward ---------------- #
    for m in range(M):
        for s in reversed(range(par.pp)):
            deps = [idx[("f", M - 1, par.pp - 1)]]
            if s < par.pp - 1:
                deps.append(idx[("b", m, s + 1)])
            if m > 0:
                deps.append(idx[("b", m - 1, s)])
            flows = []
            if a2a_bytes_per_rank:
                for g in groups.ep_groups:
                    if groups.stage_of[g[0]] == s:
                        flows += C.all_to_all(g, 2 * a2a_bytes_per_rank, fid, cca,
                                              f"ep.bwd.m{m}.s{s}")
            if s > 0:
                for (a, b) in groups.pp_pairs[s - 1]:
                    flows += C.p2p(b, a, act_bytes, fid, cca, f"pp.bwd.m{m}.s{s}")
            idx[("b", m, s)] = add(f"bwd.m{m}.s{s}", flows, deps,
                                   straggle(stage_ranks(s), t_bwd))

    # ---------------- gradient sync (the elephants) ---------------- #
    for s in range(par.pp):
        deps = [idx[("b", m, s)] for m in range(M)]
        if collective == "ring":
            flows = []
            for g in groups.dp_groups:
                if groups.stage_of[g[0]] == s:
                    flows += C.ring_allreduce(g, grad_bytes, fid, cca, f"dp.s{s}")
            if flows:
                add(f"dp.s{s}", flows, deps, 0.0)
            continue
        # staged schedule: merge per-group steps by index (all DP groups of a
        # stage run their step k concurrently), then chain the merged steps
        step_flows: list[list[FlowSpec]] = []
        for g in groups.dp_groups:
            if groups.stage_of[g[0]] != s:
                continue
            for k, (_name, fl) in enumerate(allreduce_steps(
                    collective, g, grad_bytes, fid, cca=cca, tag=f"dp.s{s}",
                    topo_meta=topo_meta)):
                while len(step_flows) <= k:
                    step_flows.append([])
                step_flows[k] += fl
        prev = -1
        for k, fl in enumerate(step_flows):
            if fl:
                prev = add(f"dp.s{s}.k{k}", fl, deps if prev < 0 else [prev], 0.0)
    return phases


def program_stats(phases: list[Phase]) -> dict:
    flows = [f for p in phases for f in p.flows]
    return {
        "phases": len(phases),
        "flows": len(flows),
        "bytes": sum(f.size for f in flows),
        "dp_bytes": sum(f.size for f in flows if f.tag.startswith("dp.")),
        "pp_bytes": sum(f.size for f in flows if f.tag.startswith("pp.")),
        "ep_bytes": sum(f.size for f in flows if f.tag.startswith("ep.")),
    }
