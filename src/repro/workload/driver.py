"""Phase-DAG driver: injects flows into the simulator as dependencies
resolve.  From the Wormhole kernel's perspective these launches are
*real-time interrupt events* (§5.3) — they cannot be known ahead of time, so
they exercise the skip-back machinery exactly like the paper's live-digital-
twin scenario."""
from __future__ import annotations

import dataclasses

from repro.net.packet_sim import PacketSim
from repro.workload.traffic import Phase


class WorkloadDriver:
    def __init__(self, sim: PacketSim, phases: list[Phase], t0: float = 0.0) -> None:
        self.sim = sim
        self.phases = phases
        self.remaining = [len(p.flows) for p in phases]
        self.done_t: list[float | None] = [None] * len(phases)
        self.launched = [False] * len(phases)
        self.pending_deps = [len(set(p.deps)) for p in phases]
        self.dependents: list[list[int]] = [[] for _ in phases]
        for j, p in enumerate(phases):
            for d in sorted(set(p.deps)):
                self.dependents[d].append(j)
        self.fid2phase: dict[int, int] = {}
        sim.finish_listeners.append(self._on_finish)
        self._t0 = t0
        for i, p in enumerate(phases):
            if not p.deps:
                self._launch(i, t0)

    # ------------------------------------------------------------------ #
    def _launch(self, i: int, t: float) -> None:
        if self.launched[i]:
            return
        self.launched[i] = True
        p = self.phases[i]
        start = t + p.compute
        if not p.flows:
            self.sim.call_at(start, lambda now, i=i: self._complete(i, now))
            return
        for fl in p.flows:
            self.fid2phase[fl.fid] = i
            self.sim.add_flow(dataclasses.replace(fl, start=start, phase=i))

    def _on_finish(self, flow, t: float) -> None:
        i = self.fid2phase.get(flow.fid)
        if i is None:
            return
        self.remaining[i] -= 1
        if self.remaining[i] == 0:
            self._complete(i, t)

    def _complete(self, i: int, t: float) -> None:
        self.done_t[i] = t
        for j in self.dependents[i]:
            self.pending_deps[j] -= 1
            if self.pending_deps[j] == 0:
                ready_t = max(self.done_t[d] for d in set(self.phases[j].deps))
                self._launch(j, ready_t)

    # ------------------------------------------------------------------ #
    @property
    def finished(self) -> bool:
        return all(d is not None for d in self.done_t)

    @property
    def iteration_time(self) -> float:
        assert self.finished, "program still running"
        return max(t for t in self.done_t if t is not None) - self._t0
