"""LLM-training workload generation: parallelism groups placed on the
topology, collectives decomposed into flows, and a phase-DAG driver that
injects flows as compute/communication dependencies resolve (the paper's
Table 1 GPT/MoE workloads)."""

from repro.workload import presets
from repro.workload.driver import WorkloadDriver
from repro.workload.parallelism import ParallelismConfig, build_groups
from repro.workload.traffic import (Phase, TrafficModelSpec,
                                    build_training_program)
