"""Collective-schedule library: alternative allreduce decompositions and
pipeline send/recv programs, all emitted as the phase-DAG shapes the
:class:`~repro.workload.driver.WorkloadDriver` consumes.

``workload/collectives.py`` models every collective as one flat flow set
(ring steps overlap perfectly, so the aggregate is a single long stream).
Real collective algorithms are *staged*: a tree allreduce is log2(n)
reduce rounds followed by log2(n) broadcast rounds, halving-doubling is a
recursive-halving reduce-scatter then a recursive-doubling allgather, and
hierarchical allreduce localizes the heavy steps (rail-local
reduce-scatter -> cross-rail allreduce of the shards -> rail-local
allgather on the rail-optimized fat-tree).  Each builder here returns an
ordered list of ``(name, flows)`` *steps* — step k may only start once
step k-1 has drained — which ``build_training_program`` stitches into the
training DAG (``collective=`` on :class:`~repro.api.scenario.WorkloadSpec`)
and tests/benches drive directly.

The staged shapes matter adversarially: Wormhole's memoization keys on
repeating contention patterns, and a staged collective replaces one long
steady elephant with a sequence of short, differently-shaped waves.
"""
from repro.workload.schedules.allreduce import (SCHEDULES, allreduce_steps,
                                                halving_doubling_allreduce,
                                                hierarchical_allreduce,
                                                ring_allreduce_steps,
                                                steps_to_phases,
                                                tree_allreduce)
from repro.workload.schedules.pipeline import (pipeline_bubble_fraction,
                                               pipeline_phases)

__all__ = [
    "SCHEDULES", "allreduce_steps", "ring_allreduce_steps", "tree_allreduce",
    "halving_doubling_allreduce", "hierarchical_allreduce", "steps_to_phases",
    "pipeline_phases", "pipeline_bubble_fraction",
]
