"""Pipeline-parallel send/recv schedules with explicit bubbles.

``build_training_program`` already threads GPipe-style microbatch
dependencies through a full TP x EP x DP x PP program; this module emits
the *bare* pipeline — one stage chain, forward activations down, gradient
activations back — so schedules, benches, and tests can study pipeline
bubbles (the head/tail idle slots the dependency DAG forces) without the
rest of the parallelism overlay.
"""
from __future__ import annotations

from repro.workload import collectives as C
from repro.workload.traffic import Phase


def pipeline_phases(stages, n_microbatches, act_bytes, fid, cca="dctcp",
                    tag="pp", t_fwd=0.0, t_bwd=None):
    """GPipe forward+backward over an explicit rank chain.

    Phase (m, s) computes for ``t_fwd`` (``t_bwd`` on the way back,
    defaulting to 2x), then sends its activation to stage s+1 (gradient to
    s-1 on the backward pass).  Dependencies: fwd(m,s) needs fwd(m,s-1)
    and fwd(m-1,s); bwd(m,s) needs the last fwd plus bwd(m,s+1) and
    bwd(m-1,s) — the classic (S-1)-deep warmup/drain bubbles fall out of
    the DAG rather than being scheduled explicitly.
    """
    S = len(stages)
    if S < 2:
        raise ValueError(f"pipeline needs >= 2 stages, got {S}")
    if n_microbatches < 1:
        raise ValueError(f"pipeline needs >= 1 microbatch, got {n_microbatches}")
    if t_bwd is None:
        t_bwd = 2 * t_fwd
    phases: list[Phase] = []
    idx: dict[tuple, int] = {}

    def add(name, flows, deps, compute):
        phases.append(Phase(name, flows, deps, compute))
        return len(phases) - 1

    for m in range(n_microbatches):
        for s in range(S):
            deps = []
            if s > 0:
                deps.append(idx[("f", m, s - 1)])
            if m > 0:
                deps.append(idx[("f", m - 1, s)])
            flows = (C.p2p(stages[s], stages[s + 1], act_bytes, fid, cca,
                           f"{tag}.fwd") if s < S - 1 else [])
            idx[("f", m, s)] = add(f"{tag}.fwd.m{m}.s{s}", flows, deps, t_fwd)
    for m in range(n_microbatches):
        for s in reversed(range(S)):
            deps = [idx[("f", n_microbatches - 1, S - 1)]]
            if s < S - 1:
                deps.append(idx[("b", m, s + 1)])
            if m > 0:
                deps.append(idx[("b", m - 1, s)])
            flows = (C.p2p(stages[s], stages[s - 1], act_bytes, fid, cca,
                           f"{tag}.bwd") if s > 0 else [])
            idx[("b", m, s)] = add(f"{tag}.bwd.m{m}.s{s}", flows, deps, t_bwd)
    return phases


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """Analytic GPipe bubble fraction (S-1)/(M+S-1): the share of each
    rank's timeline spent idle at pipeline warmup/drain when every
    microbatch costs the same."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
