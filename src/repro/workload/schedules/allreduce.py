"""Staged allreduce schedules.

Every builder has the same shape::

    builder(members, bytes_total, fid, cca, tag, topo_meta=None)
        -> list[(step_name, [FlowSpec, ...])]

``members`` are host/rank ids, ``bytes_total`` is the full gradient buffer
per rank, ``fid`` a callable id allocator (``collectives.FidAlloc``), and
``topo_meta`` the topology's builder params (``Scenario.topology.params``)
— only the hierarchical schedule reads it, to discover rail/leaf locality
on the rail-optimized fat-tree.  Steps are strictly ordered: all flows of
step k-1 finish before step k starts (the caller encodes that as phase
dependencies), which is what distinguishes these from the flat overlapped
ring in ``workload/collectives.py``.
"""
from __future__ import annotations

from repro.net.flows import FlowSpec
from repro.workload import collectives as C
from repro.workload.traffic import Phase

# step: (name, flows) — flows of one step run concurrently, steps run in order
Step = tuple[str, list[FlowSpec]]


def ring_allreduce_steps(members, bytes_total, fid, cca="dctcp", tag="ar",
                         topo_meta=None):
    """The baseline: one step holding the flat bidirectional ring."""
    del topo_meta
    return [(tag, C.ring_allreduce(members, bytes_total, fid, cca, tag))]


def tree_allreduce(members, bytes_total, fid, cca="dctcp", tag="ar",
                   topo_meta=None):
    """Binomial-tree allreduce: log2(n) reduce rounds into members[0], then
    the mirrored broadcast rounds back out.

    Round d pairs rank i with rank i+d (i a multiple of 2d); the full
    buffer moves on every hop, so the root's last reduce hop and first
    broadcast hop are the serial bottleneck — cheap for latency-bound
    (small) buffers, 2*bytes_total*log-ish on the wire for large ones.
    """
    del topo_meta
    n = len(members)
    if n < 2:
        raise ValueError(f"tree allreduce needs >= 2 members, got {n}")
    up_rounds: list[list[FlowSpec]] = []
    d = 1
    while d < n:
        flows = []
        for i in range(0, n, 2 * d):
            j = i + d
            if j < n:
                flows.append(FlowSpec(fid(), members[j], members[i],
                                      bytes_total, 0.0, cca, tag))
        if flows:
            up_rounds.append(flows)
        d *= 2
    steps: list[Step] = [(f"{tag}.up{k}", fl) for k, fl in enumerate(up_rounds)]
    for k, fl in enumerate(reversed(up_rounds)):
        steps.append((f"{tag}.down{k}",
                      [FlowSpec(fid(), f.dst, f.src, bytes_total, 0.0, cca, tag)
                       for f in fl]))
    return steps


def halving_doubling_allreduce(members, bytes_total, fid, cca="dctcp",
                               tag="ar", topo_meta=None):
    """Recursive halving-doubling: log2(n) reduce-scatter rounds over XOR
    pairs (payload halves each round), then log2(n) allgather rounds back
    (payload doubles).  Total bytes per rank = 2(n-1)/n * bytes_total, the
    same optimality as the ring but in log rounds instead of n-1.
    """
    del topo_meta
    n = len(members)
    if n < 2 or n & (n - 1):
        raise ValueError(
            f"halving-doubling needs a power-of-two group, got {n} members")
    steps: list[Step] = []
    d, size, k = n // 2, bytes_total / 2, 0
    while d >= 1:
        steps.append((f"{tag}.rs{k}",
                      [FlowSpec(fid(), members[i], members[i ^ d], size,
                                0.0, cca, tag) for i in range(n)]))
        d //= 2
        size /= 2
        k += 1
    d, size, k = 1, bytes_total / n, 0
    while d < n:
        steps.append((f"{tag}.ag{k}",
                      [FlowSpec(fid(), members[i], members[i ^ d], size,
                                0.0, cca, tag) for i in range(n)]))
        d *= 2
        size *= 2
        k += 1
    return steps


def hierarchical_allreduce(members, bytes_total, fid, cca="dctcp", tag="ar",
                           topo_meta=None):
    """Locality-aware 3-stage allreduce on the rail-optimized fat-tree:
    local ring reduce-scatter -> cross-group ring allreduce of the shards
    -> local ring allgather.

    Locality cascades: members are grouped by rail (``host %
    gpus_per_server``) when they span several rails, else by leaf switch,
    else — when the whole group already shares one locality domain, the
    common case for this repo's rail-local DP groups — into equal
    contiguous chunks of the ring, which still converts one n-wide ring
    into parallel short rings plus a thin cross-ring exchange.  Groups
    must come out equal-sized (the shard exchange pairs i-th locals).
    """
    n = len(members)
    if n < 2:
        raise ValueError(f"hierarchical allreduce needs >= 2 members, got {n}")
    meta = topo_meta or {}
    gps = int(meta.get("gpus_per_server", 8))
    leaf_radix = int(meta.get("leaf_radix", 32))
    subs = _bucket(members, lambda h: h % gps)
    if len(subs) == 1:
        subs = _bucket(members, lambda h: (h // gps) // leaf_radix)
    if len(subs) == 1:
        width = _mid_divisor(n)
        subs = [list(members[i:i + width]) for i in range(0, n, width)]
    sizes = {len(s) for s in subs}
    if len(sizes) != 1:
        raise ValueError(
            "hierarchical allreduce needs equal-size locality groups, got "
            f"sizes {sorted(len(s) for s in subs)} for members {list(members)}")
    m = sizes.pop()
    if len(subs) == 1:
        # degenerate (prime-size single-domain group): plain ring
        return [(tag, C.ring_allreduce(subs[0], bytes_total, fid, cca, tag))]
    steps: list[Step] = []
    if m >= 2:
        flows = []
        for sub in subs:
            flows += C.ring_reduce_scatter(sub, bytes_total, fid, cca, tag)
        steps.append((f"{tag}.rs", flows))
    flows = []
    for i in range(m):
        flows += C.ring_allreduce([sub[i] for sub in subs], bytes_total / m,
                                  fid, cca, tag)
    steps.append((f"{tag}.xg", flows))
    if m >= 2:
        flows = []
        for sub in subs:
            flows += C.ring_allgather(sub, bytes_total, fid, cca, tag)
        steps.append((f"{tag}.ag", flows))
    return steps


SCHEDULES = {
    "ring": ring_allreduce_steps,
    "tree": tree_allreduce,
    "halving_doubling": halving_doubling_allreduce,
    "hierarchical": hierarchical_allreduce,
}


def allreduce_steps(collective, members, bytes_total, fid, cca="dctcp",
                    tag="ar", topo_meta=None):
    """Dispatch to a registered schedule by name."""
    try:
        builder = SCHEDULES[collective]
    except KeyError:
        raise ValueError(f"unknown collective {collective!r}; "
                         f"choose from {sorted(SCHEDULES)}") from None
    return builder(members, bytes_total, fid, cca=cca, tag=tag,
                   topo_meta=topo_meta)


def steps_to_phases(steps, deps=None, compute=0.0):
    """Chain ordered steps into sequential :class:`Phase` objects — step 0
    takes ``deps`` (and ``compute``), each later step depends on its
    predecessor."""
    phases: list[Phase] = []
    for k, (name, flows) in enumerate(steps):
        phases.append(Phase(name, flows,
                            list(deps or []) if k == 0 else [k - 1],
                            compute if k == 0 else 0.0))
    return phases


def _bucket(members, key):
    groups: dict = {}
    for m in members:
        groups.setdefault(key(m), []).append(m)
    return [groups[k] for k in sorted(groups)]


def _mid_divisor(n: int) -> int:
    """Smallest divisor of n that is >= sqrt(n) (n itself when n is prime)."""
    d = int(n ** 0.5)
    while d > 1 and n % d:
        d -= 1
    return n // d
