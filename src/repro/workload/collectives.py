"""Collective → flow decomposition.

Ring collectives are modelled as one steady stream per ring member to its
neighbour carrying the collective's total per-member traffic (the standard
flow-level decomposition used by SimAI/ASTRA-sim: ring steps overlap
perfectly on disjoint links, so the aggregate is a single long flow —
exactly the elephant-flow shape whose steady-state Wormhole fast-forwards):

    all-reduce      : 2·(n-1)/n · bytes   per member → next
    reduce-scatter  :   (n-1)/n · bytes
    all-gather      :   (n-1)/n · bytes
    all-to-all      : bytes/n per ordered pair (n·(n-1) flows)
    p2p             : bytes, one flow
"""
from __future__ import annotations

from collections.abc import Iterable

from repro.net.flows import FlowSpec


class FidAlloc:
    def __init__(self, start: int = 0) -> None:
        self._next = start

    def __call__(self) -> int:
        v = self._next
        self._next += 1
        return v


def ring_allreduce(members: list[int], bytes_total: float, fid: FidAlloc,
                   cca: str, tag: str, bidirectional: bool = True) -> list[FlowSpec]:
    n = len(members)
    assert n >= 2
    per = 2 * (n - 1) / n * bytes_total
    if bidirectional:
        per /= 2
    out = []
    for i, src in enumerate(members):
        out.append(FlowSpec(fid(), src, members[(i + 1) % n], per, 0.0, cca, tag))
        if bidirectional:
            out.append(FlowSpec(fid(), src, members[(i - 1) % n], per, 0.0, cca, tag))
    return out


def ring_reduce_scatter(members: list[int], bytes_total: float, fid: FidAlloc,
                        cca: str, tag: str) -> list[FlowSpec]:
    n = len(members)
    per = (n - 1) / n * bytes_total
    return [FlowSpec(fid(), m, members[(i + 1) % n], per, 0.0, cca, tag)
            for i, m in enumerate(members)]


ring_allgather = ring_reduce_scatter  # same traffic shape


def all_to_all(members: list[int], bytes_per_rank: float, fid: FidAlloc,
               cca: str, tag: str) -> list[FlowSpec]:
    n = len(members)
    per = bytes_per_rank / n
    out = []
    for src in members:
        for dst in members:
            if src != dst:
                out.append(FlowSpec(fid(), src, dst, per, 0.0, cca, tag))
    return out


def p2p(src: int, dst: int, bytes_total: float, fid: FidAlloc,
        cca: str, tag: str) -> list[FlowSpec]:
    return [FlowSpec(fid(), src, dst, bytes_total, 0.0, cca, tag)]


def total_bytes(flows: Iterable[FlowSpec]) -> float:
    return sum(f.size for f in flows)
