"""The ``learned`` engine: fitted per-flow FCT prediction as a backend.

Registered as the sixth engine family.  A run never simulates: per-flow
features come straight from the scenario (``repro.learned.dataset``), the
fitted MLP predicts each flow's slowdown over its max-min ideal, and the
phase DAG is scheduled analytically on top (the same scheduling the fluid
backend uses), so a well-formed :class:`RunResult` comes back in
microseconds.  ``run_batch`` flattens a whole scenario sweep into one
model invocation — the m4-style serving tier: thousands of what-if
queries per second out of one process (``benchmarks/learned_bench.py``).

Guard rails:

* no fitted params -> a clear error naming the ``python -m repro fit``
  command that produces them;
* out-of-distribution queries — numeric features outside the training
  envelope, or categories (CCA / topology class) outside the fitted
  vocabulary — raise :class:`OutOfDistributionError` by default
  (``ood="warn"``/``"ignore"`` downgrade it; violations always land in
  ``extras["learned"]["ood_violations"]``).

``RunResult.extras`` carries the per-flow predicted FCTs and the model
fingerprint, so any result can be traced to the exact fit that produced
it and ``compare()``/CI counters work unchanged.
"""
from __future__ import annotations

import os
import time
import warnings
from hashlib import sha256

import numpy as np

from repro.api.engines import Engine, register_engine
from repro.api.results import RunResult
from repro.api.scenario import Scenario
from repro.learned import dataset as D
from repro.net import chaos

DEFAULT_PARAMS_PATH = "artifacts/learned_params.json"

# serving caches fitted params per (path, size, mtime, content fingerprint)
# so sweeps and repeated runs pay the read once.  mtime+size alone is not a
# safe identity: a same-size rewrite within the filesystem's timestamp
# granularity (or under os.utime) would silently serve the stale model.
_PARAMS_CACHE: dict = {}


def _file_fingerprint(path: str) -> str:
    h = sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class OutOfDistributionError(ValueError):
    """A queried scenario falls outside the fitted model's training
    envelope (feature ranges) or vocabulary (CCA / topology class)."""


def load_params(params):
    """Resolve a ``params=`` opt: a :class:`LearnedParams` passes through,
    a path loads (cached on the file's identity)."""
    from repro.learned.model import LearnedParams, load
    if isinstance(params, LearnedParams):
        return params
    path = os.fspath(params)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no fitted learned-engine params at {path!r} — fit one from a "
            f"campaign of packet/wormhole/hybrid runs with "
            f"`python -m repro fit <campaign-dir> --out {path}`, or pass "
            f"params=<path|LearnedParams>")
    st = os.stat(path)
    key = (os.path.abspath(path), st.st_size, st.st_mtime_ns,
           _file_fingerprint(path))
    if key not in _PARAMS_CACHE:
        if len(_PARAMS_CACHE) >= 8:
            _PARAMS_CACHE.clear()
        _PARAMS_CACHE[key] = load(path)
    return _PARAMS_CACHE[key]


def _violations(scenario: Scenario, table: D.FlowTable, unknown: list[str],
                meta: dict) -> list[str]:
    """OOD report for one scenario: unknown categories + numeric features
    outside the training envelope (with a 5%-of-range margin, so boundary
    scenarios the fit saw do not flag on float noise)."""
    out = [f"{scenario.name}: {u}" for u in unknown]
    lo = np.asarray(meta["envelope_lo"], np.float64)
    hi = np.asarray(meta["envelope_hi"], np.float64)
    margin = 0.05 * (hi - lo) + 1e-9
    if len(table.fids):
        mn = table.numeric.min(0)
        mx = table.numeric.max(0)
        for j, name in enumerate(D.NUMERIC_FEATURES):
            if mn[j] < lo[j] - margin[j] or mx[j] > hi[j] + margin[j]:
                bad = mn[j] if mn[j] < lo[j] - margin[j] else mx[j]
                out.append(
                    f"{scenario.name}: {name}={bad:.4g} outside fitted "
                    f"range [{lo[j]:.4g}, {hi[j]:.4g}]")
    return out


def _schedule(table: D.FlowTable, fct: np.ndarray) -> tuple[dict, float | None]:
    """Analytic phase-DAG schedule over predicted FCTs (mirrors the fluid
    backend): returns ``{fid: fct}`` and the iteration time."""
    fcts = {int(f): float(v) for f, v in zip(table.fids, fct)}
    done = [0.0] * len(table.phases)
    starts: list[float] = []
    for i, (deps, compute, start_off) in enumerate(table.phases):
        start = max((done[d] for d in set(deps)), default=0.0) + compute
        if table.kind == "flows":
            start += start_off
        end = start
        rows = np.nonzero(table.phase_of == i)[0]
        for r in rows:
            end = max(end, start + float(fct[r]))
        if len(rows):
            starts.append(start)
        done[i] = end
    if not done:
        return fcts, None
    if table.kind == "flows" and starts:
        return fcts, max(done) - min(starts)
    return fcts, max(done)


@register_engine("learned")
class LearnedEngine(Engine):
    """m4-style learned flow-level backend: per-flow FCTs predicted by an
    MLP fitted on this repo's own campaign ground truth (packet /
    wormhole / hybrid records), phase DAG scheduled analytically.

    opts:
      params  path to fitted params (``model.save``; default
              ``artifacts/learned_params.json``) or a live
              ``LearnedParams`` (uncacheable in campaign stores)
      ood     "error" (default) | "warn" | "ignore" — what to do when a
              scenario leaves the training envelope/vocabulary

    Cheapest backend after ``analytic`` and far closer to the oracle *in
    distribution*; it knows nothing about traffic it was never fitted on,
    which is what the OOD guard is for.
    """
    option_names = ("ood", "params")

    def run(self, scenario: Scenario, **opts) -> RunResult:
        return self.run_batch([scenario], **opts)[0]

    def run_batch(self, scenarios: list[Scenario],
                  params=DEFAULT_PARAMS_PATH, ood: str = "error",
                  **opts) -> list[RunResult]:
        if ood not in ("error", "warn", "ignore"):
            raise ValueError(f"unknown ood policy {ood!r} "
                             f"(use 'error', 'warn' or 'ignore')")
        for scn in scenarios:
            chaos.check_backend(chaos.plan_for(scn), self.name)
        if not scenarios:
            return []
        t0 = time.perf_counter()
        lp = load_params(params)
        meta = lp.meta

        tables: list[D.FlowTable] = []
        blocks: list[np.ndarray] = []
        violations: list[list[str]] = []
        for scn in scenarios:
            table = D.flow_table(scn)
            X, unknown = D.encode(table, meta["cca_vocab"],
                                  meta["topo_vocab"])
            tables.append(table)
            blocks.append(X)
            violations.append(_violations(scn, table, unknown, meta))
        flat = [v for vs in violations for v in vs]
        if flat:
            if ood == "error":
                raise OutOfDistributionError(
                    "scenario(s) outside the fitted model's training "
                    "distribution:\n  " + "\n  ".join(flat) +
                    "\n(refit on a campaign covering them, or pass "
                    "ood='warn'/'ignore' to predict anyway)")
            if ood == "warn":
                warnings.warn(
                    f"learned engine extrapolating outside its training "
                    f"distribution: {'; '.join(flat)}", RuntimeWarning,
                    stacklevel=2)

        from repro.learned.model import predict
        X_all = np.concatenate(blocks) if blocks else np.zeros((0, lp.d_in))
        pred = predict(lp, X_all) if len(X_all) else np.zeros(0)

        wall_total = None    # filled after the per-scenario assembly
        results = []
        at = 0
        for scn, table, viol in zip(scenarios, tables, violations):
            n = len(table.fids)
            fct = table.ideal_fct * np.exp(pred[at:at + n])
            at += n
            fcts, iteration = _schedule(table, fct)
            extras = {
                "predicted_fcts": dict(fcts),
                "learned": {
                    "params_fingerprint": meta["fingerprint"],
                    "n_flows": n,
                    "ood_violations": viol,
                },
            }
            results.append(RunResult(
                backend=self.name, scenario=scn.name, fcts=fcts,
                flow_bytes={int(f): float(s)
                            for f, s in zip(table.fids, table.size)},
                tags={int(f): t for f, t in zip(table.fids, table.tags)},
                iteration_time=iteration, events_processed=0,
                wall_time=0.0, extras=extras))
        wall_total = time.perf_counter() - t0
        for r in results:
            r.wall_time = wall_total / len(results)
            r.extras["learned"]["batch_wall"] = wall_total
        return results
