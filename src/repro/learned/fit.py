"""Training loop for the learned engine: full-batch Adam, early stop on
held-out error, deterministic under a fixed seed.

The datasets here are small (a campaign's worth of per-flow rows —
hundreds to tens of thousands), so full-batch gradients are both cheapest
and exactly reproducible: no shuffling order to pin down.  One jitted
Adam step runs in a python loop with periodic held-out evaluation; the
weights that minimized held-out MSE are the ones returned.

    ds = camp.export_dataset()
    params = fit(ds, seed=0)
    model.save(params, "artifacts/learned_params.json")
"""
from __future__ import annotations

import numpy as np

from repro.learned import model as M
from repro.learned.dataset import Dataset


def standardize_moments(X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-column mean / clamped std of the training block."""
    mu = X.mean(axis=0)
    sigma = np.maximum(X.std(axis=0), 1e-8)
    return mu, sigma


def fit(dataset: Dataset, *, seed: int = 0, hidden: tuple[int, ...] = (64, 64),
        steps: int = 1500, lr: float = 3e-3, eval_every: int = 25,
        patience: int = 300) -> M.LearnedParams:
    """Fit an MLP to ``dataset`` and return sealed :class:`LearnedParams`.

    Early stopping watches held-out MSE every ``eval_every`` steps and
    keeps the best weights; with no held-out rows (``heldout_frac=0`` or a
    tiny store) it watches training MSE instead.  ``steps`` bounds the
    loop either way, so a fixed-seed fit always does the same work.
    """
    import jax
    import jax.numpy as jnp

    tr = ~dataset.heldout
    if not tr.any():
        raise ValueError("dataset has no training rows (everything held "
                         "out) — lower heldout_frac")
    mu, sigma = standardize_moments(dataset.X[tr])
    Xtr = jnp.asarray((dataset.X[tr] - mu) / sigma, jnp.float32)
    ytr = jnp.asarray(dataset.y[tr], jnp.float32)
    have_heldout = bool(dataset.heldout.any())
    if have_heldout:
        Xhe = jnp.asarray((dataset.X[dataset.heldout] - mu) / sigma,
                          jnp.float32)
        yhe = jnp.asarray(dataset.y[dataset.heldout], jnp.float32)

    weights = M.init(seed, dataset.X.shape[1], hidden)
    m_state = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in weights]
    v_state = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in weights]
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(weights, m_state, v_state, t):
        grads = jax.grad(M.loss)(weights, Xtr, ytr)
        new_w, new_m, new_v = [], [], []
        for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(
                weights, grads, m_state, v_state):
            upd = []
            for p, g, mm, vv in ((w, gw, mw, vw), (b, gb, mb, vb)):
                mm = b1 * mm + (1 - b1) * g
                vv = b2 * vv + (1 - b2) * g * g
                mhat = mm / (1 - b1 ** t)
                vhat = vv / (1 - b2 ** t)
                upd.append((p - lr * mhat / (jnp.sqrt(vhat) + eps), mm, vv))
            new_w.append((upd[0][0], upd[1][0]))
            new_m.append((upd[0][1], upd[1][1]))
            new_v.append((upd[0][2], upd[1][2]))
        return new_w, new_m, new_v

    @jax.jit
    def eval_mse(weights, x, y):
        return M.loss(weights, x, y)

    best_err = np.inf
    best_weights = weights
    best_step = 0
    steps_run = 0
    for t in range(1, steps + 1):
        weights, m_state, v_state = step(weights, m_state, v_state,
                                         jnp.float32(t))
        steps_run = t
        if t % eval_every == 0 or t == steps:
            err = float(eval_mse(weights, Xhe, yhe)) if have_heldout \
                else float(eval_mse(weights, Xtr, ytr))
            if err < best_err:
                best_err = err
                best_weights = [(np.asarray(w), np.asarray(b))
                                for w, b in weights]
                best_step = t
            elif t - best_step >= patience:
                break

    n_num = dataset.n_numeric
    train_mse = float(eval_mse([(jnp.asarray(w), jnp.asarray(b))
                                for w, b in best_weights], Xtr, ytr))
    meta = {
        "arch": {"hidden": list(hidden), "activation": "tanh"},
        "target": "log_slowdown_vs_maxmin",
        "feature_names": dataset.feature_names,
        "n_numeric": n_num,
        "cca_vocab": list(dataset.cca_vocab),
        "topo_vocab": list(dataset.topo_vocab),
        "mu": [float(v) for v in mu],
        "sigma": [float(v) for v in sigma],
        # training envelope over the raw numeric block — the engine's
        # out-of-distribution guard
        "envelope_lo": [float(v) for v in dataset.X[tr][:, :n_num].min(0)],
        "envelope_hi": [float(v) for v in dataset.X[tr][:, :n_num].max(0)],
        "train": {
            "seed": seed, "lr": lr, "steps": steps_run,
            "best_step": best_step,
            "records": dataset.n_records,
            "heldout_records": dataset.n_heldout_records,
            "flows": int(tr.sum()),
            "heldout_flows": int(dataset.heldout.sum()),
            "train_mse": train_mse,
            "heldout_mse": float(best_err) if have_heldout else None,
        },
    }
    return M.make_params(best_weights, meta)


def fct_error(params: M.LearnedParams, X: np.ndarray, y: np.ndarray,
              ) -> np.ndarray:
    """Per-row relative FCT error of the model on encoded rows: the
    slowdown targets make ``|exp(pred - y) - 1|`` exactly
    ``|fct_pred - fct| / fct``."""
    pred = M.predict(params, X)
    return np.abs(np.exp(pred - np.asarray(y)) - 1.0)


def heldout_fct_error(params: M.LearnedParams, dataset: Dataset) -> float:
    """Mean relative FCT error on the held-out rows (nan if none) — the
    accuracy number BENCH_learned.json and the smoke tests gate on."""
    if not dataset.heldout.any():
        return float("nan")
    return float(fct_error(params, dataset.X[dataset.heldout],
                           dataset.y[dataset.heldout]).mean())
