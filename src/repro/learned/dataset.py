"""Training datasets extracted from campaign RunStores (m4-style, PAPERS.md).

A campaign's content-addressed :class:`~repro.api.store.RunStore` already
*is* a labeled dataset: every record pairs a canonical scenario JSON with
the :class:`RunResult` an engine produced for it.  This module closes the
``campaign → training set`` half of the learned-engine loop:

* :func:`flow_table` — per-flow features computable from the scenario
  alone (no simulation): flow size, path placement (hops, bottleneck
  bandwidth, propagation delay — the src/dst partition signal), topology
  class, CCA, and concurrent-flow contention summaries within the flow's
  traffic phase, including the max-min fair rate the analytic solver
  assigns.  The same function feeds both training and serving, so the two
  can never drift.
* :func:`build_dataset` — ``(features, targets)`` arrays from any object
  with a ``records()`` iterator (a ``RunStore`` or a ``Campaign``).  Only
  packet-level ground truth counts: records from backends outside
  :data:`GROUND_TRUTH_BACKENDS` are skipped, and duplicate evaluations of
  one scenario collapse to the highest-fidelity record so a scenario can
  never leak across the split.  The train/held-out split is deterministic,
  keyed off each record's ``run_key`` — re-extracting the same store
  always yields the same split.

Targets are ``log(fct / ideal_fct)`` — the log slowdown of the measured
FCT over the max-min ideal ``size / rate`` — so the model learns the
*residual* contention physics the analytic solver misses, not absolute
timescales.  Everything here is numpy-only; jax enters in
``repro.learned.model``/``fit``.
"""
from __future__ import annotations

import dataclasses
import json
from hashlib import sha256

import numpy as np

from repro.api.results import RunResult
from repro.api.scenario import Scenario
from repro.kernels.maxmin import solve_paths
from repro.net.topology import Topology

# backends whose stored results are packet-level ground truth (analytic /
# fluid / learned records are themselves approximations — training on them
# would teach the model its own error)
GROUND_TRUTH_BACKENDS = ("packet", "wormhole", "hybrid")

NUMERIC_FEATURES = (
    "log_size",            # log10 flow bytes
    "path_len",            # hops — src/dst placement (2 = same leaf)
    "log_bottleneck_bw",   # log10 min link bw on the path
    "log_prop_delay",      # log10 end-to-end propagation delay
    "log_phase_flows",     # log10 concurrent flows in the phase
    "contention_degree",   # max co-located flows on any path link
    "log_maxmin_rate",     # log10 analytic fair-share rate
    "maxmin_share",        # fair-share rate / bottleneck bw
)


# scenario sweeps re-query one fabric thousands of times; rebuilding the
# Topology (and its BFS distance caches) per query would dominate serving
_TOPO_CACHE: dict[str, Topology] = {}


def _topology_for(scenario: Scenario) -> Topology:
    key = json.dumps({"kind": scenario.topology.kind,
                      "params": scenario.topology.params},
                     sort_keys=True, default=str)
    topo = _TOPO_CACHE.get(key)
    if topo is None:
        if len(_TOPO_CACHE) >= 64:
            _TOPO_CACHE.clear()
        topo = _TOPO_CACHE[key] = scenario.build_topology()
    return topo


@dataclasses.dataclass
class FlowTable:
    """Per-flow features of one scenario, grouped by traffic phase —
    the unit both the trainer and the learned engine consume."""
    fids: np.ndarray        # int64 [N]
    numeric: np.ndarray     # float64 [N, len(NUMERIC_FEATURES)]
    cca: list[str]          # [N]
    topo_kind: str
    ideal_fct: np.ndarray   # float64 [N]  size / maxmin rate
    size: np.ndarray        # float64 [N]  bytes
    tags: list[str]         # [N]
    phase_of: np.ndarray    # int64 [N]  index into ``phases``
    phases: list[tuple[tuple[int, ...], float, float]]  # (deps, compute, start)
    kind: str               # "flows" | "workload"


def flow_table(scenario: Scenario) -> FlowTable:
    """Per-flow feature rows for ``scenario`` — pure scenario-side math
    (routing, max-min solve), no simulation."""
    topo = _topology_for(scenario)
    phases = scenario.build_phases()
    fids: list[int] = []
    rows: list[list[float]] = []
    cca: list[str] = []
    ideal: list[float] = []
    size: list[float] = []
    tags: list[str] = []
    phase_of: list[int] = []
    phase_meta: list[tuple[tuple[int, ...], float, float]] = []
    for pi, ph in enumerate(phases):
        start = ph.flows[0].start if (scenario.kind == "flows" and ph.flows) \
            else 0.0
        phase_meta.append((tuple(ph.deps), float(ph.compute), float(start)))
        if not ph.flows:
            continue
        paths = {f.fid: topo.route(f.src, f.dst, f.fid) for f in ph.flows}
        # the vectorized solver directly — same CSR layout every fast lane
        # shares, bit-identical to the historical dict solver
        rates = solve_paths(paths, topo.link_bw)
        link_users: dict[int, int] = {}
        for p in paths.values():
            for l in p:
                link_users[l] = link_users.get(l, 0) + 1
        n_phase = float(len(ph.flows))
        for f in ph.flows:
            p = paths[f.fid]
            bott = float(topo.link_bw[p].min()) if p else 1e12
            prop = float(topo.link_delay[p].sum()) if p else 0.0
            cont = max((link_users[l] for l in p), default=1)
            rate = max(float(rates.get(f.fid, bott)), 1.0)
            fids.append(f.fid)
            rows.append([np.log10(f.size), float(len(p)), np.log10(bott),
                         np.log10(prop + 1e-9), np.log10(n_phase),
                         float(cont), np.log10(rate), rate / bott])
            cca.append(f.cca)
            ideal.append(f.size / rate)
            size.append(f.size)
            tags.append(f.tag)
            phase_of.append(pi)
    return FlowTable(
        fids=np.asarray(fids, np.int64),
        numeric=np.asarray(rows, np.float64).reshape(len(fids),
                                                     len(NUMERIC_FEATURES)),
        cca=cca, topo_kind=scenario.topology.kind,
        ideal_fct=np.asarray(ideal, np.float64),
        size=np.asarray(size, np.float64), tags=tags,
        phase_of=np.asarray(phase_of, np.int64),
        phases=phase_meta, kind=scenario.kind)


def encode(table: FlowTable, cca_vocab: list[str],
           topo_vocab: list[str]) -> tuple[np.ndarray, list[str]]:
    """Numeric block + one-hot categorical blocks under a fixed vocabulary
    (the fitted model's ``meta`` carries the vocab, so serving encodes
    exactly like training did).  Categories outside the vocab encode as
    all-zeros and come back in the second return value — the engine's OOD
    policy decides what to do with them."""
    n = len(table.fids)
    n_num = len(NUMERIC_FEATURES)
    X = np.zeros((n, n_num + len(cca_vocab) + len(topo_vocab)), np.float64)
    X[:, :n_num] = table.numeric
    unknown: set[str] = set()
    cca_ix = {c: i for i, c in enumerate(cca_vocab)}
    for i, c in enumerate(table.cca):
        j = cca_ix.get(c)
        if j is None:
            unknown.add(f"cca={c!r} not in fitted vocab {cca_vocab}")
        else:
            X[i, n_num + j] = 1.0
    topo_ix = {t: i for i, t in enumerate(topo_vocab)}
    j = topo_ix.get(table.topo_kind)
    if j is None:
        if n:
            unknown.add(f"topology={table.topo_kind!r} not in fitted "
                        f"vocab {topo_vocab}")
    else:
        X[:, n_num + len(cca_vocab) + j] = 1.0
    return X, sorted(unknown)


def feature_names(cca_vocab: list[str], topo_vocab: list[str]) -> list[str]:
    return (list(NUMERIC_FEATURES)
            + [f"cca={c}" for c in cca_vocab]
            + [f"topology={t}" for t in topo_vocab])


# ---------------------------------------------------------------------- #
# store -> dataset
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class Dataset:
    """Flat per-flow training arrays plus the record-level bookkeeping the
    fit loop and benchmarks report on."""
    X: np.ndarray               # [N, D] encoded features (raw, unstandardized)
    y: np.ndarray               # [N] log(fct / ideal_fct)
    ideal_fct: np.ndarray       # [N]
    fct: np.ndarray             # [N] ground-truth FCT
    heldout: np.ndarray         # bool [N]
    record_key: list[str]       # [N] owning record's run_key
    cca_vocab: list[str]
    topo_vocab: list[str]
    n_numeric: int
    n_records: int
    n_heldout_records: int

    @property
    def feature_names(self) -> list[str]:
        return feature_names(self.cca_vocab, self.topo_vocab)

    def __len__(self) -> int:
        return len(self.y)


def heldout_fraction_of(run_key: str) -> float:
    """Deterministic position of a record in [0, 1): records with
    ``heldout_fraction_of(key) < heldout_frac`` are held out.  Pure
    content hash — stable across processes, sessions and extraction
    order."""
    return int(sha256(run_key.encode()).hexdigest()[:8], 16) / 0x100000000


def build_dataset(source, backends: tuple[str, ...] = GROUND_TRUTH_BACKENDS,
                  heldout_frac: float = 0.25) -> Dataset:
    """Extract ``(features, targets)`` from ``source`` — anything with a
    ``records()`` iterator of store records (a :class:`RunStore` or a
    :class:`Campaign`).

    Records from backends outside ``backends`` are ignored (they are not
    packet-level ground truth), duplicate evaluations of one scenario
    fingerprint collapse to the highest-fidelity backend present (so one
    scenario can never land on both sides of the split), and flows missing
    from a record's result (never completed) are dropped.
    """
    for b in backends:
        if b not in GROUND_TRUTH_BACKENDS:
            raise ValueError(
                f"backend {b!r} is not packet-level ground truth; "
                f"usable: {GROUND_TRUTH_BACKENDS}")
    rank = {b: i for i, b in enumerate(GROUND_TRUTH_BACKENDS)}
    best: dict[str, dict] = {}
    for rec in source.records():
        if rec["backend"] not in backends:
            continue
        fp = rec["scenario_fingerprint"]
        old = best.get(fp)
        if old is None or rank[rec["backend"]] < rank[old["backend"]]:
            best[fp] = rec
    if not best:
        raise ValueError(
            f"no ground-truth records (backends {backends}) in the store — "
            f"sweep a campaign on a packet-level backend first")

    cca_vocab: set[str] = set()
    topo_vocab: set[str] = set()
    parsed = []
    for fp in sorted(best):
        rec = best[fp]
        scenario = Scenario.from_dict(rec["scenario"])
        result = RunResult.from_dict(rec["result"])
        table = flow_table(scenario)
        cca_vocab.update(table.cca)
        topo_vocab.add(table.topo_kind)
        parsed.append((rec["key"], table, result))
    ccas = sorted(cca_vocab)
    topos = sorted(topo_vocab)

    xs, ys, ideals, fcts, held, keys = [], [], [], [], [], []
    n_heldout_records = 0
    for key, table, result in parsed:
        X, _ = encode(table, ccas, topos)
        have = np.array([fid in result.fcts for fid in table.fids], bool)
        fct = np.array([result.fcts.get(int(fid), np.nan)
                        for fid in table.fids], np.float64)
        ok = have & (fct > 0) & (table.ideal_fct > 0)
        if not ok.any():
            continue
        is_held = heldout_fraction_of(key) < heldout_frac
        n_heldout_records += bool(is_held)
        xs.append(X[ok])
        ys.append(np.log(fct[ok] / table.ideal_fct[ok]))
        ideals.append(table.ideal_fct[ok])
        fcts.append(fct[ok])
        held.append(np.full(int(ok.sum()), is_held, bool))
        keys.extend([key] * int(ok.sum()))
    return Dataset(
        X=np.concatenate(xs), y=np.concatenate(ys),
        ideal_fct=np.concatenate(ideals), fct=np.concatenate(fcts),
        heldout=np.concatenate(held), record_key=keys,
        cca_vocab=ccas, topo_vocab=topos,
        n_numeric=len(NUMERIC_FEATURES), n_records=len(parsed),
        n_heldout_records=n_heldout_records)
