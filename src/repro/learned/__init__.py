"""repro.learned — the learned flow-level engine (m4-style, PAPERS.md).

Campaign RunStores of packet-level runs are labeled datasets; this package
closes the loop: ``build_dataset`` extracts per-flow (features, targets)
arrays, ``fit`` trains a small pure-JAX MLP on them, ``model.save``/``load``
persist versioned params, and ``LearnedEngine`` (registered as the sixth
backend family, ``"learned"``) serves batched what-if queries from the fit
at thousands of scenarios per second.

    camp.sweep(scenarios, backend="wormhole")        # ground truth
    ds = camp.export_dataset()                       # campaign -> dataset
    params = fit(ds, seed=0)                         # dataset -> model
    model.save(params, "artifacts/learned_params.json")
    compare(scn, backends=["learned"], params="artifacts/learned_params.json")
"""
from repro.learned import model
from repro.learned.dataset import (
    GROUND_TRUTH_BACKENDS,
    NUMERIC_FEATURES,
    Dataset,
    FlowTable,
    build_dataset,
    encode,
    flow_table,
    heldout_fraction_of,
)
from repro.learned.engine import (
    DEFAULT_PARAMS_PATH,
    LearnedEngine,
    OutOfDistributionError,
    load_params,
)
from repro.learned.fit import fct_error, fit, heldout_fct_error
from repro.learned.model import PARAMS_VERSION, LearnedParams

__all__ = [
    "GROUND_TRUTH_BACKENDS",
    "NUMERIC_FEATURES",
    "Dataset",
    "FlowTable",
    "build_dataset",
    "encode",
    "flow_table",
    "heldout_fraction_of",
    "DEFAULT_PARAMS_PATH",
    "LearnedEngine",
    "OutOfDistributionError",
    "load_params",
    "fct_error",
    "fit",
    "heldout_fct_error",
    "PARAMS_VERSION",
    "LearnedParams",
    "model",
]
