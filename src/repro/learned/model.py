"""Pure-JAX per-flow FCT predictor + versioned parameter serialization.

The model is a small MLP (tanh hidden layers, linear head) over the
encoded per-flow features of ``repro.learned.dataset``, predicting the
log slowdown ``log(fct / ideal_fct)``.  ``init``/``apply``/``loss`` are
plain functions over a ``[(W, b), ...]`` weight list so the fit loop can
``jax.grad`` through them and the engine can ``vmap``/batch them freely.

Fitted parameters are a :class:`LearnedParams`: the weight list plus a
``meta`` dict carrying everything serving needs — feature vocabulary,
standardization moments, the training envelope (per-feature min/max, the
out-of-distribution guard), and a content fingerprint.  ``save``/``load``
persist them as a JSON meta file plus a sibling ``.npz`` of weights;
like the RunStore does for ``record_version``, ``load`` refuses foreign
``params_version`` files instead of silently misreading them.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
from hashlib import sha256

import numpy as np

PARAMS_VERSION = 1


# ---------------------------------------------------------------------- #
# the network: init / apply / loss (jax imported lazily so dataset-only
# users — and the packet engines' worker processes — never pay for it)
# ---------------------------------------------------------------------- #
def init(seed: int, d_in: int, hidden: tuple[int, ...] = (64, 64)) -> list:
    """Fresh weight list ``[(W, b), ...]`` for ``d_in`` features."""
    import jax
    import jax.numpy as jnp
    sizes = (d_in, *hidden, 1)
    key = jax.random.PRNGKey(seed)
    weights = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b), jnp.float32) / np.sqrt(a)
        weights.append((w, jnp.zeros((b,), jnp.float32)))
    return weights


def apply(weights, x):
    """Forward pass: ``[N, D]`` standardized features -> ``[N]`` predicted
    log slowdown."""
    import jax.numpy as jnp
    h = x
    for w, b in weights[:-1]:
        h = jnp.tanh(h @ w + b)
    w, b = weights[-1]
    return (h @ w + b)[..., 0]


def loss(weights, x, y):
    """Mean squared error in log-slowdown space."""
    import jax.numpy as jnp
    return jnp.mean((apply(weights, x) - y) ** 2)


# ---------------------------------------------------------------------- #
# fitted parameters
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class LearnedParams:
    """Fitted weights + the ``meta`` serving contract (see module doc)."""
    weights: list[tuple[np.ndarray, np.ndarray]]
    meta: dict

    @property
    def fingerprint(self) -> str:
        return self.meta["fingerprint"]

    @property
    def d_in(self) -> int:
        return self.weights[0][0].shape[0]


def fingerprint_of(weights, meta: dict) -> str:
    """Content hash over the meta (sans any existing fingerprint) and the
    raw weight bytes — what RunResult extras report so a result can always
    be traced to the exact model that produced it."""
    h = sha256(json.dumps({k: v for k, v in sorted(meta.items())
                           if k != "fingerprint"},
                          sort_keys=True, default=str).encode())
    for w, b in weights:
        h.update(np.ascontiguousarray(w, np.float32).tobytes())
        h.update(np.ascontiguousarray(b, np.float32).tobytes())
    return h.hexdigest()[:16]


def make_params(weights, meta: dict) -> LearnedParams:
    """Seal ``meta`` with version + fingerprint and wrap into
    :class:`LearnedParams` (weights come back as numpy, detached from any
    jax buffers)."""
    weights = [(np.asarray(w, np.float32), np.asarray(b, np.float32))
               for w, b in weights]
    meta = dict(meta)
    meta["params_version"] = PARAMS_VERSION
    meta["fingerprint"] = fingerprint_of(weights, meta)
    return LearnedParams(weights=weights, meta=meta)


def _npz_path(path: pathlib.Path) -> pathlib.Path:
    return path.with_suffix(".npz") if path.suffix == ".json" \
        else path.with_name(path.name + ".npz")


def save(params: LearnedParams, path: str | os.PathLike) -> None:
    """Persist to ``path`` (JSON meta) + a sibling ``.npz`` (weights).
    Atomic per file, like the RunStore's record commits."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    npz = _npz_path(path)
    arrays = {}
    for i, (w, b) in enumerate(params.weights):
        arrays[f"w{i}"] = w
        arrays[f"b{i}"] = b
    tmp = npz.with_name(f".{npz.name}.tmp.{os.getpid()}")
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, npz)
    meta = dict(params.meta)
    meta["n_layers"] = len(params.weights)
    meta["weights_file"] = npz.name
    tmp = path.with_name(f".{path.name}.tmp.{os.getpid()}")
    tmp.write_text(json.dumps(meta, indent=1, sort_keys=True))
    os.replace(tmp, path)


def load(path: str | os.PathLike) -> LearnedParams:
    """Inverse of :meth:`save`.  Refuses foreign ``params_version`` files
    and fingerprint mismatches (a meta file paired with the wrong
    weights)."""
    path = pathlib.Path(path)
    meta = json.loads(path.read_text())
    version = meta.get("params_version")
    if version != PARAMS_VERSION:
        raise ValueError(
            f"{path} has params_version {version!r}, not the supported "
            f"{PARAMS_VERSION}; re-fit the model with this code version")
    n_layers = meta.pop("n_layers")
    npz = path.with_name(meta.pop("weights_file"))
    with np.load(npz) as arrays:
        weights = [(np.asarray(arrays[f"w{i}"], np.float32),
                    np.asarray(arrays[f"b{i}"], np.float32))
                   for i in range(n_layers)]
    want = meta.get("fingerprint")
    got = fingerprint_of(weights, meta)
    if want != got:
        raise ValueError(
            f"{path}: fingerprint {want!r} does not match weights in "
            f"{npz.name} ({got!r}) — meta and weights files are from "
            f"different fits")
    return LearnedParams(weights=weights, meta=meta)


def predict(params: LearnedParams, X: np.ndarray) -> np.ndarray:
    """Serving entry: standardize raw encoded features with the fitted
    moments and apply the network.  One call evaluates any batch size —
    the engine flattens whole scenario sweeps into a single invocation."""
    import jax.numpy as jnp
    mu = np.asarray(params.meta["mu"], np.float64)
    sigma = np.asarray(params.meta["sigma"], np.float64)
    xs = (np.asarray(X, np.float64) - mu) / sigma
    weights = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params.weights]
    return np.asarray(apply(weights, jnp.asarray(xs, jnp.float32)),
                      np.float64)
