"""Training loop: microbatch gradient accumulation, optional gradient
compression (error-feedback), step-atomic checkpoints, failure injection /
elastic restart, straggler tracking."""
from __future__ import annotations

import dataclasses
from functools import partial
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import TokenPipeline
from repro.models.api import Model
from repro.parallel.compression import (CompressionConfig,
                                        compress_decompress, init_residuals)
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.fault import FailureInjector, FaultManager


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    microbatches: int = 1
    log_every: int = 10
    opt: O.AdamWConfig = dataclasses.field(default_factory=O.AdamWConfig)
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    ckpt_dir: str = ""
    ckpt_every: int = 50


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    comp = tcfg.compression

    @partial(jax.jit, donate_argnums=(0, 1, 2))
    def step(params, opt_state, residuals, batch):
        mb = tcfg.microbatches

        def loss_of(p, b):
            return model.loss(p, b)

        if mb == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def split(x):
                return x.reshape(mb, x.shape[0] // mb, *x.shape[1:])
            batches = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                tot, acc = carry
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                return (tot + l, jax.tree.map(jnp.add, acc, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zero), batches)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        grads, residuals = compress_decompress(grads, residuals, comp)
        params, opt_state, metrics = O.update(params, grads, opt_state, tcfg.opt)
        return params, opt_state, residuals, loss, metrics["grad_norm"]

    return step


def train(model: Model, pipeline: TokenPipeline, tcfg: TrainConfig,
          params=None, injector: FailureInjector | None = None,
          extra_batch: dict | None = None) -> dict:
    """Returns {'losses': [...], 'params': ..., 'resumed_from': step|None}."""
    fm = FaultManager(tcfg.ckpt_dir, tcfg.ckpt_every) if tcfg.ckpt_dir else None
    start_step = 0
    opt_state = None
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    if fm is not None and fm.resume_info() is not None:
        tmpl = {"params": params, "opt": O.init_state(params, tcfg.opt)}
        state, manifest = C.restore(tcfg.ckpt_dir, template=tmpl)
        params, opt_state = state["params"], state["opt"]
        start_step = manifest["step"]
        pipeline.restore({"step": manifest["extra"]["data_step"],
                          "seed": pipeline.seed})
    if opt_state is None:
        opt_state = O.init_state(params, tcfg.opt)
    residuals = (init_residuals(params)
                 if tcfg.compression.kind != "none"
                 else jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), params))
    step_fn = make_train_step(model, tcfg)

    losses = []
    for step in range(start_step, tcfg.steps):
        if fm:
            fm.step_started()
        batch = pipeline.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if extra_batch:
            batch.update(extra_batch)
        if injector is not None:
            injector.maybe_fail(step)
        params, opt_state, residuals, loss, gnorm = step_fn(
            params, opt_state, residuals, batch)
        losses.append(float(loss))
        if fm:
            fm.step_finished(step)
            fm.maybe_save(step, params, opt_state,
                          {"data_step": pipeline.step})
        if step % tcfg.log_every == 0:
            print(f"step {step:5d} loss {float(loss):.4f} gnorm {float(gnorm):.3f}")
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "resumed_from": start_step or None,
            "stragglers": fm.straggler_steps if fm else []}
