"""Sharded checkpointing with elastic re-mesh on restore.

Save layout: one ``.npz`` per host-shard plus a JSON manifest
(step-atomic: written to a tmp dir, fsync'd, renamed).  Each param leaf is
saved as the *global* array split along its first sharded dim into
``n_shards`` pieces; restore re-assembles and re-shards onto whatever mesh
the new job brings up (any divisor count) — a 256-chip checkpoint restores
onto 8 devices in tests.

(Orbax would do this in production; the environment has no orbax, so this
is a dependency-free equivalent — same atomicity and re-mesh semantics.)
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil

import jax
import numpy as np


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def save(ckpt_dir: str | os.PathLike, step: int, params, opt_state=None,
         extra: dict | None = None, n_shards: int = 1) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step{step}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    names, leaves = zip(*list(_leaf_paths(state)))
    for shard in range(n_shards):
        arrs = {}
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf)
            if arr.ndim == 0 or arr.shape[0] % n_shards != 0:
                if shard == 0:
                    arrs[name] = arr
            else:
                k = arr.shape[0] // n_shards
                arrs[name] = arr[shard * k:(shard + 1) * k]
        np.savez(tmp / f"shard_{shard:04d}.npz", **arrs)
    manifest = {
        "step": step, "n_shards": n_shards, "names": list(names),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():              # overwrite (restart re-saves its resume step)
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | os.PathLike, step: int | None = None,
            template=None):
    """Re-assemble the global state.  ``template``: pytree of arrays or
    ShapeDtypeStructs (e.g. for a *different* mesh) — restored leaves are
    device_put with the template's sharding when available."""
    d = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(d)
    assert step is not None, f"no checkpoints under {d}"
    final = d / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    parts: dict[str, list[np.ndarray]] = {}
    for shard in range(manifest["n_shards"]):
        with np.load(final / f"shard_{shard:04d}.npz") as z:
            for name in z.files:
                parts.setdefault(name, []).append(z[name])
    flat = {}
    for name, pieces in parts.items():
        flat[name] = pieces[0] if len(pieces) == 1 else np.concatenate(pieces, 0)

    if template is None:
        return flat, manifest
    tmpl_flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl_leaf in tmpl_flat:
        name = jax.tree_util.keystr(path)
        arr = flat[name]
        if hasattr(tmpl_leaf, "sharding") and not isinstance(
                tmpl_leaf, jax.ShapeDtypeStruct):
            leaves.append(jax.device_put(arr.astype(tmpl_leaf.dtype),
                                         tmpl_leaf.sharding))
        elif isinstance(tmpl_leaf, jax.ShapeDtypeStruct) and tmpl_leaf.sharding:
            leaves.append(jax.device_put(arr.astype(tmpl_leaf.dtype),
                                         tmpl_leaf.sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, getattr(tmpl_leaf, "dtype", None)))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return state, manifest
