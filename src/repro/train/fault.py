"""Fault tolerance for 1000+-node runs.

The failure model: any host can die between (or during) steps.  Recovery =
step-atomic checkpoints + deterministic data pipeline + elastic re-mesh:

  * checkpoints publish atomically every ``ckpt_every`` steps (a crash never
    leaves a partial checkpoint visible);
  * on restart, FaultManager finds the latest step, restores params+opt
    onto the *current* mesh (which may be smaller: elastic), and skips the
    data pipeline ahead — the byte stream is identical by construction;
  * straggler mitigation at this layer is deadline-based: a step whose wall
    time exceeds ``straggler_factor ×`` the trailing median is recorded and
    surfaced (on real fleets this feeds the scheduler; in the simulator the
    same event appears as a compute-delay perturbation that Wormhole handles
    as an interrupt).

``FailureInjector`` drives the integration tests: it kills the training
loop at a chosen step and the harness restarts it.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

from repro.train import checkpoint as C


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: int = -1
    fired: bool = False

    def maybe_fail(self, step: int) -> None:
        if step == self.fail_at_step and not self.fired:
            self.fired = True
            raise InjectedFailure(f"injected host failure at step {step}")


@dataclasses.dataclass
class FaultManager:
    ckpt_dir: str
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    keep: int = 3

    def __post_init__(self) -> None:
        self._durations: list[float] = []
        self._t0 = None
        self.straggler_steps: list[int] = []

    # -- checkpoint cadence --------------------------------------------- #
    def maybe_save(self, step: int, params, opt_state, extra: dict) -> bool:
        if step % self.ckpt_every != 0 or step == 0:
            return False
        C.save(self.ckpt_dir, step, params, opt_state, extra)
        self._gc()
        return True

    def _gc(self) -> None:
        import pathlib
        d = pathlib.Path(self.ckpt_dir)
        ckpts = sorted(d.glob("step_*"))
        for old in ckpts[:-self.keep]:
            import shutil
            shutil.rmtree(old)

    def resume_info(self):
        return C.latest_step(self.ckpt_dir)

    # -- straggler detection --------------------------------------------- #
    def step_started(self) -> None:
        self._t0 = time.perf_counter()

    def step_finished(self, step: int) -> None:
        if self._t0 is None:
            return
        dt = time.perf_counter() - self._t0
        if len(self._durations) >= 8:
            med = statistics.median(self._durations[-32:])
            if dt > self.straggler_factor * med:
                self.straggler_steps.append(step)
        self._durations.append(dt)
