"""Training substrate: optimizer, train loop, checkpointing, fault
tolerance, gradient compression hooks."""
