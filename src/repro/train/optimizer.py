"""AdamW (from scratch — no optax in this environment) with global-norm
clipping, cosine schedule, and configurable state dtype (bf16 for the
≥100B-param configs so optimizer state fits the 16GB/chip HBM budget —
recorded per-arch in the roofline table)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        step_v = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step_v
        return (p2.astype(p.dtype), m2.astype(cfg.state_dtype),
                v2.astype(cfg.state_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}
