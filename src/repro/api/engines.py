"""Engine protocol + registry: interchangeable simulation backends.

Every backend consumes the same declarative :class:`Scenario` and returns
the same structured :class:`RunResult`, so fidelity is a one-word knob:

    packet    per-packet DES oracle (the ns-3 stand-in)
    wormhole  the same oracle under the memoizing/fast-forwarding kernel
    hybrid    adaptive per-partition packet/flow granularity (bounded error
              on *unsteady* traffic — the accuracy/speed axis)
    fluid     vectorized JAX rate dynamics (vmappable for batched sweeps)
    analytic  flow-level max-min fair sharing (cheapest, coarsest)
    learned   MLP fitted on campaign RunStores (``repro.learned``) — batched
              what-if queries at thousands of scenarios/sec, in-distribution

Third-party backends register with ``@register_engine("name")``.
"""
from __future__ import annotations

import dataclasses
import time

from repro.api.analytic import AnalyticSim
from repro.api.results import RunResult
from repro.api.scenario import Scenario
from repro.core.memo import SimDB
from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net import chaos as chaos_mod
from repro.net.hybrid_sim import FIDELITIES, HybridConfig, HybridKernel, HybridSim
from repro.net.packet_sim import PacketSim
from repro.net.sharded_sim import ShardedPacketSim
from repro.workload.driver import WorkloadDriver

# repro.net.fluid_jax (and with it jax) is imported lazily by FluidEngine:
# packet/wormhole runs — including run_many worker processes — must not pay
# the jax import or spin up its thread pools.

_REGISTRY: dict[str, type] = {}


def register_engine(name: str):
    """Class decorator: make ``name`` resolvable through ``get_engine``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_engine(name: str) -> "Engine":
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; "
            f"available: {', '.join(available_backends())}") from None
    return cls()


class Engine:
    """Backend protocol: evaluate scenarios into :class:`RunResult`s.

    ``uses_db = True`` declares that ``run`` accepts a ``db=`` SimDB —
    the seam campaigns use to thread their memo DB through a backend
    without hard-coding backend names.

    ``option_names`` declares the opts ``run`` accepts; the API layer
    (``Campaign.submit``/``sweep``/``compare`` and the CLI) rejects
    anything else through :meth:`check_opts` with one shared error naming
    the accepted set — so a typoed opt fails loudly instead of keying a
    phantom experiment or being silently swallowed by ``**opts``.  Leave
    it None (the default) to opt out of validation (third-party engines
    that have not declared their opts keep working unchecked)."""
    name = "abstract"
    uses_db = False
    option_names: tuple[str, ...] | None = None

    def check_opts(self, opts: dict) -> None:
        """Raise ValueError on any opt this backend does not accept."""
        if self.option_names is None:
            return
        unknown = sorted(set(opts) - set(self.option_names))
        if unknown:
            accepted = ", ".join(sorted(self.option_names)) or "(none)"
            raise ValueError(
                f"backend {self.name!r} does not accept "
                f"opt{'s' if len(unknown) > 1 else ''} "
                f"{', '.join(map(repr, unknown))}; accepted opts: "
                f"{accepted}")

    def run(self, scenario: Scenario, **opts) -> RunResult:
        raise NotImplementedError

    def run_batch(self, scenarios: list[Scenario], **opts) -> list[RunResult]:
        return [self.run(s, **opts) for s in scenarios]


# ---------------------------------------------------------------------- #
# packet-level backends (event simulators driven by the workload layer)
# ---------------------------------------------------------------------- #
def _drive(scenario: Scenario, sim) -> "WorkloadDriver | None":
    if scenario.kind == "workload":
        return WorkloadDriver(sim, scenario.build_phases())
    for fl in scenario.flows:
        sim.add_flow(dataclasses.replace(fl))
    plan = chaos_mod.plan_for(scenario)
    if plan is not None:
        # flow scenarios skip the phase DAG, so the phase-level mice
        # injectors land here: each arrival is a plain flow whose start
        # carries the phase's compute (= the Poisson arrival time)
        for ph in plan.mice_phases(scenario._n_hosts()):
            sim.add_flow(dataclasses.replace(ph.flows[0], start=ph.compute))
    return None


def _collect(backend: str, scenario: Scenario, sim, driver, wall: float,
             kernel_report: dict | None = None,
             record_rtt=()) -> RunResult:
    if driver is not None:
        assert driver.finished, f"{scenario.name}: program did not finish"
        iteration = driver.iteration_time
    elif sim.results:
        iteration = (max(r.finish for r in sim.results.values())
                     - min(r.start for r in sim.results.values()))
    else:
        iteration = None
    extras = {}
    if record_rtt:
        extras["rtt_samples"] = {fid: list(sim.flows[fid].rtt_samples)
                                 for fid in record_rtt}
    return RunResult(
        backend=backend, scenario=scenario.name,
        fcts={fid: r.fct for fid, r in sim.results.items()},
        flow_bytes={fid: r.bytes for fid, r in sim.results.items()},
        tags={fid: r.tag for fid, r in sim.results.items()},
        iteration_time=iteration, events_processed=sim.events_processed,
        wall_time=wall, kernel_report=kernel_report, extras=extras)


@register_engine("packet")
class PacketEngine(Engine):
    """Baseline per-packet DES — the accuracy oracle everything else is
    judged against.

    opts (shared by the wormhole subclass):
      parallel       None (single-heap serial loop) or ``"partitions"``
                     (partition-sharded loop, ``repro.net.sharded_sim``)
      intra_workers  worker processes for the sharded loop's heavy-lane
                     fan-out; 1 keeps sharded execution in-process.  Results
                     are identical to the serial loop for any value.
    """
    option_names = ("intra_workers", "parallel", "record_rtt", "until",
                    "validate")

    def _make_kernel(self, scenario: Scenario, **opts):
        return None, None

    def run(self, scenario: Scenario, record_rtt=(), until: float = float("inf"),
            parallel: str | None = None, intra_workers: int = 1,
            validate: bool = False, **opts) -> RunResult:
        plan = chaos_mod.plan_for(scenario)
        chaos_mod.check_backend(plan, self.name, intra_workers=intra_workers)
        topo = scenario.build_topology()
        kernel, report_fn = self._make_kernel(scenario, **opts)
        if parallel is None or parallel == "none":
            if intra_workers > 1 or validate:
                # silently running the serial loop would make the user
                # believe the fan-out (or invariant checking) was active
                raise ValueError(
                    "intra_workers/validate require parallel='partitions'")
            sim = PacketSim(topo, kernel=kernel, **scenario.sim)
        elif parallel == "partitions":
            sim = ShardedPacketSim(topo, kernel=kernel,
                                   intra_workers=intra_workers,
                                   validate=validate, **scenario.sim)
        else:
            raise ValueError(
                f"unknown parallel mode {parallel!r} (use 'partitions')")
        sim.record_rtt_fids = set(record_rtt)
        driver = _drive(scenario, sim)
        if plan is not None and plan.has_link_events:
            plan.install(sim)
        t0 = time.perf_counter()
        try:
            sim.run(until=until)
        finally:
            if parallel == "partitions":
                sim.close()
        wall = time.perf_counter() - t0
        result = _collect(self.name, scenario, sim, driver, wall,
                          kernel_report=report_fn() if report_fn else None,
                          record_rtt=record_rtt)
        if parallel == "partitions":
            result.extras["shard"] = sim.shard_report()
        return result


@register_engine("wormhole")
class WormholeEngine(PacketEngine):
    """Packet oracle + the Wormhole memoization/fast-forwarding kernel.

    opts:
      config   WormholeConfig or dict merged over scenario.kernel
      db       a SimDB to reuse across runs (cross-run warm cache, §6.1);
               per-run hit/lookup deltas land in kernel_report["run_db_*"].
               For a *durable* DB, open a campaign — ``Campaign.open(dir)``
               persists ``simdb.json`` automatically and ``python -m repro
               serve`` shares it across hosts — or manage an explicit
               ``SimDB.load_or_new``/``save`` pair yourself.
    """
    uses_db = True
    option_names = PacketEngine.option_names + ("config", "db")

    def run(self, scenario: Scenario, db: SimDB | None = None,
            **opts) -> RunResult:
        return super().run(scenario, db=db, **opts)

    def _make_kernel(self, scenario: Scenario, config=None, db: SimDB | None = None,
                     **opts):
        if isinstance(config, WormholeConfig):
            cfg = config
        else:
            cfg = WormholeConfig(**{**scenario.kernel, **(config or {})})
        kernel = WormholeKernel(cfg, db=db)
        hits0, lookups0 = kernel.db.hits, kernel.db.lookups

        def report():
            rep = kernel.report()
            rep["run_db_hits"] = kernel.db.hits - hits0
            rep["run_db_lookups"] = kernel.db.lookups - lookups0
            return rep
        return kernel, report


# ---------------------------------------------------------------------- #
# hybrid backend (adaptive per-partition packet/flow granularity)
# ---------------------------------------------------------------------- #
@register_engine("hybrid")
class HybridEngine(Engine):
    """HyGra-style adaptive granularity on the sharded packet loop: rate-
    stable partitions demote to a max-min-solver-driven flow-level lane and
    promote back on contention change (``repro.net.hybrid_sim``).  The
    third engine family — it trades *bounded* error for speed on unsteady
    traffic the pure-packet backends must simulate in full.

    opts:
      fidelity       "packet" (bit-identical to the sharded serial loop) |
                     "auto" (adaptive demote/promote, the default) |
                     "flow" (everything flow-level from t=0, coarsest)
      demote_after   stable samples before a partition demotes (auto mode)
      config         HybridConfig or dict merged over scenario.kernel
                     (foreign keys are ignored — scenarios share one
                     kernel-knob dict across backends)
      intra_workers  worker processes for heavy packet-lane fan-out, as in
                     the packet/wormhole backends

    ``RunResult.extras["granularity"]`` reports per-granularity event
    counts (packet_lane_events / flow_lane_events) and transition stats.
    """
    option_names = ("config", "demote_after", "fidelity", "intra_workers",
                    "record_rtt", "until", "validate")

    def run(self, scenario: Scenario, fidelity: str | None = None,
            demote_after: int | None = None, config=None,
            record_rtt=(), until: float = float("inf"),
            intra_workers: int = 1, validate: bool = False,
            **opts) -> RunResult:
        if isinstance(config, HybridConfig):
            cfg = dataclasses.replace(config)    # never mutate the caller's
        else:
            cfg = HybridConfig.from_knobs({**scenario.kernel, **(config or {})})
        # explicit engine opts override the config; an unset opt must not
        # clobber a fidelity carried by config=/scenario.kernel
        if fidelity is not None:
            cfg.fidelity = fidelity
        if demote_after is not None:
            cfg.demote_after = demote_after
        if cfg.fidelity not in FIDELITIES:
            raise ValueError(f"unknown fidelity {cfg.fidelity!r}; "
                             f"have {FIDELITIES}")
        plan = chaos_mod.plan_for(scenario)
        chaos_mod.check_backend(plan, self.name, intra_workers=intra_workers)
        topo = scenario.build_topology()
        kernel, report_fn = None, None
        if cfg.fidelity != "packet":
            kernel = HybridKernel(cfg)
            report_fn = kernel.report
        sim = HybridSim(topo, kernel=kernel, intra_workers=intra_workers,
                        validate=validate, **scenario.sim)
        sim.record_rtt_fids = set(record_rtt)
        driver = _drive(scenario, sim)
        if plan is not None and plan.has_link_events:
            plan.install(sim)
        t0 = time.perf_counter()
        try:
            sim.run(until=until)
        finally:
            sim.close()
        wall = time.perf_counter() - t0
        result = _collect(self.name, scenario, sim, driver, wall,
                          kernel_report=report_fn() if report_fn else None,
                          record_rtt=record_rtt)
        result.extras["granularity"] = sim.granularity_report()
        result.extras["shard"] = sim.shard_report()
        return result


# ---------------------------------------------------------------------- #
# fluid backend (JAX rate dynamics; vmapped over batches)
# ---------------------------------------------------------------------- #
@register_engine("fluid")
class FluidEngine(Engine):
    """DCTCP-form fluid dynamics: per-phase converged rates turn into FCT
    estimates; the phase DAG is scheduled analytically on top.  Coarser
    than the oracle (~10-20% FCT error) but three orders of magnitude
    cheaper, and ``run_batch`` evaluates a whole padded sweep in one
    vmapped compilation (§6.1 multi-experiment parallelism)."""
    option_names = ("dt", "steps")

    def run(self, scenario: Scenario, steps: int = 200, dt: float | None = None,
            **opts) -> RunResult:
        from repro.net.fluid_jax import FluidScenario, fluid_converged_rates
        chaos_mod.check_backend(chaos_mod.plan_for(scenario), self.name)
        topo = scenario.build_topology()
        phases = scenario.build_phases()
        t0 = time.perf_counter()
        fcts: dict[int, float] = {}
        flow_bytes: dict[int, float] = {}
        tags: dict[int, str] = {}
        done_t: list[float] = [0.0] * len(phases)
        total_steps = 0
        for i, ph in enumerate(phases):
            start = max((done_t[d] for d in set(ph.deps)), default=0.0) + ph.compute
            if scenario.kind == "flows":
                start += ph.flows[0].start if ph.flows else 0.0
            end = start
            if ph.flows:
                fs = FluidScenario.from_flows(
                    topo, [(f.fid, f.src, f.dst, f.size) for f in ph.flows])
                rates = fluid_converged_rates(fs, steps=steps, dt=dt)["rates"]
                total_steps += steps
                for f, rate in zip(ph.flows, rates):
                    fct = f.size / max(float(rate), 1e3)
                    fcts[f.fid] = fct
                    flow_bytes[f.fid] = f.size
                    tags[f.fid] = f.tag
                    end = max(end, start + fct)
            done_t[i] = end
        wall = time.perf_counter() - t0
        iteration = max(done_t) if done_t else None
        return RunResult(backend=self.name, scenario=scenario.name,
                         fcts=fcts, flow_bytes=flow_bytes, tags=tags,
                         iteration_time=iteration, events_processed=total_steps,
                         wall_time=wall)

    def run_batch(self, scenarios: list[Scenario], steps: int = 200,
                  dt: float | None = None, **opts) -> list[RunResult]:
        """Pad + vmap: one compiled program evaluates every flow scenario's
        converged rates at once (workload scenarios fall back to a loop)."""
        from repro.net.fluid_jax import FluidScenario, sweep_converged_rates
        for s in scenarios:
            chaos_mod.check_backend(chaos_mod.plan_for(s), self.name)
        if any(s.kind != "flows" for s in scenarios):
            return [self.run(s, steps=steps, dt=dt, **opts) for s in scenarios]
        dt = dt if dt is not None else 1e-5    # vmapped path needs one shared dt
        t0 = time.perf_counter()
        fls = [FluidScenario.from_flows(
            s.build_topology(), [(f.fid, f.src, f.dst, f.size) for f in s.flows])
            for s in scenarios]
        per_scn_rates = sweep_converged_rates(fls, dt=dt, steps=steps)
        wall = time.perf_counter() - t0
        out = []
        for s, rates in zip(scenarios, per_scn_rates):
            fcts, rate_map = {}, {}
            for f, rate in zip(s.flows, rates):
                fcts[f.fid] = f.size / max(float(rate), 1e3)
                rate_map[f.fid] = float(rate)
            finishes = [f.start + fcts[f.fid] for f in s.flows]
            out.append(RunResult(
                backend=self.name, scenario=s.name, fcts=fcts,
                flow_bytes={f.fid: f.size for f in s.flows},
                tags={f.fid: f.tag for f in s.flows},
                iteration_time=(max(finishes) - min(f.start for f in s.flows))
                if finishes else None,
                events_processed=steps, wall_time=wall / len(scenarios),
                extras={"rates": rate_map, "batch_wall": wall}))
        return out


# ---------------------------------------------------------------------- #
# analytic backend (flow-level max-min fair sharing)
# ---------------------------------------------------------------------- #
@register_engine("analytic")
class AnalyticEngine(Engine):
    """Progressive max-min fair-share model — the flow-level abstraction the
    paper positions against (§2.2).  Shares the WorkloadDriver, so it runs
    the same phase DAGs the packet backends do."""
    option_names = ("until",)

    def run(self, scenario: Scenario, until: float = float("inf"),
            **opts) -> RunResult:
        chaos_mod.check_backend(chaos_mod.plan_for(scenario), self.name)
        sim = AnalyticSim(scenario.build_topology())
        driver = _drive(scenario, sim)
        t0 = time.perf_counter()
        sim.run(until=until)
        wall = time.perf_counter() - t0
        return _collect(self.name, scenario, sim, driver, wall)


# the learned engine lives in its own package (it has a training half the
# registry does not need); a plain import is safe in either import order —
# repro.learned.engine only pulls names already defined above
import repro.learned.engine  # noqa: F401
