"""Shared campaign-store service: one warm store for every host (§6.1).

``python -m repro serve -c DIR`` exposes a campaign directory over HTTP so
remote clients share its content-addressed run records *and* its memo DB —
the paper's warm-replay collapse compounds across machines instead of
staying local.  Everything here is pure stdlib (``http.server`` /
``urllib``): the service rides along in spawn workers and minimal CI
environments without touching jax.

Server (:class:`StoreServer`) endpoints, all JSON:

    GET    /ping                     service info (record count, DB size)
    GET    /runs                     {"keys": [...]}
    GET    /runs/<key>               one record (404 when absent)
    PUT    /runs/<key>               commit a record (atomic on disk)
    PUT    /runs/<key>?if_absent=1   atomic create — the claim primitive
    DELETE /runs/<key>               drop a record
    GET    /simdb                    pull the full memo DB
    POST   /simdb                    push a delta; merged via SimDB.merge
    POST   /gc                       {"ttl": s} -> expire old records/claims
    GET    /metrics                  operator counters: store hits/misses/
                                     dedup hits, SimDB replay rate, claim
                                     creates/rejects/steals/releases

Client (:class:`RemoteBackend`) speaks the same :class:`~repro.api.store.
StoreBackend` protocol as the local backends, so a
:class:`~repro.api.store.RunStore` — and therefore a whole
:class:`~repro.api.campaign.Campaign` — runs against a server unchanged.
Reads fall through to a local ``fallback`` backend, and on server loss the
client degrades gracefully: after ``retries`` attempts with exponential
backoff it commits locally, remembers the pending keys, probes the server
every ``retry_interval`` seconds, and re-pushes everything pending on
reconnect — no lost or duplicated records (the store is content-addressed,
so a re-pushed record dedups server-side).

Consistency model: records are immutable-by-content (last write wins, and
:meth:`RunStore.put` verifies content equality on overwrite), claims are
advisory with TTL expiry, and the SimDB is merge-only (commutative,
idempotent - every push dedups against the server copy).  There is no
authentication: bind to localhost or a trusted network.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import threading
import time
import urllib.error
import urllib.request
import warnings
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api.store import (CLAIM_PREFIX, RECORD_VERSION, LocalDirBackend,
                             MemoryBackend, RunStore, StoreBackend,
                             stable_record_fingerprint)
from repro.core.memo import SimDB, SimDBMismatch

_KEY_RE = re.compile(r"^[A-Za-z0-9_-]{1,200}$")


class RemoteStoreError(OSError):
    """The store server could not be reached (after retries) or answered
    with a non-success status."""


# ---------------------------------------------------------------------- #
# server
# ---------------------------------------------------------------------- #
class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    owner: "StoreServer"


class _Handler(BaseHTTPRequestHandler):
    # keep-alive matters: a sweep makes hundreds of small requests
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):    # noqa: A003 - stdlib signature
        if not self.server.owner.quiet:
            super().log_message(fmt, *args)

    # -------------------------------------------------------------- #
    def _json(self, obj, status: int = 200) -> None:
        data = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _body(self):
        length = int(self.headers.get("Content-Length") or 0)
        if length == 0:
            return None
        return json.loads(self.rfile.read(length))

    def _route(self):
        path, _, query = self.path.partition("?")
        parts = [p for p in path.split("/") if p]
        return parts, query

    def _key(self, parts) -> str | None:
        if len(parts) != 2 or not _KEY_RE.match(parts[1]):
            self._json({"error": f"bad path {self.path!r}"}, 400)
            return None
        return parts[1]

    # -------------------------------------------------------------- #
    def do_GET(self) -> None:                                 # noqa: N802
        srv = self.server.owner
        parts, _ = self._route()
        if parts == ["ping"]:
            self._json(srv.info())
        elif parts == ["metrics"]:
            with srv.lock:
                self._json(srv.metrics_payload())
        elif parts == ["runs"]:
            self._json({"keys": srv.backend.keys()})
        elif parts and parts[0] == "runs":
            key = self._key(parts)
            if key is None:
                return
            rec = srv.backend.get(key)
            if not key.startswith(CLAIM_PREFIX):
                # claim polls are coordination noise, not cache traffic
                with srv.lock:
                    srv.metrics["store_gets"] += 1
                    srv.metrics["store_hits" if rec is not None
                                else "store_misses"] += 1
            if rec is None:
                self._json({"error": "not found"}, 404)
            else:
                self._json(rec)
        elif parts == ["simdb"]:
            with srv.lock:
                srv.metrics["simdb_pulls"] += 1
                self._json(srv.db.to_dict())
        else:
            self._json({"error": f"unknown path {self.path!r}"}, 404)

    def do_PUT(self) -> None:                                 # noqa: N802
        srv = self.server.owner
        parts, query = self._route()
        if not parts or parts[0] != "runs":
            self._json({"error": f"unknown path {self.path!r}"}, 404)
            return
        key = self._key(parts)
        if key is None:
            return
        record = self._body()
        if not isinstance(record, dict):
            self._json({"error": "body must be a JSON record"}, 400)
            return
        with srv.lock:
            if "if_absent=1" in query.split("&"):
                created = srv.backend.put_new(key, record)
                if key.startswith(CLAIM_PREFIX):
                    if not created:
                        srv.metrics["claim_rejects"] += 1
                    elif record.get("stolen"):
                        srv.metrics["claim_steals"] += 1
                    else:
                        srv.metrics["claim_creates"] += 1
                self._json({"created": created})
            else:
                if not key.startswith(CLAIM_PREFIX):
                    srv.metrics["store_puts"] += 1
                    prev = srv.backend.get(key)
                    if prev is not None and stable_record_fingerprint(prev) \
                            == stable_record_fingerprint(record):
                        # same content re-committed (work-stealing overlap
                        # or a resumed sweep) — the dedup the store's
                        # content addressing exists for
                        srv.metrics["dedup_hits"] += 1
                srv.backend.put(key, record)
                self._json({"created": True})

    def do_DELETE(self) -> None:                              # noqa: N802
        srv = self.server.owner
        parts, _ = self._route()
        if not parts or parts[0] != "runs":
            self._json({"error": f"unknown path {self.path!r}"}, 404)
            return
        key = self._key(parts)
        if key is None:
            return
        with srv.lock:
            deleted = srv.backend.delete(key)
            if deleted and key.startswith(CLAIM_PREFIX):
                srv.metrics["claim_releases"] += 1
            self._json({"deleted": deleted})

    def do_POST(self) -> None:                                # noqa: N802
        srv = self.server.owner
        parts, _ = self._route()
        if parts == ["simdb"]:
            delta = self._body()
            try:
                with srv.lock:
                    incoming = SimDB.from_dict(delta)
                    added = srv.db.merge(incoming)
                    srv.metrics["simdb_pushes"] += 1
                    srv.metrics["simdb_entries_pushed"] += len(incoming)
                    srv.metrics["simdb_entries_added"] += added
                    srv.save_db()
                    self._json({"added": added, "entries": len(srv.db)})
            except SimDBMismatch as exc:
                self._json({"error": str(exc)}, 409)
        elif parts == ["gc"]:
            body = self._body() or {}
            with srv.lock:
                removed = srv.store.gc(body.get("ttl"))
            self._json({"removed": removed})
        else:
            self._json({"error": f"unknown path {self.path!r}"}, 404)


class StoreServer:
    """Serve a campaign directory's run store + memo DB over HTTP.

    ``root`` follows the campaign layout (``runs/`` + ``simdb.json``), so
    serving an existing campaign shares everything it already learned.
    Mutations are serialized by one lock — claims (``if_absent``) and
    SimDB merges are race-free through a server.  ``ttl`` (seconds)
    enables a background GC sweep expiring old run records."""

    def __init__(self, root: str | os.PathLike, host: str = "127.0.0.1",
                 port: int = 0, ttl: float | None = None,
                 quiet: bool = True) -> None:
        self.root = pathlib.Path(root)
        self.backend = LocalDirBackend(self.root / "runs")
        self.store = RunStore(backend=self.backend)
        self.db = SimDB.load_or_new(str(self.root / "simdb.json"))
        self.ttl = ttl
        self.quiet = quiet
        self.lock = threading.Lock()
        # operator counters (GET /metrics), mutated under self.lock —
        # process-lifetime, not persisted with the campaign
        self.metrics: dict[str, int] = {
            "store_gets": 0, "store_hits": 0, "store_misses": 0,
            "store_puts": 0, "dedup_hits": 0,
            "claim_creates": 0, "claim_rejects": 0, "claim_steals": 0,
            "claim_releases": 0,
            "simdb_pulls": 0, "simdb_pushes": 0,
            "simdb_entries_pushed": 0, "simdb_entries_added": 0,
        }
        self._httpd = _HTTPServer((host, port), _Handler)
        self._httpd.owner = self
        self.host, self.port = self._httpd.server_address[:2]
        self.url = f"http://{self.host}:{self.port}"
        self._gc_stop = threading.Event()
        self._threads: list[threading.Thread] = []

    def info(self) -> dict:
        return {"service": "repro-store", "record_version": RECORD_VERSION,
                "runs": len(self.store), "db_entries": len(self.db),
                "ttl": self.ttl}

    def metrics_payload(self) -> dict:
        """Counters + derived rates for ``GET /metrics`` (call under
        ``self.lock``).  ``store_hit_rate`` answers "are user queries
        landing warm?"; ``simdb_replay_rate`` is the fraction of pushed
        memo entries the server already knew — cross-host warm replays."""
        m: dict = dict(self.metrics)
        m["store_hit_rate"] = (m["store_hits"] / m["store_gets"]
                               if m["store_gets"] else None)
        m["simdb_replay_rate"] = (
            1.0 - m["simdb_entries_added"] / m["simdb_entries_pushed"]
            if m["simdb_entries_pushed"] else None)
        m["runs"] = len(self.store)
        m["db_entries"] = len(self.db)
        return m

    def save_db(self) -> None:
        if len(self.db):
            self.db.save(str(self.root / "simdb.json"))

    def gc(self, ttl: float | None = None) -> list[str]:
        with self.lock:
            return self.store.gc(self.ttl if ttl is None else ttl)

    # -------------------------------------------------------------- #
    def _gc_loop(self) -> None:
        interval = max(1.0, min(self.ttl / 2.0, 60.0))
        while not self._gc_stop.wait(interval):
            self.gc()

    def start(self) -> "StoreServer":
        """Serve on background daemon threads; returns self (url bound)."""
        t = threading.Thread(target=self._httpd.serve_forever, daemon=True,
                             name="repro-store-server")
        t.start()
        self._threads.append(t)
        if self.ttl is not None:
            g = threading.Thread(target=self._gc_loop, daemon=True,
                                 name="repro-store-gc")
            g.start()
            self._threads.append(g)
        return self

    def serve_forever(self) -> None:
        self.start()
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass
        finally:
            self.shutdown()

    def shutdown(self) -> None:
        self._gc_stop.set()
        self._httpd.shutdown()
        self._httpd.server_close()
        with self.lock:
            self.save_db()


# ---------------------------------------------------------------------- #
# client
# ---------------------------------------------------------------------- #
class RemoteBackend(StoreBackend):
    """:class:`StoreBackend` over HTTP against a :class:`StoreServer`.

    Reads check the server first and fall through to ``fallback`` (a local
    backend), so records committed during an outage — or local history
    predating the attachment — stay visible.  Writes go to the server;
    when it is unreachable they degrade to the fallback and are re-pushed
    on reconnect (``pending`` tracks what still needs to go up)."""

    def __init__(self, url: str, timeout: float = 10.0, retries: int = 3,
                 backoff: float = 0.2, retry_interval: float = 5.0,
                 fallback: StoreBackend | None = None) -> None:
        self.url = url.rstrip("/")
        self.timeout = timeout
        self.retries = max(1, retries)
        self.backoff = backoff
        self.retry_interval = retry_interval
        self.fallback = fallback if fallback is not None else MemoryBackend()
        self.pending: set[str] = set()   # keys committed locally while down
        self.reconnects = 0
        self._down_since: float | None = None
        self._last_probe = 0.0

    # -------------------------------------------------------------- #
    # transport
    # -------------------------------------------------------------- #
    def _call(self, method: str, path: str, payload=None,
              retries: int | None = None):
        """One JSON request with retry/backoff.  HTTP 404 returns None;
        other HTTP errors and exhausted network retries raise
        :class:`RemoteStoreError` (the degradation trigger)."""
        body = None if payload is None else json.dumps(payload).encode()
        attempts = self.retries if retries is None else retries
        last: Exception | None = None
        for attempt in range(attempts):
            req = urllib.request.Request(
                self.url + path, data=body, method=method,
                headers={"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=self.timeout) as rsp:
                    data = rsp.read()
                    return json.loads(data) if data else None
            except urllib.error.HTTPError as exc:
                if exc.code == 404:
                    return None
                detail = ""
                try:
                    detail = json.loads(exc.read()).get("error", "")
                except Exception:
                    pass
                raise RemoteStoreError(
                    f"{method} {self.url}{path} -> HTTP {exc.code}"
                    f"{': ' + detail if detail else ''}") from exc
            except (urllib.error.URLError, OSError) as exc:
                last = exc
                if attempt + 1 < attempts:
                    time.sleep(self.backoff * (2 ** attempt))
        raise RemoteStoreError(
            f"{method} {self.url}{path} unreachable after {attempts} "
            f"attempts: {last}") from last

    # -------------------------------------------------------------- #
    # degradation / reconnect
    # -------------------------------------------------------------- #
    @property
    def degraded(self) -> bool:
        return self._down_since is not None

    def _mark_down(self) -> None:
        if self._down_since is None:
            self._down_since = time.time()
            warnings.warn(
                f"store server {self.url} unreachable — degrading to "
                f"local-only commits (retrying every "
                f"{self.retry_interval:g}s; pending records re-push on "
                f"reconnect)", RuntimeWarning, stacklevel=4)
        self._last_probe = time.time()

    def _up(self) -> bool:
        """True when the server should be attempted: healthy, or down but
        due for a probe (which also flushes pending records on success)."""
        if self._down_since is None:
            return True
        if time.time() - self._last_probe < self.retry_interval:
            return False
        self._last_probe = time.time()
        try:
            self._call("GET", "/ping", retries=1)
        except RemoteStoreError:
            return False
        self._down_since = None
        self.reconnects += 1
        self._flush_pending()
        return True

    def _flush_pending(self) -> None:
        for key in sorted(self.pending):
            rec = self.fallback.get(key)
            if rec is None:
                self.pending.discard(key)
                continue
            try:
                self._call("PUT", f"/runs/{key}", rec)
                self.pending.discard(key)
            except RemoteStoreError:
                self._mark_down()
                return

    def ping(self) -> dict | None:
        try:
            return self._call("GET", "/ping", retries=1)
        except RemoteStoreError:
            return None

    def metrics(self) -> dict | None:
        """The server's operator counters (None when unreachable)."""
        try:
            return self._call("GET", "/metrics", retries=1)
        except RemoteStoreError:
            return None

    # -------------------------------------------------------------- #
    # StoreBackend protocol
    # -------------------------------------------------------------- #
    def get(self, key: str) -> dict | None:
        if self._up():
            try:
                rec = self._call("GET", f"/runs/{key}")
                if rec is not None:
                    return rec
            except RemoteStoreError:
                self._mark_down()
        return self.fallback.get(key)

    def put(self, key: str, record: dict) -> None:
        if self._up():
            try:
                self._call("PUT", f"/runs/{key}", record)
                return
            except RemoteStoreError:
                self._mark_down()
        self.fallback.put(key, record)
        self.pending.add(key)

    def put_new(self, key: str, record: dict) -> bool:
        if self._up():
            try:
                rsp = self._call("PUT", f"/runs/{key}?if_absent=1", record)
                return bool(rsp["created"])
            except RemoteStoreError:
                self._mark_down()
        return self.fallback.put_new(key, record)

    def delete(self, key: str) -> bool:
        local = self.fallback.delete(key)
        self.pending.discard(key)
        if self._up():
            try:
                rsp = self._call("DELETE", f"/runs/{key}")
                return bool(rsp["deleted"]) or local
            except RemoteStoreError:
                self._mark_down()
        return local

    def keys(self) -> list[str]:
        if self._up():
            try:
                remote = self._call("GET", "/runs")["keys"]
                return sorted(set(remote) | set(self.fallback.keys()))
            except RemoteStoreError:
                self._mark_down()
        return self.fallback.keys()

    def age(self, key: str) -> float | None:
        # ages live on the server (file mtimes); remote GC goes through
        # server_gc instead of the generic keys+age+delete sweep
        return None

    # -------------------------------------------------------------- #
    # service extensions (RunStore discovers these by duck typing)
    # -------------------------------------------------------------- #
    def server_gc(self, ttl: float | None) -> list[str]:
        """Run TTL GC on the server; returns removed keys ([] when
        degraded — a GC can wait for reconnection)."""
        if not self._up():
            return []
        try:
            return self._call("POST", "/gc", {"ttl": ttl})["removed"]
        except RemoteStoreError:
            self._mark_down()
            return []

    def simdb_pull(self) -> SimDB | None:
        """The server's full memo DB (None when degraded)."""
        if not self._up():
            return None
        try:
            return SimDB.from_dict(self._call("GET", "/simdb"))
        except RemoteStoreError:
            self._mark_down()
            return None

    def simdb_push(self, entries: list[dict], fingerprint: str | None) -> bool:
        """Push a delta of memo entries for the server to merge; True on
        success (False leaves the caller's outbox intact for a retry)."""
        if not entries or not self._up():
            return False
        from repro.core.memo import FORMAT_VERSION
        try:
            self._call("POST", "/simdb", {
                "format_version": FORMAT_VERSION,
                "fingerprint": fingerprint,
                "entries": entries,
            })
            return True
        except RemoteStoreError as exc:
            if "HTTP 409" in str(exc):
                raise SimDBMismatch(
                    f"store server {self.url} holds a SimDB from a "
                    f"different simulator regime: {exc}") from exc
            self._mark_down()
            return False


# ---------------------------------------------------------------------- #
# CLI entry (python -m repro serve)
# ---------------------------------------------------------------------- #
def run_server(root: str, host: str = "127.0.0.1", port: int = 0,
               ttl: float | None = None, quiet: bool = False) -> int:
    """Blocking server loop for the CLI; prints the bound URL first (port
    0 binds an ephemeral port, so callers parse the line)."""
    server = StoreServer(root, host=host, port=port, ttl=ttl, quiet=quiet)
    print(f"serving campaign store at {server.url} "
          f"(root={root}, {len(server.store)} runs, "
          f"{len(server.db)} db entries"
          + (f", ttl={ttl:g}s" if ttl is not None else "") + ")",
          flush=True)
    server.serve_forever()
    return 0
