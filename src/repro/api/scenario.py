"""Declarative, JSON-serializable experiment scenarios.

A :class:`Scenario` is the single input every simulation backend consumes:
a topology spec, either an explicit flow list or a workload-preset training
program, plus kernel / simulator knobs.  Because it is pure data
(``to_dict``/``from_dict`` round-trip exactly), a scenario can be stored,
diffed, swept over (``variant``) and handed to any registered engine — the
"one declarative scenario, interchangeable fidelity backends" framing of
m4 / HyGra applied to this repo's packet / wormhole / fluid / analytic
stack.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any

from repro.net.chaos import ChaosPlan
from repro.net.flows import FlowSpec
from repro.net.topology import TOPOLOGY_BUILDERS, Topology
from repro.workload import presets
from repro.workload.traffic import Phase, build_training_program


@dataclasses.dataclass
class TopologySpec:
    """Declarative fabric: a ``TOPOLOGY_BUILDERS`` key plus builder kwargs."""
    kind: str
    params: dict[str, Any] = dataclasses.field(default_factory=dict)

    def build(self) -> Topology:
        try:
            builder = TOPOLOGY_BUILDERS[self.kind]
        except KeyError:
            raise ValueError(
                f"unknown topology kind {self.kind!r}; "
                f"have {sorted(TOPOLOGY_BUILDERS)}") from None
        return builder(**self.params)


@dataclasses.dataclass
class WorkloadSpec:
    """A Table-1 training program by reference (family + size + knobs)."""
    family: str = "gpt"                  # gpt | moe
    n_gpus: int = 64
    cca: str = "hpcc"
    scale: float = 1 / 256               # flow-size scale vs the real workload
    ep_over_dp: int = 0                  # 0 -> family default (MoE: EP from DP)
    num_microbatches: int | None = None
    straggler: tuple[int, float] | None = None  # (rank, compute multiplier)
    collective: str = "ring"             # DP gradient-sync schedule
                                         # (workload.schedules.SCHEDULES key)

    def build_phases(self, topo_meta: dict | None = None,
                     extra_stragglers: dict[int, float] | None = None,
                     ) -> list[Phase]:
        spec, par, ep_default = presets.resolve(self.family, self.n_gpus)
        ep = self.ep_over_dp or ep_default
        return build_training_program(
            spec, par, cca=self.cca, scale=self.scale, ep_over_dp=ep,
            num_microbatches=self.num_microbatches, straggler=self.straggler,
            collective=self.collective, topo_meta=topo_meta,
            extra_stragglers=extra_stragglers)


@dataclasses.dataclass
class Scenario:
    """One experiment: topology + traffic (flows XOR workload) + knobs.

    ``kernel`` holds WormholeConfig overrides (used by the wormhole backend),
    ``sim`` holds PacketSim knobs (mtu, ecn_k, buffer_bytes, ...) shared by
    the packet-level backends, ``chaos`` is a list of perturbation-injector
    dicts (see :mod:`repro.net.chaos`) every backend derives identically.
    """
    name: str
    topology: TopologySpec
    flows: list[FlowSpec] | None = None
    workload: WorkloadSpec | None = None
    kernel: dict[str, Any] = dataclasses.field(default_factory=dict)
    sim: dict[str, Any] = dataclasses.field(default_factory=dict)
    chaos: list[dict] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if (self.flows is None) == (self.workload is None):
            raise ValueError("Scenario needs exactly one of flows / workload")

    @property
    def kind(self) -> str:
        return "flows" if self.flows is not None else "workload"

    # ------------------------------------------------------------------ #
    # builders
    # ------------------------------------------------------------------ #
    def build_topology(self) -> Topology:
        return self.topology.build()

    def build_phases(self) -> list[Phase]:
        """Traffic as a phase DAG.  Explicit flows become one dependency-free
        phase per distinct start time (each flow keeps its own launch).

        Phase-level chaos injectors land here — straggler multipliers fold
        into the workload's compute times and mice arrivals append as
        dep-free phases — so every engine, packet through analytic, drives
        the identical perturbed program.
        """
        plan = ChaosPlan.parse(self.chaos) if self.chaos else None
        if self.workload is not None:
            phases = self.workload.build_phases(
                topo_meta=dict(self.topology.params),
                extra_stragglers=(plan.straggler_map(self.workload.n_gpus)
                                  if plan else None))
        else:
            by_start: dict[float, list[FlowSpec]] = {}
            for f in self.flows:
                by_start.setdefault(f.start, []).append(f)
            phases = [Phase(f"flows@{t:g}", fl, [], 0.0)
                      for t, fl in sorted(by_start.items())]
        if plan is not None:
            phases = phases + plan.mice_phases(self._n_hosts())
        return phases

    def _n_hosts(self) -> int:
        """Host-id universe for seeded injectors (no topology build)."""
        if self.workload is not None:
            return self.workload.n_gpus
        return max(max(f.src, f.dst) for f in self.flows) + 1

    # ------------------------------------------------------------------ #
    # serialization
    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        d: dict[str, Any] = {
            "name": self.name,
            "topology": {"kind": self.topology.kind,
                         "params": dict(self.topology.params)},
            "kernel": dict(self.kernel),
            "sim": dict(self.sim),
        }
        if self.flows is not None:
            d["flows"] = [dataclasses.asdict(f) for f in self.flows]
        if self.workload is not None:
            w = dataclasses.asdict(self.workload)
            if w["straggler"] is not None:
                w["straggler"] = list(w["straggler"])
            if w["collective"] == "ring":
                # default elided: pre-collective scenario fingerprints (and
                # every run_key derived from them) stay byte-identical
                del w["collective"]
            d["workload"] = w
        if self.chaos:
            # same default-elision contract as collective=: an empty
            # injector list serializes exactly as the pre-chaos schema
            d["chaos"] = [dict(c) for c in self.chaos]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        flows = None
        if "flows" in d:
            flows = [FlowSpec(**f) for f in d["flows"]]
        workload = None
        if "workload" in d:
            w = dict(d["workload"])
            if w.get("straggler") is not None:
                w["straggler"] = tuple(w["straggler"])
            workload = WorkloadSpec(**w)
        return cls(
            name=d["name"],
            topology=TopologySpec(d["topology"]["kind"],
                                  dict(d["topology"].get("params", {}))),
            flows=flows, workload=workload,
            kernel=dict(d.get("kernel", {})), sim=dict(d.get("sim", {})),
            chaos=[dict(c) for c in d.get("chaos", [])],
        )

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------------ #
    # sweeps
    # ------------------------------------------------------------------ #
    def variant(self, name: str | None = None, *, cca: str | None = None,
                size_scale: float | None = None,
                kernel: dict | None = None, sim: dict | None = None,
                topology: TopologySpec | None = None,
                chaos: list[dict] | None = None,
                **workload_overrides) -> "Scenario":
        """A deep copy with common sweep axes overridden: CCA, flow-size
        scale, kernel/sim knob merges, topology swap, chaos injector list
        replacement, or workload fields."""
        scn = Scenario.from_dict(self.to_dict())
        if name is not None:
            scn.name = name
        if topology is not None:
            scn.topology = topology
        if kernel:
            scn.kernel = {**scn.kernel, **kernel}
        if sim:
            scn.sim = {**scn.sim, **sim}
        if chaos is not None:
            scn.chaos = [dict(c) for c in chaos]
        if scn.flows is not None:
            if workload_overrides:
                raise ValueError(
                    f"flow scenario takes no workload overrides "
                    f"{sorted(workload_overrides)}")
            if cca is not None or size_scale is not None:
                scn.flows = [dataclasses.replace(
                    f, cca=cca if cca is not None else f.cca,
                    size=f.size * (size_scale or 1.0)) for f in scn.flows]
        else:
            w = scn.workload
            if cca is not None:
                w.cca = cca
            if size_scale is not None:
                w.scale *= size_scale
            for k, v in workload_overrides.items():
                if not hasattr(w, k):
                    raise ValueError(f"WorkloadSpec has no field {k!r}")
                setattr(w, k, v)
        return scn


# ---------------------------------------------------------------------- #
# convenience constructors
# ---------------------------------------------------------------------- #
def training_scenario(n_gpus: int = 64, moe: bool = False, cca: str = "hpcc",
                      scale: float = 1 / 256, name: str | None = None,
                      gpus_per_server: int = 8, bw: float = 12.5e9,
                      chaos: list[dict] | None = None,
                      **workload_kw) -> Scenario:
    """The paper's headline setup: a Table-1 workload on its rail-optimized
    fat-tree (presets.topology_for), as a declarative scenario."""
    topo = TopologySpec("roft", {
        "n_servers": max(2, max(n_gpus, 16) // gpus_per_server),
        "gpus_per_server": gpus_per_server,
        "leaf_radix": 32, "n_spines": 8, "bw": bw,
    })
    wl = WorkloadSpec(family="moe" if moe else "gpt", n_gpus=n_gpus,
                      cca=cca, scale=scale, **workload_kw)
    if name is None:
        # the auto-name keys benchmark baseline caches: make it a function
        # of everything that changes the traffic program
        inv = 1 / scale if scale else 0
        stxt = f"1/{inv:g}" if abs(inv - round(inv)) < 1e-9 and inv >= 1 \
            else f"{scale:g}"
        name = f"{wl.family}@{n_gpus}-{cca}-s{stxt}"
        if wl.ep_over_dp:
            name += f"-ep{wl.ep_over_dp}"
        if wl.num_microbatches is not None:
            name += f"-mb{wl.num_microbatches}"
        if wl.straggler is not None:
            name += f"-straggler{wl.straggler[0]}x{wl.straggler[1]:g}"
        if wl.collective != "ring":
            name += f"-{wl.collective}"
        if chaos:
            digest = hashlib.sha256(
                json.dumps(chaos, sort_keys=True).encode()).hexdigest()[:6]
            name += f"-chaos{digest}"
    return Scenario(name=name, topology=topo, workload=wl,
                    chaos=[dict(c) for c in chaos] if chaos else [])
