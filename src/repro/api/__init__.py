"""Unified experiment layer: declarative scenarios, pluggable engines,
durable campaigns.

    from repro.api import Scenario, run, run_many, compare

    scn = training_scenario(n_gpus=64, cca="hpcc")
    result = run(scn, backend="wormhole")          # one RunResult
    table = compare(scn, backends=("packet", "wormhole", "fluid"))
    sweep = run_many([scn.variant(cca=c) for c in ("dctcp", "hpcc")],
                     backend="wormhole", shared_db=True)

Durable + resumable (§6.1): a Campaign is a named on-disk session — every
completed run is committed immediately, identical submissions are served
from the store, and the campaign's SimDB keeps warm across sessions:

    from repro.api import Campaign
    with Campaign.open("experiments/cca") as camp:
        camp.sweep(variants, backend="wormhole", workers=2)
    # re-opening resumes: completed runs are cache hits, the rest run

Shared store service (§6.1 across hosts): ``python -m repro serve -c dir``
exposes a campaign's store + memo DB over HTTP; any client that opens the
campaign with ``store="http://host:port"`` (or ``Campaign.open(url)``)
shares cache hits, warm wormhole replays, and work-stealing sweeps with
every other host on the same server.

The same API drives the CLI: ``python -m repro
{run,sweep,compare,serve,ls,show,rm}``.
"""
from repro.api.campaign import Campaign, RunEvent, RunHandle
from repro.api.engines import (Engine, available_backends, get_engine,
                               register_engine)
from repro.api.results import Comparison, RunResult, summarize_pair
from repro.api.runner import compare, run, run_many
from repro.api.scenario import (Scenario, TopologySpec, WorkloadSpec,
                                training_scenario)
from repro.api.serve import RemoteBackend, StoreServer
from repro.api.store import (RunStore, StoreBackend, run_key,
                             scenario_fingerprint)
from repro.core.memo import SimDB, SimDBMismatch
from repro.net.flows import FlowSpec

__all__ = [
    "Scenario", "TopologySpec", "WorkloadSpec", "FlowSpec",
    "training_scenario",
    "Engine", "register_engine", "get_engine", "available_backends",
    "RunResult", "summarize_pair",
    "run", "run_many", "compare", "Comparison",
    "Campaign", "RunEvent", "RunHandle",
    "RunStore", "StoreBackend", "run_key", "scenario_fingerprint",
    "RemoteBackend", "StoreServer",
    "SimDB", "SimDBMismatch",
]
