"""Unified experiment layer: declarative scenarios, pluggable engines.

    from repro.api import Scenario, run, run_many, compare

    scn = training_scenario(n_gpus=64, cca="hpcc")
    result = run(scn, backend="wormhole")          # one RunResult
    table = compare(scn, backends=("packet", "wormhole", "fluid"))
    sweep = run_many([scn.variant(cca=c) for c in ("dctcp", "hpcc")],
                     backend="wormhole", shared_db=True)
    # durable + parallel (§6.1): 2 worker processes, memo DB persisted so
    # the next session's sweep starts warm
    sweep = run_many(variants, backend="wormhole", workers=2,
                     db_path="simdb.json")
"""
from repro.api.engines import (Engine, available_backends, get_engine,
                               register_engine)
from repro.api.results import RunResult, summarize_pair
from repro.api.runner import Comparison, compare, run, run_many
from repro.api.scenario import (Scenario, TopologySpec, WorkloadSpec,
                                training_scenario)
from repro.core.memo import SimDB, SimDBMismatch
from repro.net.flows import FlowSpec

__all__ = [
    "Scenario", "TopologySpec", "WorkloadSpec", "FlowSpec",
    "training_scenario",
    "Engine", "register_engine", "get_engine", "available_backends",
    "RunResult", "summarize_pair",
    "run", "run_many", "compare", "Comparison",
    "SimDB", "SimDBMismatch",
]
