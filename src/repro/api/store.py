"""Content-addressed store of completed runs — the durable half of a
:class:`~repro.api.campaign.Campaign`.

Every completed ``(scenario, backend, opts)`` evaluation is committed under
its :func:`run_key` — a stable hash of the scenario's canonical JSON form,
the backend name and the JSON-canonicalized engine opts.  Submitting the
same triple again finds the stored record instead of simulating, which is
what makes a half-finished sweep resumable: the store is the ground truth
of what already ran.

Storage is split behind a small transport-agnostic seam:

* :class:`StoreBackend` — the protocol (``get``/``put``/``put_new``/
  ``delete``/``keys``/``records``/``age``): string key -> JSON record.
* :class:`MemoryBackend` — the anonymous campaigns behind
  ``repro.api.run``/``run_many`` (nothing written to disk).
* :class:`LocalDirBackend` — one JSON file per run, written atomically via
  rename, so a killed sweep never leaves a torn record.
* ``repro.api.serve.RemoteBackend`` — the same protocol over HTTP against
  a ``python -m repro serve`` endpoint, so many hosts share one store.

:class:`RunStore` is the policy layer on top of whichever backend: record
canonicalization (results pass through the ``RunResult.to_dict``/
``from_dict`` JSON round-trip on ``put``, so a cached result is
byte-for-byte what a re-opened campaign would read from disk), version
checking, dedup verification on overwrite, advisory *claim* records for
multi-host work stealing, and TTL garbage collection.
"""
from __future__ import annotations

import copy
import itertools
import json
import os
import pathlib
import time
import warnings
from hashlib import sha256
from collections.abc import Iterator

from repro.api.results import RunResult, jsonify
from repro.api.scenario import Scenario

RECORD_VERSION = 1

# claims are plain records living in the same keyspace under this prefix;
# run keys are 40 lowercase hex chars, so the prefix can never collide
CLAIM_PREFIX = "claim--"
DEFAULT_CLAIM_TTL = 600.0


class _Raw(tuple):
    """In-memory put defers record canonicalization to first read."""
    __slots__ = ()

    def __new__(cls, scenario, backend, opts, result):
        return super().__new__(cls, (scenario, backend, opts, result))


def _dict_fingerprint(d: dict) -> str:
    return sha256(json.dumps(d, sort_keys=True,
                             separators=(",", ":")).encode()).hexdigest()


def scenario_fingerprint(scenario: Scenario) -> str:
    """Stable content hash of a scenario's canonical JSON form."""
    return _dict_fingerprint(scenario.to_dict())


# every submit carrying an opt with no canonical JSON form is its own
# experiment — see _key_form
_UNCACHEABLE = itertools.count(1)


def _key_form(x):
    """Canonical key form of an opt value: :func:`jsonify`, except objects
    with no canonical JSON form (live SimDB handles, open files) become a
    process-unique token instead of ``repr`` — a repr can truncate (large
    ndarrays) or embed a reusable memory address, either of which could
    collide two distinct experiments onto one store key.  Such opts are
    uncacheable: every submit keys uniquely."""
    return jsonify(x, fallback=lambda v:
                   f"<uncacheable {type(v).__name__} #{next(_UNCACHEABLE)}>")


def run_key(scenario: Scenario, backend: str, opts: dict) -> str:
    """The store's content address: ``(scenario fingerprint, backend,
    canonicalized opts)`` hashed into one stable hex key.  Opts with no
    canonical JSON form never dedup (each submit is its own experiment)."""
    blob = json.dumps({
        "scenario_fingerprint": scenario_fingerprint(scenario),
        "backend": backend,
        "opts": _key_form(opts),
    }, sort_keys=True, separators=(",", ":"))
    return sha256(blob.encode()).hexdigest()[:40]


def stable_record_fingerprint(record: dict) -> str:
    """Content hash of a run record with its inherently nondeterministic
    fields (wall-clock timings) masked out — what :meth:`RunStore.put`
    compares when a key is committed twice.  Two runs of a deterministic
    engine on the same triple agree on this fingerprint even though their
    ``wall_time`` differs."""
    rec = copy.deepcopy(record)
    result = rec.get("result")
    if isinstance(result, dict):
        result.pop("wall_time", None)
        extras = result.get("extras")
        if isinstance(extras, dict):
            extras.pop("batch_wall", None)
    return _dict_fingerprint(rec)


# ---------------------------------------------------------------------- #
# backends: the transport-agnostic seam
# ---------------------------------------------------------------------- #
class StoreBackend:
    """Protocol for record storage: string key -> JSON-serializable dict.

    Implementations must make ``put`` atomic (a reader never observes a
    torn record) and ``put_new`` an atomic create-if-absent (the primitive
    claims are built on).  ``age`` reports seconds since a key was last
    written (or None when unknown) — the TTL/GC clock.
    """

    def get(self, key: str) -> dict | None:
        raise NotImplementedError

    def put(self, key: str, record: dict) -> None:
        raise NotImplementedError

    def put_new(self, key: str, record: dict) -> bool:
        """Atomically create ``key`` iff absent; True when this call won."""
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> list[str]:
        raise NotImplementedError

    def records(self) -> Iterator[dict]:
        for key in self.keys():
            rec = self.get(key)
            if rec is not None:
                yield rec

    def age(self, key: str) -> float | None:
        return None

    def close(self) -> None:
        pass


class MemoryBackend(StoreBackend):
    """Process-lifetime dict backing (anonymous campaigns)."""

    def __init__(self) -> None:
        self._mem: dict[str, dict] = {}
        self._written_at: dict[str, float] = {}

    def get(self, key: str) -> dict | None:
        ent = self._mem.get(key)
        if isinstance(ent, _Raw):
            # first read materializes the canonical record — the same JSON
            # form a disk backing would hand back.  Anonymous campaigns
            # behind run()/run_many() never read their own store, so they
            # never pay this.
            ent = json.loads(json.dumps(RunStore._record(key, *ent)))
            self._mem[key] = ent
        return ent

    def put(self, key: str, record: dict) -> None:
        self._mem[key] = record
        self._written_at[key] = time.time()

    def put_lazy(self, key: str, scenario, backend, opts, result) -> None:
        """Defer canonicalization to first read (see :class:`_Raw`)."""
        self._mem[key] = _Raw(scenario, backend, opts, result)
        self._written_at[key] = time.time()

    def put_new(self, key: str, record: dict) -> bool:
        if key in self._mem:
            return False
        self.put(key, record)
        return True

    def delete(self, key: str) -> bool:
        self._written_at.pop(key, None)
        return self._mem.pop(key, None) is not None

    def keys(self) -> list[str]:
        return sorted(self._mem)

    def age(self, key: str) -> float | None:
        t = self._written_at.get(key)
        return None if t is None else max(0.0, time.time() - t)


class LocalDirBackend(StoreBackend):
    """One ``<key>.json`` file per record, committed by atomic rename.

    A truncated or garbled file (torn copy, disk fault — our own writes
    are atomic) reads as absent with a one-shot warning, so one bad record
    can't poison dataset extraction or a resumed sweep; rewriting the key
    heals it.  ``corrupt_keys`` lists the currently-unparsable keys.
    """

    def __init__(self, path: str | os.PathLike) -> None:
        self.path = pathlib.Path(path)
        self.path.mkdir(parents=True, exist_ok=True)
        self._corrupt: set[str] = set()

    def _file(self, key: str) -> pathlib.Path:
        return self.path / f"{key}.json"

    def get(self, key: str) -> dict | None:
        try:
            with open(self._file(key)) as fh:
                rec = json.load(fh)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, UnicodeDecodeError):
            if key not in self._corrupt:
                self._corrupt.add(key)
                warnings.warn(
                    f"skipping corrupt run record {self._file(key)} "
                    f"(unparsable JSON); see RunStore.corrupt_keys()",
                    RuntimeWarning, stacklevel=4)
            return None
        self._corrupt.discard(key)
        return rec

    def _write_tmp(self, key: str, record: dict) -> pathlib.Path:
        tmp = self.path / f".{key}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump(record, fh)
        return tmp

    def put(self, key: str, record: dict) -> None:
        os.replace(self._write_tmp(key, record), self._file(key))

    def put_new(self, key: str, record: dict) -> bool:
        # os.link refuses to clobber, atomically even over NFS — the
        # multi-process-safe create-if-absent that claims ride on
        tmp = self._write_tmp(key, record)
        try:
            os.link(tmp, self._file(key))
            return True
        except FileExistsError:
            return False
        finally:
            os.unlink(tmp)

    def delete(self, key: str) -> bool:
        try:
            os.remove(self._file(key))
            return True
        except FileNotFoundError:
            return False

    def keys(self) -> list[str]:
        return sorted(p.stem for p in self.path.glob("*.json")
                      if not p.name.startswith("."))

    def age(self, key: str) -> float | None:
        try:
            return max(0.0, time.time() - os.stat(self._file(key)).st_mtime)
        except FileNotFoundError:
            return None

    def corrupt_keys(self) -> set[str]:
        return self._corrupt


# ---------------------------------------------------------------------- #
# the policy layer
# ---------------------------------------------------------------------- #
class RunStore:
    """Keyed store of completed runs over a :class:`StoreBackend`.

    ``RunStore(path)`` keeps the historical constructor: ``path=None`` is a
    :class:`MemoryBackend`, a path a :class:`LocalDirBackend`; pass
    ``backend=`` for anything else (a remote store).  ``hits``/``misses``
    count :meth:`get` outcomes — the dedup counters the CI benchmark gate
    tracks."""

    def __init__(self, path: str | os.PathLike | None = None, *,
                 backend: StoreBackend | None = None) -> None:
        if backend is not None and path is not None:
            raise ValueError("pass either path= or backend=, not both")
        if backend is None:
            backend = (LocalDirBackend(path) if path is not None
                       else MemoryBackend())
        self.backend = backend
        self.path = getattr(backend, "path", None)
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------ #
    def get(self, key: str) -> dict | None:
        """The stored record for ``key`` (or None), counting hit/miss."""
        rec = self._peek(key)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def _peek(self, key: str) -> dict | None:
        rec = self.backend.get(key)
        if rec is None:
            return None
        version = rec.get("record_version")
        if version != RECORD_VERSION:
            raise ValueError(
                f"run record {key} has record_version {version!r}, not the "
                f"supported {RECORD_VERSION}; re-record the run with this "
                f"code version")
        return rec

    def peek(self, key: str) -> dict | None:
        """Like :meth:`get` but without touching the hit/miss counters —
        for polling loops (multi-host sweeps waiting on another owner's
        claim) that would otherwise skew the dedup statistics."""
        return self._peek(key)

    def __contains__(self, key: str) -> bool:
        return self._peek(key) is not None

    @staticmethod
    def _record(key: str, scenario: Scenario, backend: str, opts: dict,
                result: RunResult) -> dict:
        scn_dict = scenario.to_dict()
        return {
            "record_version": RECORD_VERSION,
            "key": key,
            "scenario": scn_dict,
            "scenario_fingerprint": _dict_fingerprint(scn_dict),
            "backend": backend,
            "opts": jsonify(opts),
            "result": result.to_dict(),
        }

    def put(self, key: str, scenario: Scenario, backend: str, opts: dict,
            result: RunResult) -> bool:
        """Commit one completed run.  The record is fully JSON-canonical
        (the result goes through its ``to_dict`` round-trip), and the write
        is atomic — a crash mid-``put`` leaves either the previous state or
        the complete record, never a torn file.

        If the key is already committed, the stored record's content
        fingerprint (wall-clock fields masked) is verified against the new
        one: a match is a *dedup hit* (nothing rewritten, returns True); a
        mismatch warns — a silent overwrite can hide a nondeterministic
        engine — and the new record wins.  Returns whether the write was a
        dedup hit."""
        existing = self.backend.get(key)
        if existing is not None:
            record = self._record(key, scenario, backend, opts, result)
            if stable_record_fingerprint(existing) == \
                    stable_record_fingerprint(record):
                return True
            warnings.warn(
                f"run record {key} already exists with different content "
                f"(beyond wall-clock fields) — the engine {backend!r} may "
                f"be nondeterministic, or two different code versions "
                f"wrote this store; overwriting with the newer record",
                RuntimeWarning, stacklevel=2)
            self.backend.put(key, json.loads(json.dumps(record)))
            return False
        put_lazy = getattr(self.backend, "put_lazy", None)
        if put_lazy is not None:
            put_lazy(key, scenario, backend, opts, result)
        else:
            self.backend.put(key, self._record(key, scenario, backend, opts,
                                               result))
        return False

    def delete(self, key: str) -> bool:
        return self.backend.delete(key)

    # ------------------------------------------------------------------ #
    def keys(self) -> list[str]:
        return [k for k in self.backend.keys()
                if not k.startswith(CLAIM_PREFIX)]

    def records(self) -> Iterator[dict]:
        for key in self.keys():
            rec = self._peek(key)
            if rec is not None:
                yield rec

    def corrupt_keys(self) -> list[str]:
        """Keys whose record files exist but do not parse — a full sweep,
        so the answer is current even before any :meth:`records` pass."""
        for key in self.keys():
            self._peek(key)
        tracked = getattr(self.backend, "corrupt_keys", None)
        return sorted(tracked()) if tracked is not None else []

    def __len__(self) -> int:
        return len(self.keys())

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())

    def close(self) -> None:
        self.backend.close()

    # ------------------------------------------------------------------ #
    # claims: advisory work-stealing markers over the same backend
    # ------------------------------------------------------------------ #
    def claim(self, key: str, owner: str,
              ttl: float = DEFAULT_CLAIM_TTL) -> bool:
        """Try to claim ``key`` for ``owner``: True when this caller now
        holds the claim.  A claim is a plain record under a reserved key
        prefix, created with the backend's atomic ``put_new`` — so two
        sweeping hosts race safely and exactly one wins.  Claims expire
        after ``ttl`` seconds (a crashed worker's claims are stolen, which
        is what makes multi-host sweeps crash-safe); they are *advisory*:
        losing a rare steal race double-runs a scenario, and the
        content-addressed store dedups the second commit."""
        ck = CLAIM_PREFIX + key
        rec = {"owner": owner, "t": time.time(), "ttl": ttl}
        if self.backend.put_new(ck, rec):
            return True
        cur = self.backend.get(ck)
        if cur is None:                       # released between our calls
            return self.backend.put_new(ck, rec)
        if cur.get("owner") == owner:
            return True
        if time.time() - float(cur.get("t", 0.0)) > float(cur.get("ttl",
                                                          DEFAULT_CLAIM_TTL)):
            # stale claim from a dead worker: steal it (the marker lets a
            # served store count steals in its /metrics)
            self.backend.delete(ck)
            return self.backend.put_new(ck, {**rec, "stolen": True})
        return False

    def claim_owner(self, key: str) -> str | None:
        """Current live claim holder for ``key`` (None when unclaimed or
        expired)."""
        cur = self.backend.get(CLAIM_PREFIX + key)
        if cur is None:
            return None
        if time.time() - float(cur.get("t", 0.0)) > float(cur.get("ttl",
                                                          DEFAULT_CLAIM_TTL)):
            return None
        return cur.get("owner")

    def release(self, key: str, owner: str) -> None:
        """Drop ``owner``'s claim on ``key`` (someone else's is left
        alone)."""
        ck = CLAIM_PREFIX + key
        cur = self.backend.get(ck)
        if cur is not None and cur.get("owner") == owner:
            self.backend.delete(ck)

    # ------------------------------------------------------------------ #
    # TTL / GC
    # ------------------------------------------------------------------ #
    def gc(self, ttl: float | None = None) -> list[str]:
        """Compact the store: drop run records older than ``ttl`` seconds
        (None keeps them all) and every expired claim.  Returns the removed
        run keys.  Age comes from the backend's write clock (file mtime on
        disk), so re-committing a key refreshes its lease.  Against a
        remote backend the sweep runs server-side (ages live with the
        files)."""
        server_gc = getattr(self.backend, "server_gc", None)
        if server_gc is not None:
            return server_gc(ttl)
        removed: list[str] = []
        now = time.time()
        for key in self.backend.keys():
            if key.startswith(CLAIM_PREFIX):
                cur = self.backend.get(key)
                if cur is None or now - float(cur.get("t", 0.0)) > \
                        float(cur.get("ttl", DEFAULT_CLAIM_TTL)):
                    self.backend.delete(key)
                continue
            if ttl is None:
                continue
            age = self.backend.age(key)
            if age is not None and age > ttl:
                if self.backend.delete(key):
                    removed.append(key)
        return removed
