"""Content-addressed store of completed runs — the durable half of a
:class:`~repro.api.campaign.Campaign`.

Every completed ``(scenario, backend, opts)`` evaluation is committed under
its :func:`run_key` — a stable hash of the scenario's canonical JSON form,
the backend name and the JSON-canonicalized engine opts.  Submitting the
same triple again finds the stored record instead of simulating, which is
what makes a half-finished sweep resumable: the store is the ground truth
of what already ran.

Two backings share one interface: a directory (one JSON file per run,
written atomically via rename, so a killed sweep never leaves a torn
record) or an in-memory dict (the anonymous campaigns behind
``repro.api.run``/``run_many``).  Either way, results pass through the
``RunResult.to_dict``/``from_dict`` JSON round-trip on ``put``, so a cached
result is byte-for-byte what a re-opened campaign would read from disk.
"""
from __future__ import annotations

import itertools
import json
import os
import pathlib
import warnings
from hashlib import sha256
from collections.abc import Iterator

from repro.api.results import RunResult, jsonify
from repro.api.scenario import Scenario

RECORD_VERSION = 1


class _Raw(tuple):
    """In-memory put defers record canonicalization to first read."""
    __slots__ = ()

    def __new__(cls, scenario, backend, opts, result):
        return super().__new__(cls, (scenario, backend, opts, result))


def _dict_fingerprint(d: dict) -> str:
    return sha256(json.dumps(d, sort_keys=True,
                             separators=(",", ":")).encode()).hexdigest()


def scenario_fingerprint(scenario: Scenario) -> str:
    """Stable content hash of a scenario's canonical JSON form."""
    return _dict_fingerprint(scenario.to_dict())


# every submit carrying an opt with no canonical JSON form is its own
# experiment — see _key_form
_UNCACHEABLE = itertools.count(1)


def _key_form(x):
    """Canonical key form of an opt value: :func:`jsonify`, except objects
    with no canonical JSON form (live SimDB handles, open files) become a
    process-unique token instead of ``repr`` — a repr can truncate (large
    ndarrays) or embed a reusable memory address, either of which could
    collide two distinct experiments onto one store key.  Such opts are
    uncacheable: every submit keys uniquely."""
    return jsonify(x, fallback=lambda v:
                   f"<uncacheable {type(v).__name__} #{next(_UNCACHEABLE)}>")


def run_key(scenario: Scenario, backend: str, opts: dict) -> str:
    """The store's content address: ``(scenario fingerprint, backend,
    canonicalized opts)`` hashed into one stable hex key.  Opts with no
    canonical JSON form never dedup (each submit is its own experiment)."""
    blob = json.dumps({
        "scenario_fingerprint": scenario_fingerprint(scenario),
        "backend": backend,
        "opts": _key_form(opts),
    }, sort_keys=True, separators=(",", ":"))
    return sha256(blob.encode()).hexdigest()[:40]


class RunStore:
    """Keyed store of completed runs.  ``path=None`` keeps records in
    memory; a path makes each record a ``<key>.json`` file committed with
    an atomic rename.  ``hits``/``misses`` count :meth:`get` outcomes —
    the dedup counters the CI benchmark gate tracks."""

    def __init__(self, path: str | os.PathLike | None = None) -> None:
        self.path = pathlib.Path(path) if path is not None else None
        if self.path is not None:
            self.path.mkdir(parents=True, exist_ok=True)
        self._mem: dict[str, dict] = {}
        self._corrupt: set[str] = set()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------ #
    def _file(self, key: str) -> pathlib.Path:
        return self.path / f"{key}.json"

    def get(self, key: str) -> dict | None:
        """The stored record for ``key`` (or None), counting hit/miss."""
        rec = self._peek(key)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def _peek(self, key: str) -> dict | None:
        if self.path is None:
            ent = self._mem.get(key)
            if isinstance(ent, _Raw):
                # first read materializes the canonical record — the same
                # JSON form the disk backing would hand back.  Anonymous
                # campaigns behind run()/run_many() never read their own
                # store, so they never pay this.
                ent = json.loads(json.dumps(self._record(key, *ent)))
                self._mem[key] = ent
            return ent
        try:
            with open(self._file(key)) as fh:
                rec = json.load(fh)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, UnicodeDecodeError):
            # a truncated or garbled record file (torn copy, disk fault —
            # our own writes are atomic).  Treat it as absent so one bad
            # record can't poison dataset extraction or a resumed sweep;
            # resubmitting the triple overwrites it with a good record.
            if key not in self._corrupt:
                self._corrupt.add(key)
                warnings.warn(
                    f"skipping corrupt run record {self._file(key)} "
                    f"(unparsable JSON); see RunStore.corrupt_keys()",
                    RuntimeWarning, stacklevel=3)
            return None
        self._corrupt.discard(key)
        version = rec.get("record_version")
        if version != RECORD_VERSION:
            raise ValueError(
                f"{self._file(key)} has record_version {version!r}, not the "
                f"supported {RECORD_VERSION}; re-record the run with this "
                f"code version")
        return rec

    def __contains__(self, key: str) -> bool:
        return self._peek(key) is not None

    @staticmethod
    def _record(key: str, scenario: Scenario, backend: str, opts: dict,
                result: RunResult) -> dict:
        scn_dict = scenario.to_dict()
        return {
            "record_version": RECORD_VERSION,
            "key": key,
            "scenario": scn_dict,
            "scenario_fingerprint": _dict_fingerprint(scn_dict),
            "backend": backend,
            "opts": jsonify(opts),
            "result": result.to_dict(),
        }

    def put(self, key: str, scenario: Scenario, backend: str, opts: dict,
            result: RunResult) -> None:
        """Commit one completed run.  The record is fully JSON-canonical
        (the result goes through its ``to_dict`` round-trip), and the disk
        write is atomic — a crash mid-``put`` leaves either the previous
        state or the complete record, never a torn file."""
        if self.path is None:
            self._mem[key] = _Raw(scenario, backend, opts, result)
        else:
            tmp = self.path / f".{key}.tmp.{os.getpid()}"
            with open(tmp, "w") as fh:
                json.dump(self._record(key, scenario, backend, opts, result),
                          fh)
            os.replace(tmp, self._file(key))

    def delete(self, key: str) -> bool:
        if self.path is None:
            return self._mem.pop(key, None) is not None
        try:
            os.remove(self._file(key))
            return True
        except FileNotFoundError:
            return False

    # ------------------------------------------------------------------ #
    def keys(self) -> list[str]:
        if self.path is None:
            return sorted(self._mem)
        return sorted(p.stem for p in self.path.glob("*.json")
                      if not p.name.startswith("."))

    def records(self) -> Iterator[dict]:
        for key in self.keys():
            rec = self._peek(key)
            if rec is not None:
                yield rec

    def corrupt_keys(self) -> list[str]:
        """Keys whose record files exist but do not parse — a full sweep,
        so the answer is current even before any :meth:`records` pass."""
        for key in self.keys():
            self._peek(key)
        return sorted(self._corrupt)

    def __len__(self) -> int:
        return len(self.keys())

    def __iter__(self) -> Iterator[str]:
        return iter(self.keys())
