"""Top-level entry points: run one scenario, sweep many, compare backends.

Two orthogonal parallelism axes (paper §6.1):

* **across scenarios** — ``run_many(..., workers=N)`` dispatches the sweep
  over a process pool; with ``shared_db=True`` one SimDB threads through
  the runs (transients memoized in run 1 fast-forward runs 2..N) and
  ``db_path=`` makes that cache durable across sessions.  Each worker runs
  against a snapshot of the shared DB and ships back the delta of newly
  memoized transients, which the parent merges (deduplicating repeats),
  so even a cold parallel sweep converges to one warm DB.  For the fluid
  backend a serial sweep pads + vmaps into one compiled evaluation
  instead.
* **inside one run** — ``run(..., parallel="partitions",
  intra_workers=M)`` executes the packet/wormhole backends on the
  partition-sharded event loop (``repro.net.sharded_sim``): per-partition
  event lanes advance independently between global barriers and heavy
  UNSTEADY lanes fan out to a worker pool, with results identical to the
  serial loop.  Both axes compose: ``run_many(..., workers=N,
  parallel="partitions", intra_workers=M)``.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
from concurrent.futures import ProcessPoolExecutor

from repro.api.engines import get_engine
from repro.api.results import RunResult, summarize_pair
from repro.api.scenario import Scenario
from repro.core.memo import FORMAT_VERSION, SimDB


def run(scenario: Scenario, backend: str = "packet", **opts) -> RunResult:
    """Evaluate one scenario on one backend."""
    return get_engine(backend).run(scenario, **opts)


def _worker_run(scn_dict: dict, backend: str, db_dict: dict | None,
                opts: dict):
    """Module-level so ProcessPoolExecutor can pickle it.  Returns the
    RunResult plus (for DB-carrying sweeps) the delta of MemoEntries this
    run inserted and the regime fingerprint the kernel bound."""
    scenario = Scenario.from_dict(scn_dict)
    engine = get_engine(backend)
    if db_dict is None:
        return engine.run(scenario, **opts), None, None
    db = SimDB.from_dict(db_dict)
    mark = db.mark()
    result = engine.run(scenario, db=db, **opts)
    delta = [e.to_dict() for e in db.entries_since(mark)]
    return result, delta, db.fingerprint


def run_many(scenarios: list[Scenario], backend: str = "packet",
             shared_db: bool = False, db: SimDB | None = None,
             db_path: str | None = None, save_db: bool = True,
             workers: int = 1, **opts) -> list[RunResult]:
    """Evaluate a sweep.

    ``shared_db=True`` (wormhole only) threads one memo DB through the runs
    in order; pass ``db=`` to bring your own (e.g. persisted knowledge from
    an earlier sweep).  ``db_path=`` loads the DB from disk if the file
    exists and saves the (possibly grown) DB back when the sweep is done —
    the cross-session warm start (``save_db=False`` loads without writing
    back).  ``workers=N`` fans the scenarios out
    over N processes; results keep scenario order, and each scenario is
    evaluated exactly as a standalone ``run()`` — identical to the serial
    path for per-scenario engines (packet/wormhole/analytic are
    deterministic), while batching engines (fluid's padded vmap, which
    also shares one ``dt`` across the batch) use their per-scenario path
    instead.  With a DB, every worker starts from the same initial
    snapshot (no mid-sweep warm-up, unlike the serial path) and the parent
    merges every worker's insert delta back, deduplicating transients
    memoized by more than one worker — a cold parallel sweep still
    converges to one warm DB."""
    engine = get_engine(backend)
    wants_db = shared_db or db is not None or db_path is not None
    if wants_db and backend != "wormhole":
        raise ValueError(
            f"shared_db/db/db_path are wormhole features, not {backend!r}")
    if db is not None and db_path is not None:
        # saving would clobber the file with only the in-memory DB's
        # entries; load-or-merge intent must be explicit
        raise ValueError("pass either db= or db_path=, not both "
                         "(merge/save an in-memory SimDB yourself)")
    if wants_db and db is None:
        db = SimDB.load_or_new(db_path)

    if workers > 1:
        db_dict = db.to_dict() if wants_db else None
        results = []
        # spawn, not fork: the parent may have live jax/XLA threads (e.g. a
        # fluid sweep earlier in the session) and forking those deadlocks;
        # workers import only the packet-path modules, so spawning is cheap
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as pool:
            futures = [pool.submit(_worker_run, s.to_dict(), backend,
                                   db_dict, dict(opts)) for s in scenarios]
            for fut in futures:
                result, delta, fingerprint = fut.result()
                results.append(result)
                if wants_db and delta is not None:
                    db.merge(SimDB.from_dict({
                        "format_version": FORMAT_VERSION,
                        "fingerprint": fingerprint, "entries": delta}))
    elif wants_db:
        results = [engine.run(s, db=db, **opts) for s in scenarios]
    else:
        results = engine.run_batch(scenarios, **opts)

    if wants_db and db_path is not None and save_db:
        db.save(db_path)
    return results


@dataclasses.dataclass
class Comparison:
    """Per-backend speedup/accuracy table against a baseline backend."""
    scenario: str
    baseline: str
    results: dict[str, RunResult]

    def __getitem__(self, backend: str) -> RunResult:
        return self.results[backend]

    def rows(self) -> list[dict]:
        base = self.results[self.baseline]
        return [summarize_pair(base, r) for b, r in self.results.items()
                if b != self.baseline]

    def format(self) -> str:
        base = self.results[self.baseline]
        hdr = (f"{'backend':<10} {'events':>10} {'wall s':>8} {'ev x':>7} "
               f"{'wall x':>7} {'fct err%':>9} {'max err%':>9} {'iter ms':>9}")
        lines = [f"scenario {self.scenario!r}  (baseline: {self.baseline})", hdr,
                 "-" * len(hdr)]
        for b, r in self.results.items():
            s = summarize_pair(base, r)
            it = f"{r.iteration_time * 1e3:9.3f}" if r.iteration_time else " " * 9
            if b == self.baseline:
                lines.append(f"{b:<10} {r.events_processed:>10d} "
                             f"{r.wall_time:8.2f} {'1.0':>7} {'1.0':>7} "
                             f"{'-':>9} {'-':>9} {it}")
            else:
                lines.append(
                    f"{b:<10} {r.events_processed:>10d} {r.wall_time:8.2f} "
                    f"{s['event_speedup']:7.1f} {s['wall_speedup']:7.1f} "
                    f"{100 * s['fct_err_mean']:9.3f} "
                    f"{100 * s['fct_err_max']:9.3f} {it}")
        return "\n".join(lines)

    __str__ = format


def compare(scenario: Scenario, backends=("packet", "wormhole"),
            baseline: str | None = None, **opts) -> Comparison:
    """Run ``scenario`` on every backend and tabulate speedups + FCT errors
    against ``baseline`` (default: the first backend)."""
    backends = tuple(backends)
    baseline = baseline if baseline is not None else backends[0]
    if baseline not in backends:
        raise ValueError(f"baseline {baseline!r} not in backends {backends}")
    results = {b: run(scenario, backend=b, **opts) for b in backends}
    return Comparison(scenario=scenario.name, baseline=baseline, results=results)
