"""Top-level entry points: run one scenario, sweep many, compare backends.

``run_many(..., backend="wormhole", shared_db=True)`` is the paper's §6.1
multi-experiment parallelism as a single call: one SimDB threads through
the whole sweep, so transients memoized in run 1 fast-forward runs 2..N
(cross-run warm cache).  For the fluid backend the sweep pads + vmaps into
one compiled evaluation instead.
"""
from __future__ import annotations

import dataclasses

from repro.api.engines import get_engine
from repro.api.results import RunResult, summarize_pair
from repro.api.scenario import Scenario
from repro.core.memo import SimDB


def run(scenario: Scenario, backend: str = "packet", **opts) -> RunResult:
    """Evaluate one scenario on one backend."""
    return get_engine(backend).run(scenario, **opts)


def run_many(scenarios: list[Scenario], backend: str = "packet",
             shared_db: bool = False, db: SimDB | None = None,
             **opts) -> list[RunResult]:
    """Evaluate a sweep.  ``shared_db=True`` (wormhole only) threads one
    memo DB through the runs in order; pass ``db=`` to bring your own
    (e.g. persisted knowledge from an earlier sweep)."""
    engine = get_engine(backend)
    if shared_db or db is not None:
        if backend != "wormhole":
            raise ValueError(f"shared_db is a wormhole feature, not {backend!r}")
        db = db if db is not None else SimDB()
        return [engine.run(s, db=db, **opts) for s in scenarios]
    return engine.run_batch(scenarios, **opts)


@dataclasses.dataclass
class Comparison:
    """Per-backend speedup/accuracy table against a baseline backend."""
    scenario: str
    baseline: str
    results: dict[str, RunResult]

    def __getitem__(self, backend: str) -> RunResult:
        return self.results[backend]

    def rows(self) -> list[dict]:
        base = self.results[self.baseline]
        return [summarize_pair(base, r) for b, r in self.results.items()
                if b != self.baseline]

    def format(self) -> str:
        base = self.results[self.baseline]
        hdr = (f"{'backend':<10} {'events':>10} {'wall s':>8} {'ev x':>7} "
               f"{'wall x':>7} {'fct err%':>9} {'max err%':>9} {'iter ms':>9}")
        lines = [f"scenario {self.scenario!r}  (baseline: {self.baseline})", hdr,
                 "-" * len(hdr)]
        for b, r in self.results.items():
            s = summarize_pair(base, r)
            it = f"{r.iteration_time * 1e3:9.3f}" if r.iteration_time else " " * 9
            if b == self.baseline:
                lines.append(f"{b:<10} {r.events_processed:>10d} "
                             f"{r.wall_time:8.2f} {'1.0':>7} {'1.0':>7} "
                             f"{'-':>9} {'-':>9} {it}")
            else:
                lines.append(
                    f"{b:<10} {r.events_processed:>10d} {r.wall_time:8.2f} "
                    f"{s['event_speedup']:7.1f} {s['wall_speedup']:7.1f} "
                    f"{100 * s['fct_err_mean']:9.3f} "
                    f"{100 * s['fct_err_max']:9.3f} {it}")
        return "\n".join(lines)

    __str__ = format


def compare(scenario: Scenario, backends=("packet", "wormhole"),
            baseline: str | None = None, **opts) -> Comparison:
    """Run ``scenario`` on every backend and tabulate speedups + FCT errors
    against ``baseline`` (default: the first backend)."""
    backends = tuple(backends)
    baseline = baseline if baseline is not None else backends[0]
    if baseline not in backends:
        raise ValueError(f"baseline {baseline!r} not in backends {backends}")
    results = {b: run(scenario, backend=b, **opts) for b in backends}
    return Comparison(scenario=scenario.name, baseline=baseline, results=results)
