"""Top-level entry points: run one scenario, sweep many, compare backends.

Status: these are thin wrappers over an anonymous in-memory
:class:`~repro.api.campaign.Campaign` — ``run``/``run_many``/``compare``
each open a process-lifetime campaign (nothing written to disk), so they
inherit campaign semantics (identical ``(scenario, backend, opts)``
triples within one call dedup to a single simulation) while keeping the
historical flat-function signatures.  For durable, resumable sessions use
``Campaign.open(path)`` directly.

Two orthogonal parallelism axes (paper §6.1):

* **across scenarios** — ``run_many(..., workers=N)`` dispatches the sweep
  over a process pool; with ``shared_db=True`` one SimDB threads through
  the runs (transients memoized in run 1 fast-forward runs 2..N), and a
  durable ``Campaign.open(dir)`` makes that cache survive across
  sessions.  Each worker runs
  against a snapshot of the shared DB and ships back the delta of newly
  memoized transients, which the parent merges (deduplicating repeats),
  so even a cold parallel sweep converges to one warm DB.  For the fluid
  backend a serial sweep pads + vmaps into one compiled evaluation
  instead.
* **inside one run** — ``run(..., parallel="partitions",
  intra_workers=M)`` executes the packet/wormhole backends on the
  partition-sharded event loop (``repro.net.sharded_sim``): per-partition
  event lanes advance independently between global barriers and heavy
  UNSTEADY lanes fan out to a worker pool, with results identical to the
  serial loop.  Both axes compose: ``run_many(..., workers=N,
  parallel="partitions", intra_workers=M)``.
"""
from __future__ import annotations

from repro.api.campaign import Campaign
from repro.api.engines import get_engine
from repro.api.results import Comparison, RunResult
from repro.api.scenario import Scenario
from repro.core.memo import SimDB

__all__ = ["Comparison", "compare", "run", "run_many"]


def run(scenario: Scenario, backend: str = "packet", **opts) -> RunResult:
    """Evaluate one scenario on one backend (an anonymous single-run
    campaign underneath)."""
    return Campaign.in_memory().submit(scenario, backend=backend,
                                       **opts).result


def run_many(scenarios: list[Scenario], backend: str = "packet",
             shared_db: bool = False, db: SimDB | None = None,
             workers: int = 1, **opts) -> list[RunResult]:
    """Evaluate a sweep (an anonymous campaign sweep underneath; identical
    scenarios in one call are simulated once).

    ``shared_db=True`` (wormhole only) threads one memo DB through the runs
    in order; pass ``db=`` to bring your own (e.g. persisted knowledge from
    an earlier sweep — an explicit ``SimDB.load_or_new``/``save`` pair, or
    better, a durable ``Campaign.open(dir)``, which owns and persists its
    SimDB with no plumbing at all).  ``workers=N``
    fans the scenarios out over N processes; results keep scenario order,
    and each scenario is evaluated exactly as a standalone ``run()`` —
    identical to the serial path for per-scenario engines
    (packet/wormhole/analytic are deterministic), while batching engines
    (fluid's padded vmap, which also shares one ``dt`` across the batch)
    use their per-scenario path instead.  With a DB, every worker starts
    from the same initial snapshot (no mid-sweep warm-up, unlike the
    serial path) and the parent merges every worker's insert delta back,
    deduplicating transients memoized by more than one worker — a cold
    parallel sweep still converges to one warm DB."""
    engine = get_engine(backend)           # unknown backends fail up front
    engine.check_opts(opts)
    wants_db = shared_db or db is not None
    if wants_db and backend != "wormhole":
        raise ValueError(
            f"shared_db/db are wormhole features, not {backend!r}")
    if wants_db and db is None:
        db = SimDB()
    camp = Campaign.in_memory(db=db if wants_db else None)
    return camp.sweep(scenarios, backend=backend, workers=workers, **opts)


def compare(scenario: Scenario, backends=("packet", "wormhole"),
            baseline: str | None = None,
            backend_opts: dict | None = None, **opts) -> Comparison:
    """Run ``scenario`` on every backend and tabulate speedups + FCT errors
    against ``baseline`` (default: the first backend).  ``**opts`` go to
    every backend; ``backend_opts={"hybrid": {"fidelity": "flow"}}`` sends
    opts to one backend only (the CLI's ``--opt backend:key=value``)."""
    return Campaign.in_memory().compare(scenario, backends=backends,
                                        baseline=baseline,
                                        backend_opts=backend_opts, **opts)
