"""Durable, resumable, observable experiment sessions (paper §5/§6.1).

The paper's headline numbers come from *sweeps*: warm SimDB runs collapse
~500k-event baselines to a handful of events precisely because memoized
state outlives a single ``run()`` call.  A :class:`Campaign` makes that
state — and the results themselves — a named on-disk session instead of
whatever happened to be alive in one process:

    from repro.api import Campaign, training_scenario

    camp = Campaign.open("experiments/cca-sweep")
    handle = camp.submit(training_scenario(n_gpus=64), backend="wormhole")
    camp.sweep(variants, backend="wormhole", workers=2)
    camp.close()

    # next session (or after a crash mid-sweep): completed runs are
    # skipped, the campaign's SimDB starts warm, only the remainder runs
    camp = Campaign.open("experiments/cca-sweep")
    camp.sweep(variants, backend="wormhole", workers=2)

A campaign owns two durable artifacts under its directory:

* a :class:`~repro.api.store.RunStore` (``runs/``) — every completed
  ``(scenario, backend, opts)`` evaluation committed atomically the moment
  it finishes, keyed by content (:func:`~repro.api.store.run_key`).
  Submitting a triple that is already stored returns the cached
  :class:`RunResult` without invoking any engine.
* the campaign ``simdb.json`` — the wormhole memo DB, loaded on open and
  saved after every commit, so cross-run fast-forwarding survives crashes
  and sessions without any ``db_path=`` plumbing.

Progress is observable: ``subscribe(callback)`` streams a
:class:`RunEvent` per run — ``started`` / ``finished`` / ``cache_hit`` —
which the CLI (``python -m repro``) and the benchmarks consume.

``repro.api.run`` / ``run_many`` / ``compare`` are thin wrappers over an
anonymous in-memory campaign (``Campaign.in_memory()``), so the flat
function API keeps working unchanged on top of this layer.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import multiprocessing
import os
import pathlib
import socket
import time
import weakref
from concurrent.futures import ProcessPoolExecutor, as_completed
from collections.abc import Callable, Iterable, Iterator
from typing import Any

from repro.api.engines import Engine, get_engine
from repro.api.results import Comparison, RunResult
from repro.api.scenario import Scenario
from repro.api.store import DEFAULT_CLAIM_TTL, RunStore, run_key
from repro.core.memo import FORMAT_VERSION, SimDB
from repro.net.sharded_sim import shutdown_pools

MANIFEST = "campaign.json"
MANIFEST_VERSION = 1


@dataclasses.dataclass
class RunEvent:
    """One progress event on a campaign's observer stream.

    ``kind`` is ``"started"`` (an engine run begins), ``"finished"`` (it
    completed and was committed to the store) or ``"cache_hit"`` (the store
    already held the result — nothing was simulated).  ``index`` is the
    position in the submitted sweep, when the event belongs to one.
    """
    kind: str
    key: str
    scenario: str
    backend: str
    index: int | None = None
    result: RunResult | None = None


@dataclasses.dataclass
class RunHandle:
    """What :meth:`Campaign.submit` returns: the run's store key, whether
    it was served from the store, and the result itself."""
    key: str
    scenario: str
    backend: str
    cached: bool
    result: RunResult


def _worker_run(scn_dict: dict, backend: str, db_dict: dict | None,
                opts: dict):
    """Module-level so ProcessPoolExecutor can pickle it.  Returns the
    RunResult plus (for DB-carrying sweeps) the delta of MemoEntries this
    run inserted and the regime fingerprint the kernel bound."""
    scenario = Scenario.from_dict(scn_dict)
    engine = get_engine(backend)
    if db_dict is None:
        return engine.run(scenario, **opts), None, None
    db = SimDB.from_dict(db_dict)
    mark = db.mark()
    result = engine.run(scenario, db=db, **opts)
    delta = [e.to_dict() for e in db.entries_since(mark)]
    return result, delta, db.fingerprint


# ---------------------------------------------------------------------- #
# open campaigns are flushed (and the shared lane-worker pools torn down)
# at interpreter exit, so a CLI invocation or a crashed-by-exception
# session never leaves spawn workers behind or an unsaved SimDB
# ---------------------------------------------------------------------- #
_LIVE: weakref.WeakSet[Campaign] = weakref.WeakSet()
_ATEXIT_REGISTERED = False


def _register_atexit() -> None:
    global _ATEXIT_REGISTERED
    if not _ATEXIT_REGISTERED:
        atexit.register(_close_all)
        _ATEXIT_REGISTERED = True


def _close_all() -> None:
    for camp in list(_LIVE):
        camp.close()
    shutdown_pools()


class Campaign:
    """A named, durable experiment session over the engine registry."""

    def __init__(self, path: str | os.PathLike | None = None,
                 name: str | None = None, db: SimDB | None = None,
                 store: str | None = None) -> None:
        if isinstance(path, str) and path.startswith(("http://", "https://")):
            # Campaign.open("http://host:port") — a pure-remote campaign
            if store is not None and store.rstrip("/") != path.rstrip("/"):
                raise ValueError(
                    f"path {path!r} is a store URL but store={store!r} "
                    f"names a different server")
            store, path = path, None
        self.path = pathlib.Path(path) if path is not None else None
        self._observers: list[Callable[[RunEvent], Any]] = []
        self._closed = False
        self._remote = None                 # RemoteBackend once attached
        self._db_outbox: list[dict] = []    # memo entries awaiting a push
        self._owner = (f"{socket.gethostname()}:{os.getpid()}:"
                       f"{os.urandom(3).hex()}")
        if self.path is not None:
            if db is not None:
                raise ValueError(
                    "a durable campaign owns its SimDB (simdb.json under "
                    "the campaign directory); merge an external DB with "
                    "campaign.db.merge(...) instead of passing db=")
            self.path.mkdir(parents=True, exist_ok=True)
            manifest = self.path / MANIFEST
            if manifest.exists():
                m = json.loads(manifest.read_text())
                if m.get("manifest_version") != MANIFEST_VERSION:
                    raise ValueError(
                        f"{manifest} has manifest_version "
                        f"{m.get('manifest_version')!r}, not the supported "
                        f"{MANIFEST_VERSION}")
                self.name = name or m.get("name") or self.path.name
            else:
                self.name = name or self.path.name
                manifest.write_text(json.dumps(
                    {"manifest_version": MANIFEST_VERSION,
                     "name": self.name}, indent=1))
            self.store = RunStore(self.path / "runs")
            self._db = SimDB.load_or_new(str(self.path / "simdb.json"))
            _LIVE.add(self)
        else:
            self.name = name or ("remote" if store is not None
                                 else "anonymous")
            self.store = RunStore(None)
            self._db = db
        if store is not None:
            self._attach_store(store)
        _register_atexit()

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @classmethod
    def open(cls, path: str | os.PathLike, name: str | None = None,
             store: str | None = None) -> "Campaign":
        """Open (or create) the durable campaign at ``path``.  Re-opening
        resumes: completed runs are served from the store, the SimDB
        starts warm.

        ``path`` may be a store-server URL (``http://host:port``) for a
        pure-remote campaign, or ``store=`` can attach a local directory
        campaign to a shared server: reads check the server first and fall
        back to the local store, commits go to the server (degrading to
        local-only while it is unreachable), and the server's memo DB is
        pulled/merged so wormhole replays start warm on every host."""
        return cls(path=path, name=name, store=store)

    @classmethod
    def in_memory(cls, db: SimDB | None = None,
                  name: str | None = None) -> "Campaign":
        """An anonymous, process-lifetime campaign: same dedup/observer
        semantics, nothing written to disk.  ``db=`` optionally threads a
        caller-managed SimDB through wormhole runs (this is how
        ``run_many(shared_db=True)`` rides on campaigns)."""
        return cls(path=None, db=db, name=name)

    @property
    def db(self) -> SimDB | None:
        """The campaign's memo DB (always present on durable campaigns)."""
        return self._db

    @property
    def remote(self):
        """The attached :class:`~repro.api.serve.RemoteBackend` (None for
        purely local campaigns)."""
        return self._remote

    # ------------------------------------------------------------------ #
    # shared store service
    # ------------------------------------------------------------------ #
    def _attach_store(self, store) -> None:
        """Route the campaign's store through a ``python -m repro serve``
        endpoint.  The current backend becomes the remote's local fallback
        (so prior local history stays visible and outage-time commits have
        somewhere durable to land), the local memo DB is pushed up, and the
        server's is pulled down — warm state compounds both ways."""
        from repro.api.serve import RemoteBackend
        if isinstance(store, RemoteBackend):
            remote = store
        elif isinstance(store, str):
            if self._remote is not None:
                if self._remote.url == store.rstrip("/"):
                    return
                raise ValueError(
                    f"campaign is already attached to {self._remote.url}; "
                    f"cannot switch to {store!r}")
            remote = RemoteBackend(store, fallback=self.store.backend)
        else:
            raise TypeError(
                f"store= must be a server URL or RemoteBackend, "
                f"not {type(store).__name__}")
        hits, misses = self.store.hits, self.store.misses
        self.store = RunStore(backend=remote)
        self.store.hits, self.store.misses = hits, misses
        self._remote = remote
        if self._db is None:
            self._db = SimDB()
        if len(self._db):
            # share everything this host already learned; the server-side
            # merge dedups, so a re-push is idempotent
            self._db_outbox.extend(self._db.to_dict()["entries"])
        pulled = remote.simdb_pull()
        if pulled is not None and len(pulled):
            self._db.merge(pulled)
        self._flush_db_outbox()
        _LIVE.add(self)

    def _flush_db_outbox(self) -> None:
        if self._remote is None or not self._db_outbox:
            return
        fingerprint = self._db.fingerprint if self._db is not None else None
        if self._remote.simdb_push(self._db_outbox, fingerprint):
            self._db_outbox.clear()

    def gc(self, ttl: float | None = None) -> list[str]:
        """Expire run records older than ``ttl`` seconds plus stale claims
        (server-side when attached); returns the removed run keys."""
        return self.store.gc(ttl)

    # ------------------------------------------------------------------ #
    # observers
    # ------------------------------------------------------------------ #
    def subscribe(self, callback: Callable[[RunEvent], Any]):
        """Register a progress observer; returns ``callback`` for later
        :meth:`unsubscribe`."""
        self._observers.append(callback)
        return callback

    def unsubscribe(self, callback) -> None:
        self._observers.remove(callback)

    def _emit(self, event: RunEvent) -> None:
        for cb in list(self._observers):
            cb(event)

    # ------------------------------------------------------------------ #
    # submitting work
    # ------------------------------------------------------------------ #
    def _check_opts(self, opts: dict) -> None:
        if (self.path is not None or self._remote is not None) and \
                "db" in opts:
            raise ValueError(
                "a durable or served campaign owns its SimDB — drop db= "
                "(use repro.api.run/run_many for caller-managed DBs)")

    def _db_for(self, engine: Engine, opts: dict) -> SimDB | None:
        """The campaign DB, iff this engine consumes one and the caller is
        not managing a DB explicitly (in-memory campaigns only)."""
        if not getattr(engine, "uses_db", False):
            return None
        if "db" in opts:
            return None
        return self._db

    def submit(self, scenario: Scenario, backend: str = "packet",
               **opts) -> RunHandle:
        """Evaluate one scenario on one backend — unless the store already
        holds this exact ``(scenario, backend, opts)`` triple, in which
        case the stored result is returned without simulating."""
        engine = get_engine(backend)
        engine.check_opts(opts)
        self._check_opts(opts)
        key = run_key(scenario, backend, opts)
        rec = self.store.get(key)
        if rec is not None:
            result = RunResult.from_dict(rec["result"])
            self._emit(RunEvent("cache_hit", key, scenario.name, backend,
                                result=result))
            return RunHandle(key, scenario.name, backend, True, result)
        run_opts = dict(opts)
        db = self._db_for(engine, opts)
        mark = None
        if db is not None:
            run_opts["db"] = db
            mark = db.mark()
        self._emit(RunEvent("started", key, scenario.name, backend))
        result = engine.run(scenario, **run_opts)
        if mark is not None and self._remote is not None:
            self._db_outbox.extend(e.to_dict()
                                   for e in db.entries_since(mark))
        self._commit(key, scenario, backend, opts, result,
                     db_used=db is not None)
        self._emit(RunEvent("finished", key, scenario.name, backend,
                            result=result))
        return RunHandle(key, scenario.name, backend, False, result)

    def sweep(self, scenarios: Iterable[Scenario], backend: str = "packet",
              workers: int = 1, store: str | None = None,
              claims: bool | None = None,
              claim_ttl: float = DEFAULT_CLAIM_TTL,
              poll: float = 0.5, **opts) -> list[RunResult]:
        """Evaluate a sweep with crash-safe incremental persistence: each
        completed run commits to the store (and the SimDB flushes) the
        moment it finishes, so a killed sweep resumes from its last
        completed run on the next open.  Runs already in the store — from
        an earlier session or an identical scenario earlier in this very
        sweep — are skipped and served as ``cache_hit`` events.  Results
        keep scenario order.

        ``workers=N`` fans uncached scenarios over N spawn processes (each
        runs against a snapshot of the campaign DB; insert deltas merge
        back as runs complete).  Serial sweeps on batch-capable engines
        (fluid's padded vmap) keep their batched evaluation.

        ``store=URL`` attaches the campaign to a shared store server (see
        :meth:`open`).  ``claims`` turns on work stealing (default: on iff
        a server is attached): before running, each uncached scenario is
        claimed via an atomic marker record, scenarios claimed by another
        host are left to it and polled every ``poll`` seconds — their
        results arrive as ``cache_hit`` events — and a claim that outlives
        ``claim_ttl`` seconds is stolen and run here, so hosts sweeping
        overlapping sets split the work and a crashed host's share is
        reclaimed."""
        scenarios = list(scenarios)
        engine = get_engine(backend)
        if store is not None:
            self._attach_store(store)
        engine.check_opts(opts)
        self._check_opts(opts)
        keys = [run_key(s, backend, opts) for s in scenarios]
        results: list[RunResult | None] = [None] * len(scenarios)
        by_key: dict[str, list[int]] = {}
        todo: list[int] = []
        for i, k in enumerate(keys):
            if k in by_key:                  # intra-sweep duplicate
                by_key[k].append(i)
                continue
            by_key[k] = [i]
            rec = self.store.get(k)
            if rec is not None:
                results[i] = RunResult.from_dict(rec["result"])
                self._emit(RunEvent("cache_hit", k, scenarios[i].name,
                                    backend, index=i, result=results[i]))
            else:
                todo.append(i)
        if claims is None:
            claims = self._remote is not None
        foreign: list[int] = []
        if claims and todo:
            mine = []
            for i in todo:
                if self.store.claim(keys[i], self._owner, ttl=claim_ttl):
                    mine.append(i)
                else:
                    foreign.append(i)
            todo = mine
        db = self._db_for(engine, opts)
        if todo and workers > 1:
            self._sweep_parallel(scenarios, keys, todo, results, backend,
                                 db, opts, workers)
        elif todo:
            self._sweep_serial(scenarios, keys, todo, results, engine,
                               backend, db, opts)
        if claims:
            for i in todo:
                self.store.release(keys[i], self._owner)
        if foreign:
            self._await_foreign(scenarios, keys, foreign, results, engine,
                                backend, db, opts, claim_ttl, poll)
        for k, idxs in by_key.items():
            for j in idxs[1:]:
                results[j] = results[idxs[0]]
                self._emit(RunEvent("cache_hit", k, scenarios[j].name,
                                    backend, index=j, result=results[j]))
        return results

    def _sweep_serial(self, scenarios, keys, todo, results, engine,
                      backend, db, opts) -> None:
        # a batch-capable engine (fluid's padded vmap) evaluates the whole
        # uncached remainder in one compiled program; commit granularity is
        # then the batch, which is inherent to vmapped evaluation
        if db is None and type(engine).run_batch is not Engine.run_batch:
            for i in todo:
                self._emit(RunEvent("started", keys[i], scenarios[i].name,
                                    backend, index=i))
            batch = engine.run_batch([scenarios[i] for i in todo], **opts)
            for i, result in zip(todo, batch):
                results[i] = result
                self._commit(keys[i], scenarios[i], backend, opts, result)
                # (batch path only runs when db is None — nothing to flush)
                self._emit(RunEvent("finished", keys[i], scenarios[i].name,
                                    backend, index=i, result=result))
            return
        for i in todo:
            self._emit(RunEvent("started", keys[i], scenarios[i].name,
                                backend, index=i))
            run_opts = dict(opts)
            mark = None
            if db is not None:
                run_opts["db"] = db
                mark = db.mark()
            result = engine.run(scenarios[i], **run_opts)
            if mark is not None and self._remote is not None:
                self._db_outbox.extend(e.to_dict()
                                       for e in db.entries_since(mark))
            results[i] = result
            self._commit(keys[i], scenarios[i], backend, opts, result,
                         db_used=db is not None)
            self._emit(RunEvent("finished", keys[i], scenarios[i].name,
                                backend, index=i, result=result))

    def _await_foreign(self, scenarios, keys, foreign, results, engine,
                       backend, db, opts, claim_ttl, poll) -> None:
        # another host holds claims on these keys: poll for their records
        # (counter-neutral peeks), and steal any claim that expires — a
        # crashed host's share of the sweep finishes here
        pending = list(foreign)
        while pending:
            still: list[int] = []
            for i in pending:
                rec = self.store.peek(keys[i])
                if rec is not None:
                    results[i] = RunResult.from_dict(rec["result"])
                    self._emit(RunEvent("cache_hit", keys[i],
                                        scenarios[i].name, backend, index=i,
                                        result=results[i]))
                    continue
                if self.store.claim(keys[i], self._owner, ttl=claim_ttl):
                    self._sweep_serial(scenarios, keys, [i], results,
                                       engine, backend, db, opts)
                    self.store.release(keys[i], self._owner)
                    continue
                still.append(i)
            if still:
                time.sleep(poll)
            pending = still

    def _sweep_parallel(self, scenarios, keys, todo, results, backend,
                        db, opts, workers) -> None:
        db_dict = db.to_dict() if db is not None else None
        # spawn, not fork: the parent may have live jax/XLA threads (e.g. a
        # fluid sweep earlier in the session) and forking those deadlocks
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as pool:
            futures = {}
            for i in todo:
                self._emit(RunEvent("started", keys[i], scenarios[i].name,
                                    backend, index=i))
                futures[pool.submit(_worker_run, scenarios[i].to_dict(),
                                    backend, db_dict, dict(opts))] = i
            # commit in completion order — the crash-safe increment; the
            # results list still comes back in scenario order
            for fut in as_completed(futures):
                i = futures[fut]
                result, delta, fingerprint = fut.result()
                results[i] = result
                if db is not None and delta is not None:
                    db.merge(SimDB.from_dict({
                        "format_version": FORMAT_VERSION,
                        "fingerprint": fingerprint, "entries": delta}))
                    if self._remote is not None:
                        # the push dedups server-side, so the raw delta
                        # (pre-merge) is fine to forward as-is
                        self._db_outbox.extend(delta)
                self._commit(keys[i], scenarios[i], backend, opts, result,
                             db_used=db is not None)
                self._emit(RunEvent("finished", keys[i], scenarios[i].name,
                                    backend, index=i, result=result))

    def _commit(self, key, scenario, backend, opts, result,
                db_used: bool = False) -> None:
        self.store.put(key, scenario, backend, opts, result)
        if db_used:
            # only runs the campaign DB was threaded into can have grown
            # it — skip the O(DB size) rewrite for everything else
            self._save_db()
            self._flush_db_outbox()

    def _save_db(self) -> None:
        if self.path is not None and self._db is not None and len(self._db):
            self._db.save(str(self.path / "simdb.json"))

    # ------------------------------------------------------------------ #
    # queries over the store
    # ------------------------------------------------------------------ #
    def records(self, backend: str | None = None,
                scenario: "Scenario | str | None" = None) -> Iterator[dict]:
        """Stored run records, optionally filtered by backend and/or
        scenario (a Scenario or its name)."""
        name = scenario.name if isinstance(scenario, Scenario) else scenario
        for rec in self.store.records():
            if backend is not None and rec["backend"] != backend:
                continue
            if name is not None and rec["scenario"]["name"] != name:
                continue
            yield rec

    def results(self, backend: str | None = None,
                scenario: Scenario | str | None = None) -> list[RunResult]:
        """Stored results (post JSON round-trip), same filters as
        :meth:`records`."""
        return [RunResult.from_dict(r["result"])
                for r in self.records(backend=backend, scenario=scenario)]

    def export_dataset(self, backends=None, heldout_frac: float = 0.25):
        """The campaign's stored ground truth as a learned-engine training
        :class:`~repro.learned.dataset.Dataset` — the ``campaign → training
        set`` seam (``repro.learned`` imports lazily; dataset extraction is
        numpy-only).  ``backends`` defaults to every ground-truth family
        present (packet/wormhole/hybrid)."""
        from repro.learned.dataset import GROUND_TRUTH_BACKENDS, build_dataset
        if backends is None:
            backends = GROUND_TRUTH_BACKENDS
        return build_dataset(self, backends=tuple(backends),
                             heldout_frac=heldout_frac)

    def compare(self, scenario: Scenario,
                backends=("packet", "wormhole"),
                baseline: str | None = None,
                backend_opts: dict | None = None, **opts) -> Comparison:
        """Run ``scenario`` on every backend (cache hits for any the store
        already holds) and tabulate speedups + FCT errors against
        ``baseline`` (default: the first backend).

        ``**opts`` go to every backend; ``backend_opts`` maps a backend
        name to opts only it receives (overriding the shared ones) — the
        ``--opt backend:key=value`` CLI form — so one comparison can, say,
        pin ``hybrid``'s fidelity without leaking an unknown opt into
        ``packet``."""
        backends = tuple(backends)
        baseline = baseline if baseline is not None else backends[0]
        if baseline not in backends:
            raise ValueError(
                f"baseline {baseline!r} not in backends {backends}")
        backend_opts = dict(backend_opts or {})
        unknown = set(backend_opts) - set(backends)
        if unknown:
            raise ValueError(
                f"backend_opts for {sorted(unknown)} but backends are "
                f"{backends}")
        results = {b: self.submit(scenario, backend=b,
                                  **{**opts, **backend_opts.get(b, {})})
                   .result for b in backends}
        return Comparison(scenario=scenario.name, baseline=baseline,
                          results=results)

    def __len__(self) -> int:
        return len(self.store)

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """End the session: flush the SimDB and (for durable campaigns)
        tear down the shared lane-worker pools so spawn workers never
        outlive the work.  Registered at atexit for every open campaign;
        idempotent."""
        if self._closed:
            return
        self._closed = True
        self._save_db()
        self._flush_db_outbox()
        self.store.close()
        _LIVE.discard(self)
        if self.path is not None:
            shutdown_pools()

    def __enter__(self) -> "Campaign":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        where = str(self.path) if self.path is not None else "in-memory"
        if self._remote is not None:
            where += f" -> {self._remote.url}"
        return (f"Campaign({self.name!r}, {where}, runs={len(self.store)}, "
                f"db_entries={len(self._db) if self._db is not None else 0})")
