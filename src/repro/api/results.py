"""Unified structured results returned by every backend."""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class RunResult:
    """What one engine run of one scenario produced.

    ``fcts`` maps flow id -> completion time (seconds); ``iteration_time``
    is the traffic-program makespan (phase-DAG end for workload scenarios,
    last-finish minus first-start for flow scenarios).
    """
    backend: str
    scenario: str
    fcts: dict[int, float]
    flow_bytes: dict[int, float]
    tags: dict[int, str]
    iteration_time: float | None
    events_processed: int
    wall_time: float
    kernel_report: dict | None = None
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def fct_errors_vs(self, baseline: "RunResult") -> np.ndarray:
        """Relative per-flow FCT error against a baseline run of the same
        scenario (flows missing from either side are ignored)."""
        return np.array([abs(self.fcts[fid] - fct) / fct
                         for fid, fct in baseline.fcts.items()
                         if fct > 0 and fid in self.fcts])

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("extras")                       # may hold non-JSON payloads
        return d


def summarize_pair(base: RunResult, other: RunResult) -> dict:
    """Speedup / accuracy summary of ``other`` against baseline ``base`` —
    the table quickstart, simulate_cluster and paper_figures all share."""
    errs = other.fct_errors_vs(base)
    out = {
        "backend": other.backend,
        "events": other.events_processed,
        "wall": other.wall_time,
        "event_speedup": base.events_processed / max(other.events_processed, 1),
        "wall_speedup": base.wall_time / max(other.wall_time, 1e-9),
        "fct_err_mean": float(errs.mean()) if errs.size else float("nan"),
        "fct_err_max": float(errs.max()) if errs.size else float("nan"),
        "fct_err_p99": float(np.quantile(errs, 0.99)) if errs.size else float("nan"),
    }
    if base.iteration_time and other.iteration_time is not None:
        out["iter_err"] = (abs(other.iteration_time - base.iteration_time)
                           / base.iteration_time)
    return out
