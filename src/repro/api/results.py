"""Unified structured results returned by every backend."""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import numpy as np


def jsonify(x: Any, fallback: Callable[[Any], Any] = repr) -> Any:
    """Best-effort canonical JSON form: dataclasses/dicts/sequences recurse,
    dict keys become strings, tuples become lists, numpy arrays/scalars
    unwrap, and anything without a canonical form goes through ``fallback``
    (default ``repr``) — so the output always survives ``json.dumps`` and
    is idempotent on already-JSON trees.  The store's key canonicalizer
    passes a different fallback; keep the recursion shared so record and
    key forms can never diverge on a type."""
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return jsonify(dataclasses.asdict(x), fallback)
    if isinstance(x, dict):
        return {str(k): jsonify(v, fallback) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonify(v, fallback) for v in x]
    if isinstance(x, (set, frozenset)):
        return sorted(jsonify(v, fallback) for v in x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    return fallback(x)


@dataclasses.dataclass
class RunResult:
    """What one engine run of one scenario produced.

    ``fcts`` maps flow id -> completion time (seconds); ``iteration_time``
    is the traffic-program makespan (phase-DAG end for workload scenarios,
    last-finish minus first-start for flow scenarios).
    """
    backend: str
    scenario: str
    fcts: dict[int, float]
    flow_bytes: dict[int, float]
    tags: dict[int, str]
    iteration_time: float | None
    events_processed: int
    wall_time: float
    kernel_report: dict | None = None
    extras: dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def fct_errors_vs(self, baseline: RunResult) -> np.ndarray:
        """Relative per-flow FCT error against a baseline run of the same
        scenario (flows missing from either side are ignored)."""
        return np.array([abs(self.fcts[fid] - fct) / fct
                         for fid, fct in baseline.fcts.items()
                         if fct > 0 and fid in self.fcts])

    def to_dict(self) -> dict:
        """Canonical JSON form: every key is a string, every value survives
        ``json.dumps``.  ``from_dict(to_dict(r)).to_dict() == to_dict(r)``
        exactly — the round-trip the RunStore persists results through.
        ``extras`` payloads ride along in their JSON shape (tuples as lists,
        non-string keys stringified)."""
        return jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: dict) -> "RunResult":
        """Inverse of :meth:`to_dict` (flow-id keys come back as ints)."""
        return cls(
            backend=d["backend"], scenario=d["scenario"],
            fcts={int(k): float(v) for k, v in d["fcts"].items()},
            flow_bytes={int(k): float(v)
                        for k, v in d["flow_bytes"].items()},
            tags={int(k): str(v) for k, v in d["tags"].items()},
            iteration_time=(None if d.get("iteration_time") is None
                            else float(d["iteration_time"])),
            events_processed=int(d["events_processed"]),
            wall_time=float(d["wall_time"]),
            kernel_report=d.get("kernel_report"),
            extras=dict(d.get("extras", {})))


@dataclasses.dataclass
class Comparison:
    """Per-backend speedup/accuracy table against a baseline backend."""
    scenario: str
    baseline: str
    results: dict[str, RunResult]

    def __getitem__(self, backend: str) -> RunResult:
        return self.results[backend]

    def rows(self) -> list[dict]:
        base = self.results[self.baseline]
        return [summarize_pair(base, r) for b, r in self.results.items()
                if b != self.baseline]

    def format(self) -> str:
        base = self.results[self.baseline]
        hdr = (f"{'backend':<10} {'events':>10} {'wall s':>8} {'ev x':>7} "
               f"{'wall x':>7} {'fct err%':>9} {'max err%':>9} {'iter ms':>9}")
        lines = [f"scenario {self.scenario!r}  (baseline: {self.baseline})", hdr,
                 "-" * len(hdr)]
        for b, r in self.results.items():
            s = summarize_pair(base, r)
            it = f"{r.iteration_time * 1e3:9.3f}" if r.iteration_time else " " * 9
            if b == self.baseline:
                lines.append(f"{b:<10} {r.events_processed:>10d} "
                             f"{r.wall_time:8.2f} {'1.0':>7} {'1.0':>7} "
                             f"{'-':>9} {'-':>9} {it}")
            else:
                lines.append(
                    f"{b:<10} {r.events_processed:>10d} {r.wall_time:8.2f} "
                    f"{s['event_speedup']:7.1f} {s['wall_speedup']:7.1f} "
                    f"{100 * s['fct_err_mean']:9.3f} "
                    f"{100 * s['fct_err_max']:9.3f} {it}")
        return "\n".join(lines)

    __str__ = format


def summarize_pair(base: RunResult, other: RunResult) -> dict:
    """Speedup / accuracy summary of ``other`` against baseline ``base`` —
    the table quickstart, simulate_cluster and paper_figures all share."""
    errs = other.fct_errors_vs(base)
    out = {
        "backend": other.backend,
        "events": other.events_processed,
        "wall": other.wall_time,
        "event_speedup": base.events_processed / max(other.events_processed, 1),
        "wall_speedup": base.wall_time / max(other.wall_time, 1e-9),
        "fct_err_mean": float(errs.mean()) if errs.size else float("nan"),
        "fct_err_max": float(errs.max()) if errs.size else float("nan"),
        "fct_err_p99": float(np.quantile(errs, 0.99)) if errs.size else float("nan"),
    }
    if base.iteration_time and other.iteration_time is not None:
        out["iter_err"] = (abs(other.iteration_time - base.iteration_time)
                           / base.iteration_time)
    return out
