"""Analytic flow-level backend: progressive max-min fair sharing.

No packets, no CCA dynamics — at every discrete event (flow arrival, flow
completion, workload timer) the active flows get their max-min fair-share
rates via water-filling over the topology's directed links, and state
advances linearly to the next event.  This is the classic flow-level
abstraction the paper benchmarks against (~20% FCT error, §2.2): the
cheapest rung on the fidelity ladder, three orders of magnitude fewer
events than the packet oracle.

``AnalyticSim`` deliberately mirrors the slice of :class:`PacketSim` the
workload layer touches (``add_flow`` / ``call_at`` / ``finish_listeners`` /
``run`` / ``results``), so the same :class:`WorkloadDriver` drives either.
"""
from __future__ import annotations

import heapq
import itertools

from repro.net.flows import FlowResult, FlowSpec, maxmin_rates
from repro.net.soa import FlowTable
from repro.net.topology import Topology

__all__ = ["AnalyticSim", "maxmin_rates"]   # solver lives in repro.net.flows

_EPS = 1e-12


class _AFlow:
    __slots__ = ("spec", "path", "remaining", "rate", "start_actual")

    def __init__(self, spec: FlowSpec, path: list[int]) -> None:
        self.spec = spec
        self.path = path
        self.remaining = spec.size
        self.rate = 0.0
        self.start_actual = 0.0

    @property
    def fid(self) -> int:
        return self.spec.fid


class AnalyticSim:
    def __init__(self, topo: Topology, **_ignored) -> None:
        self.topo = topo
        self.now = 0.0
        self.events_processed = 0       # rate recomputations (events)
        self.flow_table = FlowTable()   # SoA paths: the solver's direct input
        self.flows: dict[int, _AFlow] = {}
        self.active: dict[int, _AFlow] = {}
        self.results: dict[int, FlowResult] = {}
        self.finish_listeners: list = []
        self._heap: list = []
        self._seq = itertools.count()

    # ------------------------------------------------------------------ #
    def add_flow(self, spec: FlowSpec) -> _AFlow:
        path = self.topo.route(spec.src, spec.dst, spec.fid)
        if not path:
            raise ValueError(f"flow {spec.fid}: src==dst ({spec.src})")
        f = _AFlow(spec, path)
        self.flows[spec.fid] = f
        self.flow_table.add(spec.fid, path)
        heapq.heappush(self._heap,
                       (max(spec.start, self.now), next(self._seq), "start", f))
        return f

    def call_at(self, t: float, fn) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq), "call", fn))

    # ------------------------------------------------------------------ #
    def _maxmin_rates(self) -> None:
        """Water-filling over the active set, via the struct-of-arrays
        :class:`~repro.net.soa.FlowTable` (bit-identical to the historical
        per-solve ``{fid: path}`` dict rebuild, without the rebuild)."""
        rates = self.flow_table.solve_rates(self.active, self.topo.link_bw)
        for fid, r in rates.items():
            self.active[fid].rate = r

    def _advance(self, dt: float) -> None:
        if dt <= 0:
            return
        for f in self.active.values():
            f.remaining -= f.rate * dt

    def _finish(self, f: _AFlow, t: float) -> None:
        self.active.pop(f.fid, None)
        f.remaining = 0.0
        self.results[f.fid] = FlowResult(
            fid=f.fid, start=f.start_actual, fct=t - f.start_actual,
            bytes=f.spec.size, tag=f.spec.tag)
        for cb in self.finish_listeners:
            cb(f, t)

    # ------------------------------------------------------------------ #
    def run(self, until: float = float("inf")) -> None:
        while self._heap or self.active:
            next_t = self._heap[0][0] if self._heap else float("inf")
            if self.active:
                self._maxmin_rates()
                self.events_processed += 1
                t_fin = min(self.now + f.remaining / max(f.rate, _EPS)
                            for f in self.active.values())
                t_next = min(t_fin, next_t)
                if t_next > until:
                    self._advance(until - self.now)
                    self.now = until
                    return
                self._advance(t_next - self.now)
                self.now = t_next
                done = [f for f in self.active.values()
                        if f.remaining <= 1e-6 * f.spec.size + 1e-3]
                if done:
                    for f in done:
                        self._finish(f, self.now)
                    continue            # rates changed: recompute before events
            else:
                if next_t > until:
                    return
                self.now = next_t
            # drain every event at exactly this instant, then recompute rates
            while self._heap and self._heap[0][0] <= self.now + _EPS:
                _, _, kind, payload = heapq.heappop(self._heap)
                self.events_processed += 1
                if kind == "start":
                    payload.start_actual = self.now
                    self.active[payload.fid] = payload
                else:
                    payload(self.now)

    def all_done(self) -> bool:
        return all(fid in self.results for fid in self.flows)
