"""Command-line interface over the Campaign API.

    PYTHONPATH=src python -m repro run gpt@64 --backend wormhole
    PYTHONPATH=src python -m repro run scenario.json -c camp/ --backend hybrid
    PYTHONPATH=src python -m repro sweep a.json b.json -c camp/ --workers 2
    PYTHONPATH=src python -m repro compare gpt@32 --backends packet,hybrid \
        --opt hybrid:fidelity=auto
    PYTHONPATH=src python -m repro serve -c camp/ --port 8321
    PYTHONPATH=src python -m repro sweep a.json --store http://host:8321
    PYTHONPATH=src python -m repro ls -c camp/
    PYTHONPATH=src python -m repro show KEY -c http://host:8321
    PYTHONPATH=src python -m repro rm KEY -c camp/        # or: rm --all
    PYTHONPATH=src python -m repro backends
    PYTHONPATH=src python -m repro fit camp/ --out artifacts/params.json
    PYTHONPATH=src python -m repro lint src tests --format github

Scenarios are either a path to a ``Scenario`` JSON file (``to_json``) or a
training-preset shorthand ``gpt@N`` / ``moe@N`` (modified by ``--cca`` /
``--scale``).  ``-c/--campaign DIR`` makes the session durable: completed
runs commit to the campaign store as they finish, a re-invoked command
skips them (cache hits), and the campaign's SimDB keeps wormhole runs warm
across invocations.  ``-c`` also accepts a store-server URL
(``http://host:port``, see ``serve``) and ``--store URL`` attaches a
durable directory campaign to a shared server.  Without ``-c`` an
anonymous in-memory campaign is used.  ``--opt`` takes ``key=value`` for
every backend or ``backend:key=value`` for one backend only.  Every
command tears the spawn worker pools down before exiting.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.api import Campaign, Scenario, training_scenario
from repro.api.campaign import RunEvent
from repro.net.sharded_sim import shutdown_pools


def _parse_scale(text: str) -> float:
    """Accept '1/256' (the paper's idiom) as well as plain floats."""
    if "/" in text:
        num, den = text.split("/", 1)
        return float(num) / float(den)
    return float(text)


def _load_scenario(spec: str, args) -> Scenario:
    if spec.endswith(".json"):
        try:
            with open(spec) as fh:
                return Scenario.from_json(fh.read())
        except FileNotFoundError:
            raise SystemExit(
                f"error: scenario file {spec!r} not found") from None
    family, sep, n = spec.partition("@")
    if sep and family in ("gpt", "moe") and n.isdigit():
        return training_scenario(n_gpus=int(n), moe=(family == "moe"),
                                 cca=args.cca,
                                 scale=_parse_scale(args.scale))
    raise SystemExit(
        f"error: scenario {spec!r} is neither a .json file nor a "
        f"'gpt@N'/'moe@N' preset")


def _parse_opts(pairs: list[str]) -> tuple[dict, dict]:
    """``--opt key=value`` engine opts; values parse as JSON when they can
    (``--opt fidelity=auto`` stays a string, ``--opt intra_workers=2`` an
    int).  ``--opt backend:key=value`` scopes the opt to one backend;
    returns ``(shared_opts, per_backend_opts)``."""
    opts: dict = {}
    per_backend: dict[str, dict] = {}
    for pair in pairs:
        key, sep, value = pair.partition("=")
        if not sep:
            raise SystemExit(f"error: --opt wants [backend:]key=value, "
                             f"got {pair!r}")
        try:
            val = json.loads(value)
        except json.JSONDecodeError:
            val = value
        backend, bsep, bkey = key.partition(":")
        if bsep:
            per_backend.setdefault(backend, {})[bkey] = val
        else:
            opts[key] = val
    return opts, per_backend


def _engine_opts(args) -> dict:
    """Merged opts for a single-backend command (run/sweep): shared opts
    plus the ones scoped to this backend; opts scoped to a backend the
    command will not run are an error, not a silent drop."""
    opts, per_backend = _parse_opts(args.opt)
    stray = sorted(set(per_backend) - {args.backend})
    if stray:
        raise SystemExit(
            f"error: --opt scoped to backend(s) {', '.join(stray)} but "
            f"this command runs {args.backend!r} (backend-scoped opts "
            f"fan out in `compare`)")
    return {**opts, **per_backend.get(args.backend, {})}


def _open_campaign(args) -> Campaign:
    store = getattr(args, "store", None)
    if getattr(args, "campaign", None):
        return Campaign.open(args.campaign, store=store)
    if store:
        return Campaign.open(store)
    return Campaign.in_memory()


def _progress(event: RunEvent) -> None:
    if event.kind == "started":
        print(f"[{event.backend}] {event.scenario}: running ...")
    elif event.kind == "finished":
        r = event.result
        print(f"[{event.backend}] {event.scenario}: {r.events_processed} "
              f"events in {r.wall_time:.2f}s")
    else:
        print(f"[{event.backend}] {event.scenario}: cache hit "
              f"({event.key[:12]})")


def _summary_line(rec_or_handle) -> str:
    if isinstance(rec_or_handle, dict):
        key, backend = rec_or_handle["key"], rec_or_handle["backend"]
        name = rec_or_handle["scenario"]["name"]
        res = rec_or_handle["result"]
        events, wall = res["events_processed"], res["wall_time"]
        flows = len(res["fcts"])
    else:
        h = rec_or_handle
        key, backend, name = h.key, h.backend, h.scenario
        events, wall = h.result.events_processed, h.result.wall_time
        flows = len(h.result.fcts)
    return (f"{key[:12]}  {backend:<9} {name:<28} {flows:>6} flows "
            f"{events:>10} events {wall:>8.2f}s")


# ---------------------------------------------------------------------- #
# subcommands
# ---------------------------------------------------------------------- #
def cmd_run(args) -> int:
    camp = _open_campaign(args)
    camp.subscribe(_progress)
    opts = _engine_opts(args)
    handle = camp.submit(_load_scenario(args.scenario, args),
                         backend=args.backend, **opts)
    r = handle.result
    print(_summary_line(handle))
    if r.iteration_time:
        print(f"iteration time: {r.iteration_time * 1e3:.3f} ms (scaled)")
    camp.close()
    return 0


def cmd_sweep(args) -> int:
    camp = _open_campaign(args)
    camp.subscribe(_progress)
    opts = _engine_opts(args)
    scenarios = [_load_scenario(s, args) for s in args.scenarios]
    # count from the event stream: intra-sweep duplicates surface as
    # cache_hit events but never touch the store's hit/miss counters
    kinds = []
    camp.subscribe(lambda e: kinds.append(e.kind))
    results = camp.sweep(scenarios, backend=args.backend,
                         workers=args.workers, **opts)
    print(f"sweep done: {len(results)} results "
          f"({kinds.count('cache_hit')} from the store, "
          f"{kinds.count('finished')} simulated)  "
          f"campaign: {len(camp)} stored runs")
    camp.close()
    return 0


def cmd_compare(args) -> int:
    camp = _open_campaign(args)
    camp.subscribe(_progress)
    opts, per_backend = _parse_opts(args.opt)
    backends = tuple(b for b in args.backends.split(",") if b)
    try:
        comparison = camp.compare(_load_scenario(args.scenario, args),
                                  backends=backends, baseline=args.baseline,
                                  backend_opts=per_backend, **opts)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        camp.close()
        return 1
    print(comparison)
    camp.close()
    return 0


def cmd_serve(args) -> int:
    from repro.api.serve import run_server
    return run_server(args.campaign, host=args.host, port=args.port,
                      ttl=args.ttl, quiet=args.quiet)


def cmd_ls(args) -> int:
    camp = _open_campaign(args)
    records = list(camp.records(backend=args.backend or None))
    for rec in records:
        print(_summary_line(rec))
    print(f"{len(records)} stored runs in {camp.name!r}"
          + (f" (db: {len(camp.db)} memo entries)" if camp.db else ""))
    camp.close()
    return 0


def cmd_show(args) -> int:
    camp = _open_campaign(args)
    matches = [k for k in camp.store.keys() if k.startswith(args.key)]
    if not matches:
        print(f"error: no stored run with key prefix {args.key!r}",
              file=sys.stderr)
        camp.close()
        return 1
    if len(matches) > 1:
        print(f"error: key prefix {args.key!r} is ambiguous "
              f"({len(matches)} matches)", file=sys.stderr)
        camp.close()
        return 1
    print(json.dumps(camp.store.get(matches[0]), indent=1))
    camp.close()
    return 0


def cmd_rm(args) -> int:
    camp = _open_campaign(args)
    if args.all:
        keys = set(camp.store.keys())
    else:
        keys = set()
        for prefix in args.keys:
            # destructive, so exactly like `show`: an ambiguous prefix is
            # refused, never expanded
            matches = [k for k in camp.store.keys() if k.startswith(prefix)]
            if not matches:
                print(f"error: no stored run with key prefix {prefix!r}",
                      file=sys.stderr)
                camp.close()
                return 1
            if len(matches) > 1:
                print(f"error: key prefix {prefix!r} is ambiguous "
                      f"({len(matches)} matches); nothing removed",
                      file=sys.stderr)
                camp.close()
                return 1
            keys.add(matches[0])
    for key in sorted(keys):
        camp.store.delete(key)
    print(f"removed {len(keys)} stored runs from {camp.name!r}")
    camp.close()
    return 0


def cmd_backends(args) -> int:
    from repro.api import available_backends, get_engine
    from repro.api.engines import Engine
    print(f"{'backend':<10} {'uses_db':<8} {'run_batch':<10} description")
    for name in available_backends():
        engine = get_engine(name)
        batched = type(engine).run_batch is not Engine.run_batch
        doc = (type(engine).__doc__ or "").strip().splitlines()
        first = doc[0].rstrip(" .") if doc else ""
        print(f"{name:<10} {'yes' if engine.uses_db else 'no':<8} "
              f"{'batched' if batched else 'serial':<10} {first}")
    return 0


def cmd_lint(args) -> int:
    # tools/ is not a package on sys.path when repro is imported from
    # src/; locate it relative to the repo root (walking up also covers
    # editable installs run from a subdirectory)
    from pathlib import Path
    here = Path(__file__).resolve()
    for cand in [here.parents[2], *Path.cwd().resolve().parents,
                 Path.cwd().resolve()]:
        tools = cand / "tools" / "reprolint"
        if tools.is_dir():
            sys.path.insert(0, str(tools.parent))
            break
    else:
        print("error: tools/reprolint not found (run from the repo)",
              file=sys.stderr)
        return 2
    from reprolint.cli import main as reprolint_main
    return reprolint_main(args.args)


def cmd_fit(args) -> int:
    from repro.learned import fit, heldout_fct_error, model
    camp = Campaign.open(args.campaign)
    backends = tuple(args.backends.split(",")) if args.backends else None
    try:
        ds = camp.export_dataset(backends=backends,
                                 heldout_frac=args.heldout_frac)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        camp.close()
        return 1
    print(f"dataset: {len(ds)} flows from {ds.n_records} records "
          f"({ds.n_heldout_records} records / "
          f"{int(ds.heldout.sum())} flows held out)")
    hidden = tuple(int(h) for h in args.hidden.split(",") if h)
    params = fit(ds, seed=args.seed, hidden=hidden, steps=args.steps,
                 lr=args.lr)
    model.save(params, args.out)
    train = params.meta["train"]
    err = heldout_fct_error(params, ds)
    print(f"fit: {train['steps']} steps (best at {train['best_step']}), "
          f"train mse {train['train_mse']:.3e}")
    if err == err:    # not nan
        print(f"held-out mean FCT error: {err * 100:.2f}%")
    print(f"saved {params.fingerprint} -> {args.out}")
    camp.close()
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Experiment campaigns over the engine registry")
    sub = ap.add_subparsers(dest="command", required=True)

    def scenario_args(p):
        p.add_argument("--backend", default="packet")
        p.add_argument("--cca", default="hpcc",
                       help="CCA for gpt@N/moe@N presets")
        p.add_argument("--scale", default="1/256",
                       help="flow-size scale for presets, e.g. 1/256")
        p.add_argument("--opt", action="append", default=[],
                       metavar="KEY=VALUE",
                       help="extra engine opt (repeatable); values parse "
                            "as JSON when possible")
        p.add_argument("-c", "--campaign", metavar="DIR|URL",
                       help="durable campaign directory or store-server "
                            "URL (default: anonymous in-memory session)")
        p.add_argument("--store", metavar="URL", default=None,
                       help="attach the campaign to a shared store server "
                            "(python -m repro serve)")

    p = sub.add_parser("run", help="evaluate one scenario on one backend")
    p.add_argument("scenario", help="scenario .json file or gpt@N / moe@N")
    scenario_args(p)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("sweep",
                       help="evaluate many scenarios, resumably")
    p.add_argument("scenarios", nargs="+",
                   help="scenario .json files and/or gpt@N / moe@N presets")
    scenario_args(p)
    p.add_argument("--workers", type=int, default=1,
                   help="fan uncached scenarios over N spawn processes")
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("compare",
                       help="run one scenario on several backends and "
                            "tabulate speedups + FCT errors")
    p.add_argument("scenario", help="scenario .json file or gpt@N / moe@N")
    scenario_args(p)
    p.add_argument("--backends", default="packet,wormhole",
                   help="comma list of backends (default: packet,wormhole)")
    p.add_argument("--baseline", default=None,
                   help="error/speedup reference (default: first backend)")
    p.set_defaults(fn=cmd_compare)

    p = sub.add_parser("serve",
                       help="serve a campaign's store + memo DB over HTTP "
                            "for remote clients (-c URL / --store URL)")
    p.add_argument("-c", "--campaign", metavar="DIR", required=True,
                   help="campaign directory to serve (created if missing)")
    p.add_argument("--host", default="127.0.0.1",
                   help="bind address (default: 127.0.0.1)")
    p.add_argument("--port", type=int, default=8321,
                   help="bind port; 0 picks an ephemeral port, printed "
                        "on the first line (default: 8321)")
    p.add_argument("--ttl", type=float, default=None,
                   help="expire run records older than TTL seconds "
                        "(background GC; default: keep forever)")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="suppress per-request logging")
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("ls", help="list the campaign's stored runs")
    p.add_argument("-c", "--campaign", metavar="DIR|URL", required=True)
    p.add_argument("--backend", default=None, help="filter by backend")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("show", help="print one stored run record as JSON")
    p.add_argument("key", help="store key (any unambiguous prefix)")
    p.add_argument("-c", "--campaign", metavar="DIR|URL", required=True)
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("rm", help="remove stored runs")
    p.add_argument("keys", nargs="*",
                   help="store keys (unambiguous prefixes)")
    p.add_argument("--all", action="store_true",
                   help="remove every stored run")
    p.add_argument("-c", "--campaign", metavar="DIR|URL", required=True)
    p.set_defaults(fn=cmd_rm)

    p = sub.add_parser("backends",
                       help="list registered backends and capabilities")
    p.set_defaults(fn=cmd_backends)

    # `lint` is special-cased in main(): everything after it goes to
    # reprolint verbatim (argparse REMAINDER can't pass through leading
    # option flags like `lint --list-rules`).  The stub is only here so
    # the subcommand shows up in --help.
    p = sub.add_parser(
        "lint", help="run the reprolint static-analysis gates "
                     "(delegates to tools/reprolint)")
    p.add_argument("args", nargs=argparse.REMAINDER)
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser(
        "fit", help="fit the learned engine on a campaign's stored runs")
    p.add_argument("campaign", metavar="DIR",
                   help="campaign directory holding ground-truth runs")
    p.add_argument("--out", default="artifacts/learned_params.json",
                   help="where to save fitted params (JSON + sibling .npz)")
    p.add_argument("--backends", default=None,
                   help="comma list of ground-truth backends to train on "
                        "(default: packet,wormhole,hybrid)")
    p.add_argument("--heldout-frac", type=float, default=0.25,
                   help="fraction of records held out (by run_key hash)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--steps", type=int, default=1500)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--hidden", default="64,64",
                   help="comma list of hidden layer widths")
    p.set_defaults(fn=cmd_fit)
    return ap


def main(argv=None) -> int:
    raw = list(sys.argv[1:] if argv is None else argv)
    if raw[:1] == ["lint"]:
        # bypass argparse so flags like `lint --list-rules` reach
        # reprolint untouched
        import types
        return cmd_lint(types.SimpleNamespace(args=raw[1:]))
    args = build_parser().parse_args(raw)
    if args.command == "rm" and not args.all and not args.keys:
        build_parser().error("rm wants keys or --all")
    try:
        return args.fn(args)
    except BrokenPipeError:
        return 0                      # e.g. `... ls | head` closed stdout
    finally:
        # spawn workers must never outlive a CLI invocation
        shutdown_pools()


if __name__ == "__main__":
    sys.exit(main())
