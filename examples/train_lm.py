"""End-to-end training driver: a ~100M-parameter granite-family LM trained
for a few hundred steps on CPU with the full substrate — AdamW, synthetic
pipeline, step-atomic checkpoints, and a mid-run injected failure that the
run recovers from.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs.registry import get
from repro.data.pipeline import TokenPipeline
from repro.models.api import build_model
from repro.train.fault import FailureInjector, InjectedFailure
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=60)
    args = ap.parse_args()

    # ~100M params: a granite-family config scaled to laptop size
    cfg = dataclasses.replace(
        get("granite-3-2b"), name="granite-100m", n_layers=12, d_model=768,
        n_heads=12, n_kv=4, head_dim=64, d_ff=2304, vocab=16384,
        dtype="float32", param_dtype="float32", remat=False, loss_chunk=128)
    model = build_model(cfg)
    print(f"{cfg.name}: {model.n_params/1e6:.1f}M params, "
          f"{args.steps} steps, checkpoint+restart demo")

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    pipe = lambda: TokenPipeline(vocab=cfg.vocab, seq_len=256, global_batch=8,
                                 seed=0)
    tcfg = TrainConfig(
        steps=args.steps, log_every=20, ckpt_dir=ckpt, ckpt_every=50,
        opt=AdamWConfig(lr=3e-3, warmup=20, total_steps=args.steps))

    try:
        train(model, pipe(), tcfg,
              injector=FailureInjector(fail_at_step=args.fail_at))
    except InjectedFailure as e:
        print(f"!! {e} — restarting from the latest checkpoint")
    out = train(model, pipe(), tcfg)
    print(f"resumed from step {out['resumed_from']}; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
