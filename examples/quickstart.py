"""Quickstart: Wormhole as a drop-in simulation kernel.

Simulates two waves of contending flows on a leaf-spine fabric twice —
once with plain packet-level DES (the ns-3 baseline), once with the
Wormhole kernel — and prints the speedup, the FCT error, and what the
kernel did (parks / memo replays / skip-backs).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time
sys.path.insert(0, "src")

from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.flows import FlowSpec
from repro.net.packet_sim import PacketSim
from repro.net.topology import leaf_spine_clos


def scenario(kernel=None):
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    sim = PacketSim(topo, kernel=kernel)
    fid = 0
    for wave_start in (0.0, 0.02):              # the second wave repeats the first
        for i in range(4):
            sim.add_flow(FlowSpec(fid, i, 12 + (i % 2), size=8e6,
                                  start=wave_start, cca="dctcp",
                                  tag=f"wave@{wave_start}"))
            fid += 1
    t0 = time.perf_counter()
    sim.run()
    return sim, time.perf_counter() - t0


def main():
    base, base_wall = scenario()
    kernel = WormholeKernel(WormholeConfig())
    wh, wh_wall = scenario(kernel)

    errs = [abs(wh.results[f].fct - r.fct) / r.fct
            for f, r in base.results.items()]
    print(f"baseline : {base.events_processed:>9d} events  {base_wall:.2f}s")
    print(f"wormhole : {wh.events_processed:>9d} events  {wh_wall:.2f}s")
    print(f"speedup  : {base.events_processed / wh.events_processed:.1f}x events, "
          f"{base_wall / wh_wall:.1f}x wall")
    print(f"FCT error: mean {100 * sum(errs) / len(errs):.3f}%  "
          f"max {100 * max(errs):.3f}%   (paper bound: <1% mean)")
    rep = kernel.report()
    print(f"kernel   : {rep['parks']} steady parks, {rep['replays']} memo "
          f"replays ({rep['db_hits']}/{rep['db_lookups']} DB hits), "
          f"{rep['skip_backs']} skip-backs")


if __name__ == "__main__":
    main()
