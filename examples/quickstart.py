"""Quickstart: one declarative scenario, interchangeable backends.

Two waves of contending flows on a leaf-spine fabric, evaluated on the
packet-level DES oracle (the ns-3 baseline), the memoizing Wormhole kernel,
the adaptive packet/flow hybrid, and the flow-level analytic model — one
`compare()` call prints the speedup/FCT-error table.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import FlowSpec, Scenario, TopologySpec, compare


def make_scenario() -> Scenario:
    flows = []
    fid = 0
    for wave_start in (0.0, 0.02):              # the second wave repeats the first
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6,
                                  start=wave_start, cca="dctcp",
                                  tag=f"wave@{wave_start}"))
            fid += 1
    return Scenario(
        name="quickstart",
        topology=TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                       "n_spines": 2}),
        flows=flows,
    )


def main():
    scn = make_scenario()
    cmp = compare(scn, backends=("packet", "wormhole", "hybrid", "analytic"))
    print(cmp.format())
    rep = cmp["wormhole"].kernel_report
    print(f"\nkernel   : {rep['parks']} steady parks, {rep['replays']} memo "
          f"replays ({rep['db_hits']}/{rep['db_lookups']} DB hits), "
          f"{rep['skip_backs']} skip-backs   (paper bound: <1% mean FCT err)")
    g = cmp["hybrid"].extras["granularity"]
    print(f"hybrid   : {g['demotions']} demotions, {g['promotions']} "
          f"promotions, {g['packet_lane_events']} packet-lane events "
          f"(vs {cmp['packet'].events_processed} oracle events)")


if __name__ == "__main__":
    main()
