"""Quickstart: one declarative scenario, interchangeable backends.

Two waves of contending flows on a leaf-spine fabric, evaluated on the
packet-level DES oracle (the ns-3 baseline), the memoizing Wormhole kernel,
the adaptive packet/flow hybrid, and the flow-level analytic model — one
`compare()` call prints the speedup/FCT-error table.  Then the same
scenario through a durable Campaign: resubmitting an already-evaluated
(scenario, backend, opts) triple is a cache hit served from the on-disk
store, no engine invoked.  The last section closes the learned-engine
loop — a campaign's run store is a labeled dataset, so cache ground
truth, fit the MLP, and answer a what-if query without simulating.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile
import time

from repro.api import Campaign, FlowSpec, Scenario, TopologySpec, compare


def make_scenario() -> Scenario:
    flows = []
    fid = 0
    for wave_start in (0.0, 0.02):              # the second wave repeats the first
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6,
                                  start=wave_start, cca="dctcp",
                                  tag=f"wave@{wave_start}"))
            fid += 1
    return Scenario(
        name="quickstart",
        topology=TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                       "n_spines": 2}),
        flows=flows,
    )


def main():
    scn = make_scenario()
    cmp = compare(scn, backends=("packet", "wormhole", "hybrid", "analytic"))
    print(cmp.format())
    rep = cmp["wormhole"].kernel_report
    print(f"\nkernel   : {rep['parks']} steady parks, {rep['replays']} memo "
          f"replays ({rep['db_hits']}/{rep['db_lookups']} DB hits), "
          f"{rep['skip_backs']} skip-backs   (paper bound: <1% mean FCT err)")
    g = cmp["hybrid"].extras["granularity"]
    print(f"hybrid   : {g['demotions']} demotions, {g['promotions']} "
          f"promotions, {g['packet_lane_events']} packet-lane events "
          f"(vs {cmp['packet'].events_processed} oracle events)")

    # durable campaigns: results commit to an on-disk store as they finish,
    # so resubmitting the identical experiment is a cache hit — the stored
    # RunResult comes back through its JSON round-trip, no simulation
    with tempfile.TemporaryDirectory() as td:
        with Campaign.open(os.path.join(td, "campaign"),
                           name="quickstart") as camp:
            first = camp.submit(scn, backend="wormhole")
            again = camp.submit(scn, backend="wormhole")
        assert again.cached and not first.cached
        assert again.result.fcts == first.result.fcts
        print(f"campaign : resubmit of {scn.name!r} cached={again.cached} "
              f"(store key {again.key[:12]}) — identical FCTs, 0 new events")

    # learned engine: cache ground truth -> fit -> query.  13 flow-fidelity
    # hybrid runs (~ms each) become the training set; the fitted MLP then
    # answers a size the campaign never ran, no simulation at all
    from repro.learned import fit
    with Campaign.in_memory(name="quickstart-learned") as camp:
        camp.sweep([scn.variant(name=f"s{i}", size_scale=0.5 + 0.125 * i)
                    for i in range(13)], backend="hybrid", fidelity="flow")
        # a 1-step throwaway fit warms the XLA jit cache, so the timing
        # below measures the workflow rather than the one-time compile
        fit(camp.export_dataset(), seed=0, hidden=(16, 16), steps=1)
        t0 = time.perf_counter()
        params = fit(camp.export_dataset(), seed=0, hidden=(16, 16),
                     steps=150)
        what_if = scn.variant(name="what-if", size_scale=1.1)
        pred = camp.submit(what_if, backend="learned", params=params).result
        elapsed = time.perf_counter() - t0
        truth = camp.submit(what_if, backend="hybrid",
                            fidelity="flow").result
    err = pred.fct_errors_vs(truth).mean()
    assert err < 0.25, f"learned what-if err {err:.3f} looks broken"
    assert all(v > 0 for v in pred.fcts.values())
    print(f"learned  : 13 cached runs -> dataset -> fit -> what-if query in "
          f"{elapsed:.2f}s post-compile, err {err * 100:.2f}% vs flow truth "
          f"(params {params.fingerprint})")


if __name__ == "__main__":
    main()
