"""Quickstart: one declarative scenario, interchangeable backends.

Two waves of contending flows on a leaf-spine fabric, evaluated on the
packet-level DES oracle (the ns-3 baseline), the memoizing Wormhole kernel,
the adaptive packet/flow hybrid, and the flow-level analytic model — one
`compare()` call prints the speedup/FCT-error table.  The last section
shows the same scenario through a durable Campaign: resubmitting an
already-evaluated (scenario, backend, opts) triple is a cache hit served
from the on-disk store, no engine invoked.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.api import Campaign, FlowSpec, Scenario, TopologySpec, compare


def make_scenario() -> Scenario:
    flows = []
    fid = 0
    for wave_start in (0.0, 0.02):              # the second wave repeats the first
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6,
                                  start=wave_start, cca="dctcp",
                                  tag=f"wave@{wave_start}"))
            fid += 1
    return Scenario(
        name="quickstart",
        topology=TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                       "n_spines": 2}),
        flows=flows,
    )


def main():
    scn = make_scenario()
    cmp = compare(scn, backends=("packet", "wormhole", "hybrid", "analytic"))
    print(cmp.format())
    rep = cmp["wormhole"].kernel_report
    print(f"\nkernel   : {rep['parks']} steady parks, {rep['replays']} memo "
          f"replays ({rep['db_hits']}/{rep['db_lookups']} DB hits), "
          f"{rep['skip_backs']} skip-backs   (paper bound: <1% mean FCT err)")
    g = cmp["hybrid"].extras["granularity"]
    print(f"hybrid   : {g['demotions']} demotions, {g['promotions']} "
          f"promotions, {g['packet_lane_events']} packet-lane events "
          f"(vs {cmp['packet'].events_processed} oracle events)")

    # durable campaigns: results commit to an on-disk store as they finish,
    # so resubmitting the identical experiment is a cache hit — the stored
    # RunResult comes back through its JSON round-trip, no simulation
    with tempfile.TemporaryDirectory() as td:
        with Campaign.open(os.path.join(td, "campaign"),
                           name="quickstart") as camp:
            first = camp.submit(scn, backend="wormhole")
            again = camp.submit(scn, backend="wormhole")
        assert again.cached and not first.cached
        assert again.result.fcts == first.result.fcts
        print(f"campaign : resubmit of {scn.name!r} cached={again.cached} "
              f"(store key {again.key[:12]}) — identical FCTs, 0 new events")


if __name__ == "__main__":
    main()
