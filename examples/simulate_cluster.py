"""Simulate one training iteration of a Table-1 workload on a rail-optimized
fat-tree — the paper's headline scenario — with and without Wormhole.

    PYTHONPATH=src python examples/simulate_cluster.py --gpus 128 [--moe]
"""
import argparse
import sys
import time
sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.packet_sim import PacketSim
from repro.workload import presets
from repro.workload.driver import WorkloadDriver
from repro.workload.traffic import build_training_program, program_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gpus", type=int, default=128, choices=[64, 128, 256, 1024])
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--cca", default="hpcc")
    ap.add_argument("--scale", type=float, default=1 / 256,
                    help="flow-size scale vs the real workload (oracle cost)")
    ap.add_argument("--skip-baseline", action="store_true")
    args = ap.parse_args()

    wl = (presets.MOE if args.moe else presets.GPT)[args.gpus]
    ep = min(presets.MOE_EP_DOMAIN, wl.par.dp) if args.moe else 0
    topo = presets.topology_for(args.gpus)
    phases = build_training_program(wl.spec, wl.par, cca=args.cca,
                                    scale=args.scale, ep_over_dp=ep)
    st = program_stats(phases)
    print(f"{wl.name} ({wl.par.label()}) on {topo.name}: "
          f"{st['flows']} flows / {st['phases']} phases, "
          f"{st['bytes']/1e6:.0f} MB scaled wire bytes")

    def run(kernel=None):
        sim = PacketSim(topo, kernel=kernel)
        drv = WorkloadDriver(sim, phases)
        t0 = time.perf_counter()
        sim.run()
        assert drv.finished
        return sim, drv, time.perf_counter() - t0

    if not args.skip_baseline:
        base, bdrv, bwall = run()
        print(f"baseline : {base.events_processed} events, {bwall:.1f}s wall, "
              f"iteration {bdrv.iteration_time*1e3:.2f} ms (scaled)")
    k = WormholeKernel(WormholeConfig())
    wh, wdrv, wwall = run(k)
    rep = k.report()
    skip = rep["est_events_skipped"] / (rep["est_events_skipped"] + wh.events_processed)
    print(f"wormhole : {wh.events_processed} events, {wwall:.1f}s wall, "
          f"iteration {wdrv.iteration_time*1e3:.2f} ms (scaled)")
    if not args.skip_baseline:
        errs = [abs(wh.results[f].fct - r.fct) / r.fct
                for f, r in base.results.items()]
        print(f"speedup  : {base.events_processed/wh.events_processed:.1f}x events "
              f"({bwall/wwall:.1f}x wall); FCT err {100*sum(errs)/len(errs):.2f}% mean; "
              f"iter-time err {100*abs(wdrv.iteration_time-bdrv.iteration_time)/bdrv.iteration_time:.2f}%")
    print(f"kernel   : skip~{100*skip:.1f}%  parks={rep['parks']} "
          f"replays={rep['replays']} db={rep['db_entries']} entries "
          f"({rep['db_bytes']/1e3:.1f} KB)")


if __name__ == "__main__":
    main()
