"""Simulate one training iteration of a Table-1 workload on a rail-optimized
fat-tree — the paper's headline scenario — with and without Wormhole,
through the declarative `repro.api` layer.

    PYTHONPATH=src python examples/simulate_cluster.py --gpus 128 [--moe]
"""
import argparse

from repro.api import compare, run, training_scenario
from repro.workload.traffic import program_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gpus", type=int, default=128, choices=[64, 128, 256, 1024])
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--cca", default="hpcc")
    ap.add_argument("--scale", type=float, default=1 / 256,
                    help="flow-size scale vs the real workload (oracle cost)")
    ap.add_argument("--skip-baseline", action="store_true")
    args = ap.parse_args()

    scn = training_scenario(n_gpus=args.gpus, moe=args.moe, cca=args.cca,
                            scale=args.scale)
    st = program_stats(scn.build_phases())
    print(f"{scn.name} on {scn.build_topology().name}: "
          f"{st['flows']} flows / {st['phases']} phases, "
          f"{st['bytes']/1e6:.0f} MB scaled wire bytes")

    if args.skip_baseline:
        wh = run(scn, backend="wormhole")
        rep = wh.kernel_report
        print(f"wormhole : {wh.events_processed} events, {wh.wall_time:.1f}s "
              f"wall, iteration {wh.iteration_time*1e3:.2f} ms (scaled)")
    else:
        cmp = compare(scn, backends=("packet", "wormhole"))
        print(cmp.format())
        rep = cmp["wormhole"].kernel_report
    skip = rep["est_events_skipped"] / (
        rep["est_events_skipped"] + rep["events_processed"])
    print(f"kernel   : skip~{100*skip:.1f}%  parks={rep['parks']} "
          f"replays={rep['replays']} db={rep['db_entries']} entries "
          f"({rep['db_bytes']/1e3:.1f} KB)")


if __name__ == "__main__":
    main()
