"""Multi-experiment parallelism, TPU-style: vmap the JAX fluid engine over a
batch of what-if scenarios (the analogue of running independent ns-3
processes on spare cores, paper §2.1/§6.1) — one compiled program evaluates
every scenario's converged rates at once.

    PYTHONPATH=src python examples/sweep_cca.py
"""
import sys
import time
sys.path.insert(0, "src")

import numpy as np

from repro.net.fluid_jax import FluidScenario, sweep
from repro.net.topology import rail_optimized_fat_tree


def main():
    topo = rail_optimized_fat_tree(8, gpus_per_server=4, leaf_radix=8, n_spines=2)
    # sweep: how does the DP ring's converged rate change as competing
    # incast flows are added? (16 scenarios, one vmapped evaluation)
    scenarios = []
    for extra in range(16):
        flows = [(i, i, (i + 4) % 32, 1e9) for i in range(8)]
        flows += [(100 + j, 8 + j, 28, 1e9) for j in range(extra)]
        scenarios.append(FluidScenario.from_flows(topo, flows))

    t0 = time.perf_counter()
    out = sweep(scenarios, dt=1e-5, steps=200)
    dt = time.perf_counter() - t0
    rates = np.asarray(out["rate_hist"])[:, -1, :]   # [n_scn, F] final rates
    print(f"evaluated {len(scenarios)} scenarios in {dt:.2f}s (one vmapped run)")
    for i in (0, 4, 8, 15):
        r = rates[i][:8]
        print(f"  +{i:2d} incast flows: DP ring rates "
              f"{r.min()/1e9:.2f}-{r.max()/1e9:.2f} GB/s")


if __name__ == "__main__":
    main()
