"""Batched what-if sweeps through `repro.api.run_many` — the paper's §6.1
multi-experiment parallelism in two flavors:

1. fluid backend: the scenario batch is padded + vmapped, one compiled JAX
   program evaluates every variant's converged rates at once (the TPU
   analogue of running independent ns-3 processes on spare cores);
2. wormhole backend with `shared_db=True`: one simulation DB threads
   through the sweep, so the transients memoized in run 1 fast-forward
   runs 2..N (cross-run warm cache);
3. durable campaigns: a `Campaign` directory owns both the result store
   and the SimDB — a `workers=2` cold sweep commits each run as it
   finishes, and the "next session" re-opens the campaign, skips every
   completed run (cache hits) and runs only the held-out variant, warm.

    PYTHONPATH=src python examples/sweep_cca.py
"""
import os
import tempfile
import time

from repro.api import Campaign, FlowSpec, Scenario, TopologySpec, run_many


def incast_scenario(extra: int) -> Scenario:
    """A DP ring plus `extra` competing incast flows on a rail-optimized
    fabric."""
    topo = TopologySpec("roft", {"n_servers": 8, "gpus_per_server": 4,
                                 "leaf_radix": 8, "n_spines": 2})
    flows = [FlowSpec(i, i, (i + 4) % 32, size=1e9, tag="dp")
             for i in range(8)]
    flows += [FlowSpec(100 + j, 8 + j, 28, size=1e9, tag="incast")
              for j in range(extra)]
    return Scenario(f"incast+{extra}", topo, flows=flows)


def wave_scenario(size_scale: float) -> Scenario:
    """The quickstart contention pattern at a swept flow size (same FCG, so
    the memoized transients transfer across the sweep)."""
    flows = []
    fid = 0
    for wave in (0.0, 0.02):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6 * size_scale,
                                  start=wave, cca="dctcp"))
            fid += 1
    return Scenario(f"waves x{size_scale:g}",
                    TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                          "n_spines": 2}), flows=flows)


def main():
    # -- fluid: 16 scenarios, one vmapped evaluation -------------------- #
    scns = [incast_scenario(extra) for extra in range(16)]
    t0 = time.perf_counter()
    results = run_many(scns, backend="fluid", dt=1e-5, steps=200)
    dt = time.perf_counter() - t0
    print(f"fluid sweep: {len(scns)} scenarios in {dt:.2f}s (one vmapped run)")
    for i in (0, 4, 8, 15):
        rates = [r for fid, r in results[i].extras["rates"].items() if fid < 8]
        print(f"  +{i:2d} incast flows: DP ring rates "
              f"{min(rates)/1e9:.2f}-{max(rates)/1e9:.2f} GB/s")

    # -- wormhole: shared memo DB across the sweep ---------------------- #
    scns = [wave_scenario(s) for s in (1.0, 1.1, 1.2, 1.3)]
    results = run_many(scns, backend="wormhole", shared_db=True)
    print("\nwormhole sweep (one shared SimDB):")
    for scn, r in zip(scns, results):
        rep = r.kernel_report
        print(f"  {scn.name:<12} {r.events_processed:>7d} events  "
              f"memo hits {rep['run_db_hits']}/{rep['run_db_lookups']}  "
              f"(db: {rep['db_entries']} entries)")
    cold, warm = results[0], results[-1]
    print(f"  warm-cache speedup vs cold run: "
          f"{cold.events_processed / max(warm.events_processed, 1):.0f}x events")

    # -- durable campaign: parallel cold sweep -> crash-safe store+DB ---- #
    with tempfile.TemporaryDirectory() as td:
        cdir = os.path.join(td, "campaign")
        with Campaign.open(cdir, name="cca-sweep") as camp:
            cold_par = camp.sweep(scns[:-1], backend="wormhole", workers=2)
        db_bytes = os.path.getsize(os.path.join(cdir, "simdb.json"))
        print(f"\ncampaign sweep: {len(cold_par)} cold runs on 2 worker "
              f"processes, each committed as it finished "
              f"-> {db_bytes}B SimDB on disk")
        # "next session": re-open the campaign and ask for the *full*
        # sweep — completed runs are cache hits from the store, only the
        # held-out variant simulates, warm off the campaign's SimDB
        with Campaign.open(cdir) as camp:
            kinds = []
            camp.subscribe(lambda e: kinds.append(e.kind))
            warm2 = camp.sweep(scns, backend="wormhole", workers=2)[-1]
        rep = warm2.kernel_report
        print(f"  resume: {kinds.count('cache_hit')} cache hits, "
              f"{kinds.count('finished')} simulated")
        print(f"  {scns[-1].name:<12} {warm2.events_processed:>7d} events  "
              f"memo hits {rep['run_db_hits']}/{rep['run_db_lookups']} "
              f"off the re-opened campaign's DB")


if __name__ == "__main__":
    main()
