"""§Perf hillclimbing on the three selected cells (run directly:
``PYTHONPATH=src python benchmarks/hillclimb.py``).

Cells (selection per assignment):
  * granite-3-2b × train_4k   — worst train roofline fraction (0.20),
    collective-bound by TP16 activation all-reduces on a 2.6B model
  * deepseek-v3-671b × train_4k — most representative of MoE-at-scale and
    the largest absolute collective term (71.5 s/step)
  * mixtral-8x22b × prefill_32k — most collective-bound inference cell

Each iteration: hypothesis + napkin math -> config/rules change ->
re-lower + re-compile (feasibility + HLO collective evidence) -> analytic
roofline terms -> confirmed/refuted.  Results land in
artifacts/perf/<cell>.json and are narrated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "perf"


def measure(arch, shape, cfg_override=None, extra_rules=None, label=""):
    from repro.launch import dryrun as D
    parts = D.lower_cell(arch, shape, multi_pod=False,
                         extra_rules=extra_rules, cfg_override=cfg_override)
    rec = D.analyse(*parts)
    rec["variant"] = label
    return rec


def terms(rec):
    return {k: round(rec[k], 3) for k in
            ("compute_s", "memory_s", "collective_s", "roofline_fraction")} | {
        "dominant": rec["dominant"]}


def hillclimb_granite():
    from repro.configs.registry import get
    cell = []
    base = measure("granite-3-2b", "train_4k", label="baseline(TP16xDP16)")
    cell.append(("baseline", None, base))
    # It 1: drop tensor parallelism; use the model axis as extra DP/FSDP.
    # Napkin: TP AR 120.8 GB/chip -> 0; param AG grows 0.9->15.8 GB/chip
    # (3 gathers of the full 5.3 GB bf16 params), grads RS 5.3 GB.
    # coll 2.44s -> ~0.42s < compute 0.48s => compute-bound.
    rules = {"mlp": None, "heads": None, "kv": None, "vocab": None,
             "embed": ("pod", "data", "model"),
             "act_batch": ("pod", "data", "model")}
    it1 = measure("granite-3-2b", "train_4k", extra_rules=rules,
                  label="fsdp256(no-TP)")
    # analytic terms under the variant layout = same formulas with the
    # logical split model=1, dp=256
    from repro.launch import analytic as AN, roofline as RL
    from repro.configs.base import SHAPES
    from repro.models.api import build_model
    cfg = get("granite-3-2b")
    model = build_model(cfg)
    coll = AN.cell_collectives(cfg, SHAPES["train_4k"], model.n_params,
                               {"data": 256, "model": 1})
    fl = AN.cell_flops(cfg, SHAPES["train_4k"])
    mem = AN.cell_memory(cfg, SHAPES["train_4k"], model.n_params, 256, 256)
    t = RL.roofline(fl["total"], mem.traffic_bytes, coll["total"], 256)
    it1.update(t)
    it1["collectives_analytic"] = coll
    cell.append(("fsdp256(no-TP)", rules, it1))
    return "granite-3-2b__train_4k", cell


def hillclimb_deepseek():
    from repro.configs.registry import get
    cfg0 = get("deepseek-v3-671b")
    cell = []
    # paper-faithful-ish baseline of the IMPLEMENTATION before the MoE
    # dispatch rework: dense one-hot (GShard-style) dispatch
    b0 = measure("deepseek-v3-671b", "train_4k",
                 cfg_override=dataclasses.replace(cfg0, moe_dispatch="einsum"),
                 label="einsum-dispatch")
    cell.append(("einsum-dispatch(baseline)", None, b0))
    # It 1: gather/scatter dispatch — dispatch FLOPs T·E·cap·d -> 0.
    # Napkin: compute 1458s -> ~8s (187x), collective unchanged.
    b1 = measure("deepseek-v3-671b", "train_4k", label="gather-dispatch")
    cell.append(("gather-dispatch", None, b1))
    # It 2: save-MoE remat policy — backward recompute repeats the
    # all-to-alls.  Napkin: a2a passes 3->2: 52.3 -> 34.9 GB*...s
    c2 = dataclasses.replace(cfg0, remat_policy="save_moe")
    b2 = measure("deepseek-v3-671b", "train_4k", cfg_override=c2,
                 label="save_moe-remat")
    cell.append(("save_moe-remat", None, b2))
    # It 3: fp8 dispatch wire (DeepSeek-V3's own trick): dispatch direction
    # bytes halve: a2a factor (1+2)/(2+2)=0.75.
    c3 = dataclasses.replace(cfg0, remat_policy="save_moe",
                             moe_a2a_dtype="float8_e4m3fn")
    b3 = measure("deepseek-v3-671b", "train_4k", cfg_override=c3,
                 label="save_moe+fp8a2a")
    cell.append(("save_moe+fp8a2a", None, b3))
    return "deepseek-v3-671b__train_4k", cell


def hillclimb_mixtral():
    from repro.configs.registry import get
    cfg0 = get("mixtral-8x22b")
    cell = []
    b0 = measure("mixtral-8x22b", "prefill_32k",
                 cfg_override=dataclasses.replace(cfg0, moe_dispatch="einsum"),
                 label="einsum-dispatch")
    cell.append(("einsum-dispatch(baseline)", None, b0))
    b1 = measure("mixtral-8x22b", "prefill_32k", label="gather-dispatch")
    cell.append(("gather-dispatch", None, b1))
    c2 = dataclasses.replace(cfg0, moe_a2a_dtype="float8_e4m3fn")
    b2 = measure("mixtral-8x22b", "prefill_32k", cfg_override=c2,
                 label="fp8-a2a")
    cell.append(("fp8-a2a", None, b2))
    return "mixtral-8x22b__prefill_32k", cell


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    for fn in (hillclimb_granite, hillclimb_deepseek, hillclimb_mixtral):
        tag, cell = fn()
        rows = []
        print(f"\n=== {tag} ===")
        for label, rules, rec in cell:
            t = terms(rec)
            print(f"  {label:28s} {t}")
            rows.append({"variant": label, "rules": rules, **{
                k: rec[k] for k in ("compute_s", "memory_s", "collective_s",
                                    "roofline_fraction", "dominant",
                                    "est_peak_gb_per_device", "compile_s")},
                "collectives": rec.get("collectives_analytic", {})})
        (OUT / f"{tag}.json").write_text(json.dumps(rows, indent=1, default=str))


if __name__ == "__main__":
    main()
