"""Deterministic benchmark-regression gate for CI.

Runs a scaled-down pass over the paper-figure scenarios (quickstart incast,
a 32-GPU GPT row, the MoE/EP fallback row) on the packet / wormhole /
hybrid backends and collects *deterministic* counters only — events
processed, memo-DB hits/lookups, steady-skip parks, hybrid granularity
stats.  Wall-clock never enters: CI boxes are noisy, event counts are not.

The counters diff against the committed ``artifacts/ci_baseline.json``
with explicit per-counter tolerances; any drift past tolerance (or any
added/removed counter) fails the run, which is the whole point — a PR that
silently regresses the memo-hit or event-collapse numbers turns the
``bench-regression`` job red instead of landing quietly.

    PYTHONPATH=src python -m benchmarks.ci_regression \
        --baseline artifacts/ci_baseline.json [--update] [--out FILE]

``--update`` rewrites the baseline from the current run (commit the diff
with the PR that legitimately moves a counter, and say why).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile

from benchmarks.common import quickstart_scenario
from repro.api import Campaign, run, training_scenario

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
BASELINE = ART / "ci_baseline.json"

# counters are deterministic by design, so abs stays 0 — a nonzero floor
# would exempt exactly the small counters (db hits, parks, promotions)
# whose silent regressions this gate exists to catch; the rel band only
# absorbs benign version drift on the large event counts
DEFAULT_TOL = {"rel": 0.02, "abs": 0}
# per-counter overrides for anything that legitimately needs more slack
TOLERANCES: dict[str, dict] = {
    # the fixed-seed fit is deterministic on one machine, but XLA CPU
    # codegen differs across boxes/versions — the error magnitude (ppm of
    # FCT) gets a wide band while the hard <10% acceptance bound stays an
    # exact 0/1 counter
    "learned/heldout_err_ppm": {"rel": 0.75, "abs": 500},
}


def collect_counters() -> dict[str, int]:
    """The scaled-down paper_figures pass: one packet oracle run (the
    cheapest scenario only), wormhole and hybrid on every scenario."""
    from repro.kernels.maxmin import SOLVER_COUNTERS, reset_counters

    scenarios = [
        ("quickstart", quickstart_scenario(), True),
        ("gpt32", training_scenario(n_gpus=32, cca="hpcc", scale=1 / 256),
         False),
        ("moe32", training_scenario(n_gpus=32, moe=True, cca="hpcc",
                                    scale=1 / 512), False),
    ]
    reset_counters()
    out: dict[str, int] = {}

    def scenario_counters(label: str, scn, with_packet: bool) -> None:
        if with_packet:
            base = run(scn, backend="packet")
            out[f"{label}/packet/events_processed"] = base.events_processed
        wh = run(scn, backend="wormhole")
        rep = wh.kernel_report
        out[f"{label}/wormhole/events_processed"] = wh.events_processed
        out[f"{label}/wormhole/db_hits"] = rep["db_hits"]
        out[f"{label}/wormhole/db_lookups"] = rep["db_lookups"]
        # steady-skip windows: every park/replay opens one skip window
        out[f"{label}/wormhole/parks"] = rep["parks"]
        out[f"{label}/wormhole/replays"] = rep["replays"]
        hy = run(scn, backend="hybrid")
        g = hy.extras["granularity"]
        sh = hy.extras["shard"]
        out[f"{label}/hybrid/events_processed"] = hy.events_processed
        out[f"{label}/hybrid/packet_lane_events"] = g["packet_lane_events"]
        out[f"{label}/hybrid/demotions"] = g["demotions"]
        out[f"{label}/hybrid/promotions"] = g["promotions"]
        # batched run draining (repro.net.soa.LaneState.pop_run): a drift
        # here means same-timestamp bursts stopped (or started) collapsing
        out[f"{label}/hybrid/batched_drains"] = sh["batched_drains"]
        out[f"{label}/hybrid/max_batch_width"] = sh["max_batch_width"]

    for label, scn, with_packet in scenarios:
        scenario_counters(label, scn, with_packet)
    # water-filling solver invocations across the scenario pass (demotion
    # lanes + flow-fidelity solves) — snapshotted here so the counter pins
    # the figure scenarios alone, not the campaign/learned sweeps below
    # (nor the schedule/chaos rows, which run after the snapshot)
    out["maxmin/solver_invocations"] = SOLVER_COUNTERS["invocations"]
    out["maxmin/max_flows_per_solve"] = SOLVER_COUNTERS["max_flows"]
    # schedule/chaos diversity rows: a staged tree allreduce (the memo must
    # survive non-ring gradient-sync DAGs) and a seeded mice+straggler
    # perturbation (deterministic by construction — the injectors are
    # seeded, so these counters are as exact as the clean ones)
    scenario_counters("gpt32tree", training_scenario(
        n_gpus=32, cca="hpcc", scale=1 / 256, collective="tree"), False)
    scenario_counters("gpt32chaos", training_scenario(
        n_gpus=32, cca="hpcc", scale=1 / 256, chaos=[
            {"kind": "mice", "seed": 7, "rate": 20000.0, "size": 4e4,
             "duration": 0.002},
            {"kind": "straggler", "seed": 3, "count": 2, "factor": 1.5},
        ]), False)
    out.update(campaign_counters())
    out.update(learned_counters())
    return out


def campaign_counters() -> dict[str, int]:
    """Campaign-store dedup counters: three quickstart size variants swept
    twice against one durable campaign.  The first pass must miss the
    store exactly once per variant, the second must be pure cache hits —
    and the campaign SimDB's entry count pins the serial warm-sweep memo
    behavior.  A regression here means dedup keys drifted (silently
    re-simulating stored runs) or stopped discriminating (silently serving
    wrong cache hits)."""
    scn = quickstart_scenario()
    variants = [scn.variant(name=f"ci-{s:g}", size_scale=s)
                for s in (1.0, 1.05, 1.1)]
    with tempfile.TemporaryDirectory() as td:
        with Campaign.open(os.path.join(td, "camp"), name="ci") as camp:
            camp.sweep(variants, backend="wormhole")
            camp.sweep(variants, backend="wormhole")
            hits, misses = camp.store.hits, camp.store.misses
            committed, db_entries = len(camp.store), len(camp.db)
    return {
        "campaign/store_hits": hits,
        "campaign/store_misses": misses,
        "campaign/runs_committed": committed,
        "campaign/db_entries": db_entries,
    }


def learned_counters() -> dict[str, int]:
    """Learned-engine pipeline counters: a fixed 16-record wormhole
    campaign, the deterministic ``run_key``-hash split, and a fixed-seed
    fit.  The record/flow counts are exact (a drift means the dedup keys
    or the split hash moved — both silently reshuffle every training set);
    the held-out error rides along as ppm with a wide tolerance plus an
    exact under-10%% acceptance bit."""
    from benchmarks.learned_bench import wave_scenario
    from repro.learned import fit, heldout_fct_error

    family = [wave_scenario(float(s), base_size=4e5, name=f"ci-learned-{i}")
              for i, s in enumerate([0.5 + 0.08 * k for k in range(20)])]
    with Campaign.in_memory(name="ci-learned") as camp:
        camp.sweep(family, backend="wormhole")
        ds = camp.export_dataset()
    params = fit(ds, seed=0, steps=400)
    err = heldout_fct_error(params, ds)
    return {
        "learned/train_records": ds.n_records - ds.n_heldout_records,
        "learned/heldout_records": ds.n_heldout_records,
        "learned/train_flows": int((~ds.heldout).sum()),
        "learned/heldout_flows": int(ds.heldout.sum()),
        "learned/heldout_err_ppm": -1 if err != err else int(round(err * 1e6)),
        "learned/err_under_10pct": int(err == err and err < 0.10),
    }


def check(baseline: dict, counters: dict) -> list[str]:
    drifts: list[str] = []
    tol_table = baseline.get("tolerances", {})
    default = baseline.get("default_tolerance", DEFAULT_TOL)
    base = baseline["counters"]
    for name in sorted(set(base) | set(counters)):
        if name not in counters:
            drifts.append(f"{name}: in baseline but not produced any more "
                          f"(was {base[name]}) — --update the baseline")
            continue
        if name not in base:
            drifts.append(f"{name}: new counter {counters[name]} not in "
                          f"baseline — --update the baseline")
            continue
        old, new = base[name], counters[name]
        tol = tol_table.get(name, default)
        allowed = max(tol.get("abs", 0), tol.get("rel", 0.0) * abs(old))
        if abs(new - old) > allowed:
            drifts.append(f"{name}: {old} -> {new} "
                          f"(drift {new - old:+}, allowed ±{allowed:g})")
    return drifts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=pathlib.Path, default=BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current run")
    ap.add_argument("--out", type=pathlib.Path,
                    default=ART / "BENCH_ci_counters.json",
                    help="where to dump the current counters (uploaded as a "
                         "workflow artifact)")
    args = ap.parse_args(argv)

    counters = collect_counters()
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps({"counters": counters}, indent=1))
    print(f"wrote {len(counters)} counters -> {args.out}")

    if args.update:
        args.baseline.write_text(json.dumps({
            "format_version": 1,
            "default_tolerance": DEFAULT_TOL,
            "tolerances": TOLERANCES,
            "counters": counters,
        }, indent=1))
        print(f"baseline written -> {args.baseline}")
        return 0
    if not args.baseline.exists():
        # a gate with no baseline must fail loudly, not auto-green: a
        # deleted/renamed baseline (or a workflow path typo) would otherwise
        # turn every CI run into a successful comparison against nothing
        print(f"FAIL: baseline {args.baseline} does not exist "
              f"(run with --update to create it and commit the file)")
        return 2

    baseline = json.loads(args.baseline.read_text())
    drifts = check(baseline, counters)
    if drifts:
        print(f"FAIL: {len(drifts)} counter(s) drifted past tolerance:")
        for d in drifts:
            print("  " + d)
        return 1
    print(f"ok: all {len(counters)} counters within tolerance of "
          f"{args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
