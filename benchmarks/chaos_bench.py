"""Memo-survival benchmark under chaos injection.

Produces ``artifacts/BENCH_chaos.json``: a (collective schedule x chaos
level) grid over a scaled 64-GPU GPT row, measuring how the wormhole
memoization machinery and the hybrid granularity controller hold up when
the traffic program is perturbed — the paper's thesis is that memoized
fast-forwarding survives *structural repetition*, so the interesting
question is what happens when repetition is diluted (background mice,
stragglers) or broken outright (link capacity changes mid-run).

Per cell the row records:

* ``memo_hit_rate`` — wormhole ``db_hits / db_lookups`` (repetition that
  survived the perturbation);
* ``parks`` / ``replays`` / ``skip_backs`` — steady-skip windows opened,
  replayed, and rolled back by a mid-run capacity change or a flow
  arrival;
* ``wh_err_mean`` / ``wh_event_ratio`` — mean per-flow FCT error vs the
  packet oracle and the event-collapse ratio;
* ``hybrid.demotion_rate`` / ``hybrid.promotion_rate`` — demoted flow
  lanes per finished flow, and the fraction of demotions that a capacity
  change (or probe) forced back to packet fidelity.

Chaos levels (five perturbation axes beyond the clean baseline):

* ``mice`` — seeded Poisson background flows across the fabric;
* ``mice+straggler`` — plus seeded 1.5x compute stragglers;
* ``degrade`` — a traffic-carrying fabric port at half capacity from
  mid-iteration on (times are fractions of the measured clean iteration
  time, so the grid stays meaningful if the workload presets move);
* ``degrade@tail`` — the same half-capacity cut, but timed inside the
  gradient-sync tail where the hybrid detector has already demoted the
  dp lanes: this is the cell that exercises chaos-driven *promotions*
  (the window is probed per schedule from the last dp stage's measured
  packet active window — demotion locks on ~90% of the way through it);
* ``flap`` — a dead port (capacity x1e-7) for a tenth of the iteration.
  This cell is a deliberate *divergence showcase*: an MTU that starts
  serializing on a dead port finishes seconds later, so whether any
  given flow straddles the cliff is knife-edge even for the packet
  oracle, and the wormhole/hybrid runs (whose park/unpark legitimately
  shifts absolute packet timing) can catch different straddlers.  The
  recorded errors are expected to be enormous — memoized fast-forwarding
  does not (and cannot) reproduce knife-edge outage straddling; bounded
  degrades are the regime where the <1%% contract survives.

The empty-injector acceptance gate runs first: ``chaos=[]`` must be
*bit-identical* to the pre-chaos packet run (same FCTs, same event
count) — the whole subsystem is free until a perturbation is declared.

Unlike ``benchmarks.ci_regression`` this is not a CI gate — run it on a
quiet box:

    PYTHONPATH=src python -m benchmarks.chaos_bench [--out FILE]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.api import run, training_scenario
from repro.net.packet_sim import PacketSim
from repro.workload.driver import WorkloadDriver

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"

COLLECTIVES = ("ring", "tree", "hierarchical")
SCALE = 1 / 1024        # keeps the 18-cell grid to a few minutes of packet time


def base_scenario(collective: str):
    return training_scenario(n_gpus=64, cca="hpcc", scale=SCALE,
                             collective=collective)


def probe(scn) -> dict:
    """One instrumented packet run: the clean iteration time, a fabric
    port that carries the first dp stage's gradient traffic, and the
    (port, time) pair that lands inside the last dp stage's demotion
    window.  Probed — not hard-coded — so the injectors keep hitting
    live traffic if the topology builder or workload presets change."""
    sim = PacketSim(scn.build_topology())
    phases = scn.build_phases()
    finish: dict[int, float] = {}
    sim.finish_listeners.append(lambda fl, t: finish.setdefault(fl.fid, t))
    drv = WorkloadDriver(sim, phases)
    sim.run()
    dp = [ph for ph in phases if ph.name.startswith("dp")]
    head, tail = dp[0].flows[0], dp[-1].flows[0]
    t0 = sim.flows[tail.fid].start_actual
    # the hybrid demotion detector locks on ~90% of the way through the
    # flow's packet active window; 93% sits between lock-on and completion
    return {
        "iter_t": drv.iteration_time,
        "hot_port": sim.flows[head.fid].path[-1],
        "tail_port": sim.flows[tail.fid].path[-1],
        "tail_t": t0 + 0.93 * (finish[tail.fid] - t0),
    }


def chaos_levels(p: dict) -> dict[str, list[dict]]:
    it = p["iter_t"]
    mice = {"kind": "mice", "seed": 7, "rate": 24.0 / it, "size": 4e4,
            "duration": 0.8 * it}
    return {
        "none": [],
        "mice": [mice],
        "mice+straggler": [
            mice,
            {"kind": "straggler", "seed": 3, "count": 4, "factor": 1.5},
        ],
        "degrade": [
            {"kind": "degrade_link", "link": p["hot_port"], "t": 0.5 * it,
             "factor": 0.5},
        ],
        "degrade@tail": [
            {"kind": "degrade_link", "link": p["tail_port"],
             "t": p["tail_t"], "factor": 0.5},
        ],
        "flap": [
            {"kind": "link_flap", "link": p["hot_port"], "t_down": 0.4 * it,
             "t_up": 0.5 * it},
        ],
    }


def bit_identity_gate(scn) -> dict:
    """chaos=[] must cost nothing: identical FCTs, identical event count."""
    base = run(scn, backend="packet")
    empty = run(scn.variant(name=scn.name + "-empty", chaos=[]),
                backend="packet")
    gate = {"fcts_equal": empty.fcts == base.fcts,
            "events_equal": empty.events_processed == base.events_processed}
    assert all(gate.values()), f"empty injector list is not free: {gate}"
    return gate


def measure_cell(scn, pkt) -> dict:
    wh = run(scn, backend="wormhole")
    rep = wh.kernel_report
    hy = run(scn, backend="hybrid")
    g = hy.extras["granularity"]
    n_flows = len(pkt.fcts)
    return {
        "n_flows": n_flows,
        "pkt_events": pkt.events_processed,
        "memo_hit_rate": round(rep["db_hits"] / max(rep["db_lookups"], 1), 4),
        "db_hits": rep["db_hits"], "db_lookups": rep["db_lookups"],
        "parks": rep["parks"], "replays": rep["replays"],
        "skip_backs": rep["skip_backs"],
        "wh_err_mean": round(float(wh.fct_errors_vs(pkt).mean()), 5),
        "wh_event_ratio": round(
            wh.events_processed / max(pkt.events_processed, 1), 4),
        "hybrid": {
            "demotions": g["demotions"], "promotions": g["promotions"],
            "demotion_rate": round(g["demotions"] / max(n_flows, 1), 4),
            "promotion_rate": round(
                g["promotions"] / max(g["demotions"], 1), 4),
            "hy_err_mean": round(float(hy.fct_errors_vs(pkt).mean()), 5),
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", type=pathlib.Path, default=ART / "BENCH_chaos.json")
    args = ap.parse_args(argv)

    gate = bit_identity_gate(base_scenario("ring"))
    print(f"bit-identity gate (chaos=[]): {gate}")

    grid: dict[str, dict] = {}
    probes: dict[str, dict] = {}
    for collective in COLLECTIVES:
        probes[collective] = p = probe(base_scenario(collective))
        grid[collective] = {}
        for level, injectors in chaos_levels(p).items():
            scn = base_scenario(collective).variant(
                name=f"chaos-bench-{collective}-{level}", chaos=injectors)
            pkt = run(scn, backend="packet")
            cell = measure_cell(scn, pkt)
            grid[collective][level] = cell
            print(f"  {collective:>13s} / {level:<15s} "
                  f"hit_rate={cell['memo_hit_rate']:.2f} "
                  f"parks={cell['parks']} skip_backs={cell['skip_backs']} "
                  f"wh_err={cell['wh_err_mean']:.4f} "
                  f"promo={cell['hybrid']['promotions']}")

    out = {
        "generated_by": "benchmarks/chaos_bench.py",
        "scenario": f"gpt 64-GPU, cca=hpcc, scale={SCALE:g}",
        "bit_identity_empty_injectors": gate,
        "probes": probes,
        "grid": grid,
        "notes": {
            "memo_hit_rate": "wormhole db_hits/db_lookups — structural "
                             "repetition that survived the perturbation",
            "skip_backs": "steady-skip windows rolled back because a "
                          "capacity change (or a flow arrival) invalidated "
                          "the parked rates",
            "promotion_rate": "fraction of hybrid flow-lane demotions "
                              "forced back to packet fidelity",
            "degrade@tail": "capacity cut timed inside the last dp stage's "
                            "demotion window (probed per schedule) — the "
                            "cell that exercises chaos-driven promotions",
            "flap": "divergence showcase, not an accuracy cell: an MTU "
                    "serializing on a dead (1e-7x) port finishes seconds "
                    "later, so which flows straddle the outage is "
                    "knife-edge even for the packet oracle; wormhole/"
                    "hybrid park shifts legitimately catch different "
                    "straddlers and the FCT errors blow up",
        },
    }
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(out, indent=1) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
