# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# Run from the repo root: ``PYTHONPATH=src python -m benchmarks.run``.
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slowest paper-figure benches")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figures, roofline_table
    benches = list(paper_figures.ALL) + list(kernel_bench.ALL) + \
        list(roofline_table.ALL)
    if args.fast:
        slow = {"fig13_sensitivity", "fig8a_speed_vs_scale"}
        benches = [b for b in benches if b.__name__ not in slow]
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]

    print("name,us_per_call,derived")
    all_rows = []
    for bench in benches:
        t0 = time.perf_counter()
        try:
            rows = bench()
        except Exception as e:  # keep the harness honest but alive
            rows = [(f"{bench.__name__}/ERROR", 0.0,
                     {"error": f"{type(e).__name__}: {e}"})]
        for name, us, derived in rows:
            print(f"{name},{us:.1f},\"{json.dumps(derived, default=str)}\"")
            all_rows.append({"name": name, "us_per_call": us, "derived": derived})
        sys.stdout.flush()
    ART.mkdir(exist_ok=True)
    (ART / "bench_results.json").write_text(json.dumps(all_rows, indent=1,
                                                       default=str))


if __name__ == "__main__":
    main()
