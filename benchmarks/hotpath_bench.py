"""Hot-path throughput benchmark: SoA packet lanes + the max-min kernel.

Produces ``artifacts/BENCH_hotpath.json`` with three sections:

* ``events_per_sec`` — serial packet-oracle throughput (best-of-N) on the
  quickstart incast and a 64-GPU GPT row, measured twice: *before* in a
  subprocess against a detached git worktree of ``--baseline-rev`` (the
  growth seed, before the SoA/hot-loop work), and *after* in-process
  against the current tree.  Event counts are asserted identical — the
  speedup is real only because the event streams are bit-identical.
* ``solver_calls_per_sec`` — the max-min water-filling implementations
  (historical dict loop, exact array solver, jax ref, Pallas kernel) at
  100 / 1k / 10k flows over a 128-link fabric.
* ``kernel_parity`` — max relative deviation kernel↔ref and ref↔exact at
  10k flows (the acceptance bar is kernel↔ref ≤ 1e-6).

Unlike ``benchmarks.ci_regression`` this measures wall-clock and is NOT a
CI gate — run it on a quiet box:

    PYTHONPATH=src python -m benchmarks.hotpath_bench [--skip-before]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import tempfile
import time

import numpy as np

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
REPO = pathlib.Path(__file__).resolve().parents[1]
# the growth seed: last commit before the SoA refactor / hot-loop rewrite
DEFAULT_BASELINE_REV = "e4fdf5b"

# one source of truth for the measured scenarios, importable by the
# subprocess that measures the baseline worktree (same builder calls exist
# at the seed rev)
SCENARIOS = {
    "quickstart": "quickstart_scenario()",
    "gpt64": "training_scenario(n_gpus=64, cca='hpcc', scale=1/256)",
}

_CHILD = r"""
import json, sys, time
from benchmarks.common import quickstart_scenario
from repro.api import run, training_scenario

out = {}
for name, expr in json.loads(sys.argv[1]).items():
    best, events = 0.0, None
    for _ in range(int(sys.argv[2])):
        scn = eval(expr)
        t0 = time.perf_counter()
        r = run(scn, backend="packet")
        dt = time.perf_counter() - t0
        events = r.events_processed
        best = max(best, events / dt)
    out[name] = {"events": events, "events_per_sec": best}
print("RESULT " + json.dumps(out))
"""


def measure_events_per_sec(repeats: int) -> dict:
    """In-process best-of-N events/sec for each scenario."""
    from benchmarks.common import quickstart_scenario  # noqa: F401
    from repro.api import run, training_scenario  # noqa: F401

    out = {}
    for name, expr in SCENARIOS.items():
        best, events = 0.0, None
        for _ in range(repeats):
            scn = eval(expr)
            t0 = time.perf_counter()
            r = run(scn, backend="packet")
            dt = time.perf_counter() - t0
            events = r.events_processed
            best = max(best, events / dt)
        out[name] = {"events": events, "events_per_sec": best}
    return out


def measure_baseline(rev: str, repeats: int) -> dict | None:
    """Check out ``rev`` into a temporary worktree and measure it in a
    subprocess (its own interpreter, its own import tree)."""
    with tempfile.TemporaryDirectory(prefix="hotpath_baseline_") as td:
        wt = pathlib.Path(td) / "wt"
        add = subprocess.run(
            ["git", "-C", str(REPO), "worktree", "add", "--detach",
             str(wt), rev], capture_output=True, text=True)
        if add.returncode != 0:
            print(f"warning: cannot create baseline worktree for {rev!r}: "
                  f"{add.stderr.strip()} — skipping before-measurements",
                  file=sys.stderr)
            return None
        try:
            env = {"PYTHONPATH": f"{wt / 'src'}:{wt}", "PATH": "/usr/bin:/bin",
                   "HOME": "/tmp"}
            proc = subprocess.run(
                [sys.executable, "-c", _CHILD, json.dumps(SCENARIOS),
                 str(repeats)],
                capture_output=True, text=True, env=env, cwd=str(wt))
            for line in proc.stdout.splitlines():
                if line.startswith("RESULT "):
                    return json.loads(line[len("RESULT "):])
            print(f"warning: baseline run produced no result "
                  f"(rc={proc.returncode}):\n{proc.stderr[-2000:]}",
                  file=sys.stderr)
            return None
        finally:
            subprocess.run(["git", "-C", str(REPO), "worktree", "remove",
                            "--force", str(wt)], capture_output=True)


def _time_calls(fn, min_seconds: float = 0.4, max_reps: int = 400) -> float:
    """Calls/sec: one warmup call, then enough repeats to fill the budget."""
    fn()                                   # warmup (jit compile, caches)
    t0 = time.perf_counter()
    fn()
    once = time.perf_counter() - t0
    reps = max(1, min(max_reps, int(min_seconds / max(once, 1e-9))))
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return reps / (time.perf_counter() - t0)


def solver_case(n_flows: int, n_links: int = 128, hops: int = 3, seed: int = 7):
    """Random duplicate-free paths (the jax scope) + capacities."""
    rng = np.random.default_rng(seed)
    links = (rng.random((n_flows, n_links)).argpartition(hops, axis=1)
             [:, :hops].astype(np.int64))
    paths = {100 + i: list(map(int, links[i])) for i in range(n_flows)}
    bw = rng.uniform(1e9, 1e10, n_links)
    off = np.arange(0, hops * (n_flows + 1), hops, dtype=np.int64)
    return paths, links.ravel(), off, bw


def measure_solvers() -> tuple[dict, dict]:
    from repro.kernels.maxmin import solve_paths
    from repro.kernels.maxmin.ops import maxmin_rates_arrays, maxmin_rates_jax
    from repro.net.flows import maxmin_rates_dict

    calls = {}
    for F in (100, 1000, 10_000):
        paths, links, off, bw = solver_case(F)
        calls[f"flows={F}"] = {
            "dict": _time_calls(lambda: maxmin_rates_dict(paths, bw)),
            "array": _time_calls(lambda: solve_paths(paths, bw)),
            "jax_ref": _time_calls(
                lambda: maxmin_rates_jax(links, off, bw, impl="ref")),
            "pallas_kernel": _time_calls(
                lambda: maxmin_rates_jax(links, off, bw, impl="kernel")),
        }
    # parity at the largest size
    paths, links, off, bw = solver_case(10_000)
    ref = np.asarray(maxmin_rates_jax(links, off, bw, impl="ref"), np.float64)
    ker = np.asarray(maxmin_rates_jax(links, off, bw, impl="kernel"),
                     np.float64)
    exact = maxmin_rates_arrays(links, off, bw)
    denom = np.maximum(np.abs(ref), 1e-30)
    parity = {
        "flows": 10_000,
        "max_rel_diff_kernel_vs_ref": float(np.max(np.abs(ker - ref) / denom)),
        "max_rel_diff_ref_vs_exact": float(
            np.max(np.abs(ref - exact) / np.maximum(np.abs(exact), 1e-30))),
    }
    return calls, parity


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-rev", default=DEFAULT_BASELINE_REV)
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N for the events/sec runs")
    ap.add_argument("--skip-before", action="store_true",
                    help="skip the baseline-worktree measurements")
    ap.add_argument("--out", type=pathlib.Path,
                    default=ART / "BENCH_hotpath.json")
    args = ap.parse_args(argv)

    before = None if args.skip_before else measure_baseline(
        args.baseline_rev, args.repeats)
    after = measure_events_per_sec(args.repeats)

    events = {}
    for name, a in after.items():
        row = {"events": a["events"],
               "after_events_per_sec": round(a["events_per_sec"])}
        if before and name in before:
            b = before[name]
            # the invariant the whole PR rests on: the optimized loop pops
            # exactly the event stream the seed loop popped
            assert b["events"] == a["events"], (
                f"{name}: event count drifted {b['events']} -> {a['events']}")
            row["before_events_per_sec"] = round(b["events_per_sec"])
            row["speedup"] = round(a["events_per_sec"] /
                                   b["events_per_sec"], 2)
        events[name] = row

    solver_calls, parity = measure_solvers()

    out = {
        "generated_by": "benchmarks/hotpath_bench.py",
        "baseline_rev": args.baseline_rev,
        "events_per_sec": events,
        "solver_calls_per_sec": {
            k: {impl: round(v, 1) for impl, v in row.items()}
            for k, row in solver_calls.items()},
        "kernel_parity": parity,
        "notes": {
            "slots_sweep": (
                "CCA hierarchy, wormhole Part and memo entries moved to "
                "slotted classes; measured on the dev box (best-of-3, "
                "before the loop rewrite) this step alone took quickstart "
                "343661 -> 450662 ev/s and gpt64 243423 -> 325971 ev/s"),
            "logging_and_clocks": (
                "audit found no logging calls and no wall-clock reads on "
                "the packet hot path (time.perf_counter only in cold-path "
                "campaign/engine bookkeeping), so the guarded-logging and "
                "cached-clock parts of the sweep were no-ops"),
            "methodology": (
                "events/sec is best-of-N wall-clock over identical "
                "scenarios; 'before' runs in a subprocess against a "
                "detached worktree of baseline_rev with its own "
                "PYTHONPATH"),
        },
    }
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(out, indent=1) + "\n")
    print(f"wrote {args.out}")
    for name, row in events.items():
        print(f"  {name}: {row}")
    print(f"  parity: {parity}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
