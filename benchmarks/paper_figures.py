"""One benchmark per paper table/figure (scaled workloads; ratios are the
reproduced quantity, wall-clock absolutes are CPU-scaled).  Each function
returns rows of (name, us_per_call, derived-metrics-dict)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_pair, run_one, summarize, workload, fct_errors
from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.fluid_jax import FluidScenario, fluid_converged_rates

SCALE = 1 / 256
SIZES = (16, 32, 64, 128)


def _row(name, seconds, derived):
    return (name, seconds * 1e6, derived)


# ------------------------------------------------------------------ #
# Fig 8a — speedup vs network size (GPT workload)
# ------------------------------------------------------------------ #
def fig8a_speed_vs_scale():
    rows = []
    for n in SIZES:
        topo, phases = workload(n, cca="hpcc", scale=SCALE)
        base, wh, k = run_pair(f"gpt{n}", topo, phases)
        s = summarize(base, wh, k)
        rows.append(_row(f"fig8a/gpt@{n}gpus", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "wall_speedup": round(s["wall_speedup"], 2),
            "base_events": s["base_events"],
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 8b — speedup per CCA; Fig 10b — skip ratio per CCA
# ------------------------------------------------------------------ #
def fig8b_10b_cca():
    rows = []
    for cca in ("dctcp", "dcqcn", "timely", "hpcc"):
        topo, phases = workload(64, cca=cca, scale=SCALE)
        base, wh, k = run_pair(f"gpt64-{cca}", topo, phases)
        s = summarize(base, wh, k)
        rows.append(_row(f"fig8b/speedup@{cca}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "skip_ratio": round(s["skip_ratio"], 4),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 9a/9b — partitions and DB size
# ------------------------------------------------------------------ #
def fig9_partitions_db():
    rows = []
    for n in SIZES:
        topo, phases = workload(n, cca="hpcc", scale=SCALE)
        base, wh, k = run_pair(f"gpt{n}", topo, phases)
        s = summarize(base, wh, k)
        rows.append(_row(f"fig9/gpt@{n}gpus", wh["wall"], {
            "partitions_formed": s["partitions_seen"],
            "db_entries": s["db_entries"],
            "db_bytes": s["db_bytes"],
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 10a — acceleration breakdown (steady-only / memo-only / both)
# ------------------------------------------------------------------ #
def fig10a_breakdown():
    topo, phases = workload(64, cca="hpcc", scale=SCALE)
    rows = []
    for label, cfg in [
        ("steady_only", WormholeConfig(enable_memo=False)),
        ("memo_only", WormholeConfig(enable_steady=False)),
        ("both", WormholeConfig()),
    ]:
        base, wh, k = run_pair("gpt64-hpcc", topo, phases, wcfg=cfg)
        s = summarize(base, wh, k)
        rows.append(_row(f"fig10a/{label}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 11 — FCT error: Wormhole vs flow-level (fluid) simulator
# ------------------------------------------------------------------ #
def fig11_accuracy():
    rows = []
    for n in (32, 64):
        topo, phases = workload(n, cca="hpcc", scale=SCALE)
        base, wh, k = run_pair(f"gpt{n}", topo, phases)
        s = summarize(base, wh, k)
        # flow-level abstraction: every phase's flows at fluid converged
        # rates (no transients, no packets) — the paper's ~20%-error baseline
        ferr = _flow_level_errors(topo, phases, base)
        rows.append(_row(f"fig11/gpt@{n}gpus", wh["wall"], {
            "wormhole_fct_err": round(s["fct_err_mean"], 5),
            "flow_level_fct_err": round(float(ferr), 5),
            "iteration_time_err": round(s["iter_err"], 5),
        }))
    return rows


def _flow_level_errors(topo, phases, base) -> float:
    errs = []
    for ph in phases:
        if not ph.flows:
            continue
        scn = FluidScenario.from_flows(
            topo, [(f.fid, f.src, f.dst, f.size) for f in ph.flows])
        r = fluid_converged_rates(scn, steps=120)
        for f, rate in zip(ph.flows, r["rates"]):
            est = f.size / max(rate, 1e3)
            true = base["fcts"].get(f.fid)
            if true:
                errs.append(abs(est - true) / true)
    return float(np.mean(errs))


# ------------------------------------------------------------------ #
# Fig 12 — NRMSE of per-packet RTTs (first flow per class)
# ------------------------------------------------------------------ #
def fig12_rtt_nrmse():
    topo, phases = workload(64, cca="hpcc", scale=SCALE)
    fid0 = phases[-1].flows[0].fid          # a DP elephant
    base, wh, k = run_pair("gpt64-hpcc", topo, phases, record_rtt=(fid0,))
    bt = np.array([t for t, _ in base["sim"].flows[fid0].rtt_samples])
    br = np.array([r for _, r in base["sim"].flows[fid0].rtt_samples])
    wt = np.array([t for t, _ in wh["sim"].flows[fid0].rtt_samples])
    wr = np.array([r for _, r in wh["sim"].flows[fid0].rtt_samples])
    if len(wt) < 2:
        nrmse = float("nan")
    else:
        interp = np.interp(bt, wt, wr)      # steady gaps: last-value hold
        nrmse = float(np.sqrt(np.mean((interp - br) ** 2)) / np.mean(br))
    return [_row("fig12/rtt_nrmse", wh["wall"], {
        "nrmse": round(nrmse, 5), "packets_base": len(br),
        "packets_wormhole": len(wr)})]


# ------------------------------------------------------------------ #
# Fig 13 — sensitivity: metric, l, θ
# ------------------------------------------------------------------ #
def fig13_sensitivity():
    topo, phases = workload(64, cca="hpcc", scale=SCALE)
    rows = []
    for metric in ("rate", "inflight", "qlen"):
        base, wh, k = run_pair("gpt64-hpcc", topo, phases,
                               wcfg=WormholeConfig(metric=metric))
        s = summarize(base, wh, k)
        rows.append(_row(f"fig13a/metric={metric}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    for l in (16, 32, 64):
        base, wh, k = run_pair("gpt64-hpcc", topo, phases,
                               wcfg=WormholeConfig(window=l, window_auto=False))
        s = summarize(base, wh, k)
        rows.append(_row(f"fig13b/l={l}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    for theta in (0.02, 0.05, 0.1, 0.2):
        base, wh, k = run_pair("gpt64-hpcc", topo, phases,
                               wcfg=WormholeConfig(theta=theta, theta_auto=False))
        s = summarize(base, wh, k)
        rows.append(_row(f"fig13c/theta={theta}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    return rows


# ------------------------------------------------------------------ #
# Fig 14 — topologies
# ------------------------------------------------------------------ #
def fig14_topology():
    from repro.net.topology import fat_tree, leaf_spine_clos
    from repro.workload.traffic import build_training_program
    from repro.workload.parallelism import ParallelismConfig
    from benchmarks.common import gpt_spec
    rows = []
    par = ParallelismConfig(tp=8, dp=4, pp=2)
    spec = gpt_spec(64)
    topos = {
        "roft": workload(64, scale=SCALE)[0],
        "fat_tree": fat_tree(8),
        "clos": leaf_spine_clos(64, leaf_down=16, n_spines=8),
    }
    for name, topo in topos.items():
        phases = build_training_program(spec, par, cca="hpcc", scale=SCALE)
        base, wh, k = run_pair(f"gpt64-{name}", topo, phases)
        s = summarize(base, wh, k)
        rows.append(_row(f"fig14/{name}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    return rows


# ------------------------------------------------------------------ #
# Fig 3a/3b — pattern repetition + steady share; MoE vs GPT contrast
# ------------------------------------------------------------------ #
def fig3_patterns_steady():
    rows = []
    for label, moe in (("gpt", False), ("moe", True)):
        topo, phases = workload(64, cca="hpcc", scale=SCALE, moe=moe)
        base, wh, k = run_pair(f"{label}64-patterns", topo, phases)
        rep = k.report()
        # steady share: steady time / active flow time
        active = sum(r for r in base["fcts"].values())
        steady = rep["steady_flow_seconds"]
        rows.append(_row(f"fig3/{label}", wh["wall"], {
            "pattern_instances": rep["db_lookups"],
            "distinct_patterns": rep["db_entries"],
            "repetitions": rep["db_hits"],
            "steady_share": round(steady / max(active, 1e-12), 4),
            "skip_ratio": round(rep["est_events_skipped"] /
                                max(rep["est_events_skipped"] + wh["events"], 1), 4),
        }))
    return rows


# ------------------------------------------------------------------ #
# Table "Wormhole+parallel": warm-DB second experiment (multi-experiment)
# ------------------------------------------------------------------ #
def warm_db_second_run():
    topo, phases = workload(64, cca="hpcc", scale=SCALE)
    base, wh1, k1 = run_pair("gpt64-hpcc", topo, phases)
    hits_before = k1.db.hits
    k2 = WormholeKernel(WormholeConfig(), db=k1.db)       # reuse knowledge
    wh2 = run_one(topo, phases, kernel=k2)
    errs = fct_errors(base, wh2)
    return [_row("multi_experiment/warm_db", wh2["wall"], {
        "cold_speedup": round(base["events"] / wh1["events"], 2),
        "warm_speedup": round(base["events"] / wh2["events"], 2),
        "warm_fct_err": round(float(errs.mean()), 5),
        "warm_hits": k2.db.hits - hits_before,
    })]


# ------------------------------------------------------------------ #
# Beyond-paper: speedup vs flow-size scale (extrapolation toward the
# paper's GB-flow regime; paper flows are ~256x our 1/256 default)
# ------------------------------------------------------------------ #
def scale_trend():
    rows = []
    for scale, label in ((1 / 512, "1/512"), (1 / 256, "1/256"),
                         (1 / 128, "1/128")):
        topo, phases = workload(64, cca="hpcc", scale=scale)
        base, wh, k = run_pair(f"gpt64-scale{label}", topo, phases)
        s = summarize(base, wh, k)
        rows.append(_row(f"scale_trend/{label}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "skip_ratio": round(s["skip_ratio"], 4),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# paper-faithful detector (plain Eq.6, fixed l and theta) vs hardened
def faithful_vs_hardened():
    topo, phases = workload(64, cca="hpcc", scale=1 / 256)
    rows = []
    for label, cfg in (
        ("paper_faithful", WormholeConfig(confirm=False, theta_auto=False,
                                          window_auto=False, window=16)),
        ("hardened_default", WormholeConfig()),
    ):
        base, wh, k = run_pair("gpt64-hpcc", topo, phases, wcfg=cfg)
        s = summarize(base, wh, k)
        rows.append(_row(f"detector/{label}", wh["wall"], {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5),
            "fct_err_p99": round(s["fct_err_p99"], 5),
        }))
    return rows


# straggler handling at the simulation layer: a slow rank shifts phase
# launches; Wormhole absorbs them as real-time interrupts (skip-backs)
def straggler_sim():
    from repro.workload import presets
    from repro.workload.traffic import build_training_program
    wl = presets.GPT[64]
    topo = presets.topology_for(64)
    phases = build_training_program(wl.spec, wl.par, cca="hpcc", scale=1 / 256,
                                    straggler=(0, 3.0))
    base, wh, k = run_pair("gpt64-straggler", topo, phases)
    s = summarize(base, wh, k)
    return [_row("straggler/rank0_3x", wh["wall"], {
        "event_speedup": round(s["event_speedup"], 2),
        "fct_err_mean": round(s["fct_err_mean"], 5),
        "iter_err": round(s["iter_err"], 5),
        "skip_backs": s["skip_backs"],
    })]


ALL = [fig3_patterns_steady, fig8a_speed_vs_scale, fig8b_10b_cca,
       fig9_partitions_db, fig10a_breakdown, fig11_accuracy, fig12_rtt_nrmse,
       fig13_sensitivity, fig14_topology, warm_db_second_run, scale_trend,
       faithful_vs_hardened, straggler_sim]
