"""One benchmark per paper table/figure (scaled workloads; ratios are the
reproduced quantity, wall-clock absolutes are CPU-scaled).  Each function
returns rows of (name, us_per_call, derived-metrics-dict).  All runs go
through the `repro.api` experiment layer."""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import (_CACHE, packet_baseline, quickstart_scenario,
                               run_pair, summarize, workload)
from repro.api import (Campaign, FlowSpec, Scenario, TopologySpec, run,
                       run_many)
from repro.core.wormhole import WormholeConfig

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"

SCALE = 1 / 256
SIZES = (16, 32, 64, 128)


def _sweep_variants():
    return [workload(64, cca="hpcc", scale=SCALE).variant(
        name=f"gpt64-sz{s:g}", size_scale=s) for s in (1.0, 1.05, 1.1, 1.15)]


def _shared_db_sweep(variants):
    """The serial shared-SimDB sweep, cached so warm_db_sweep and
    persist_warm_sweep (which uses it as the in-memory warm baseline)
    run it once."""
    key = ("warm_sweep", tuple(v.name for v in variants))
    if key not in _CACHE:
        _CACHE[key] = run_many(variants, backend="wormhole", shared_db=True)
    return _CACHE[key]


def _row(name, seconds, derived):
    return (name, seconds * 1e6, derived)


# ------------------------------------------------------------------ #
# Fig 8a — speedup vs network size (GPT workload)
# ------------------------------------------------------------------ #
def fig8a_speed_vs_scale():
    rows = []
    for n in SIZES:
        base, wh = run_pair(workload(n, cca="hpcc", scale=SCALE))
        s = summarize(base, wh)
        rows.append(_row(f"fig8a/gpt@{n}gpus", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "wall_speedup": round(s["wall_speedup"], 2),
            "base_events": s["base_events"],
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 8b — speedup per CCA; Fig 10b — skip ratio per CCA
# ------------------------------------------------------------------ #
def fig8b_10b_cca():
    rows = []
    for cca in ("dctcp", "dcqcn", "timely", "hpcc"):
        base, wh = run_pair(workload(64, cca=cca, scale=SCALE))
        s = summarize(base, wh)
        rows.append(_row(f"fig8b/speedup@{cca}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "skip_ratio": round(s["skip_ratio"], 4),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 9a/9b — partitions and DB size
# ------------------------------------------------------------------ #
def fig9_partitions_db():
    rows = []
    for n in SIZES:
        base, wh = run_pair(workload(n, cca="hpcc", scale=SCALE))
        s = summarize(base, wh)
        rows.append(_row(f"fig9/gpt@{n}gpus", wh.wall_time, {
            "partitions_formed": s["partitions_seen"],
            "db_entries": s["db_entries"],
            "db_bytes": s["db_bytes"],
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 10a — acceleration breakdown (steady-only / memo-only / both)
# ------------------------------------------------------------------ #
def fig10a_breakdown():
    scn = workload(64, cca="hpcc", scale=SCALE)
    rows = []
    for label, cfg in [
        ("steady_only", WormholeConfig(enable_memo=False)),
        ("memo_only", WormholeConfig(enable_steady=False)),
        ("both", WormholeConfig()),
    ]:
        base, wh = run_pair(scn, wcfg=cfg)
        s = summarize(base, wh)
        rows.append(_row(f"fig10a/{label}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 11 — FCT error: Wormhole vs flow-level (fluid) simulator
# ------------------------------------------------------------------ #
def fig11_accuracy():
    rows = []
    for n in (32, 64):
        scn = workload(n, cca="hpcc", scale=SCALE)
        base, wh = run_pair(scn)
        s = summarize(base, wh)
        # flow-level abstraction: every phase's flows at fluid converged
        # rates (no transients, no packets) — the paper's ~20%-error baseline
        fluid = run(scn, backend="fluid", steps=120)
        ferr = float(fluid.fct_errors_vs(base).mean())
        rows.append(_row(f"fig11/gpt@{n}gpus", wh.wall_time, {
            "wormhole_fct_err": round(s["fct_err_mean"], 5),
            "flow_level_fct_err": round(ferr, 5),
            "iteration_time_err": round(s["iter_err"], 5),
        }))
    return rows


# ------------------------------------------------------------------ #
# Fig 12 — NRMSE of per-packet RTTs (first flow per class)
# ------------------------------------------------------------------ #
def fig12_rtt_nrmse():
    scn = workload(64, cca="hpcc", scale=SCALE)
    fid0 = scn.build_phases()[-1].flows[0].fid          # a DP elephant
    base, wh = run_pair(scn, record_rtt=(fid0,))
    bs = base.extras["rtt_samples"][fid0]
    ws = wh.extras["rtt_samples"][fid0]
    bt, br = (np.array([t for t, _ in bs]), np.array([r for _, r in bs]))
    wt, wr = (np.array([t for t, _ in ws]), np.array([r for _, r in ws]))
    if len(wt) < 2:
        nrmse = float("nan")
    else:
        interp = np.interp(bt, wt, wr)      # steady gaps: last-value hold
        nrmse = float(np.sqrt(np.mean((interp - br) ** 2)) / np.mean(br))
    return [_row("fig12/rtt_nrmse", wh.wall_time, {
        "nrmse": round(nrmse, 5), "packets_base": len(br),
        "packets_wormhole": len(wr)})]


# ------------------------------------------------------------------ #
# Fig 13 — sensitivity: metric, l, θ
# ------------------------------------------------------------------ #
def fig13_sensitivity():
    scn = workload(64, cca="hpcc", scale=SCALE)
    rows = []
    for metric in ("rate", "inflight", "qlen"):
        base, wh = run_pair(scn, wcfg=WormholeConfig(metric=metric))
        s = summarize(base, wh)
        rows.append(_row(f"fig13a/metric={metric}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    for l in (16, 32, 64):
        base, wh = run_pair(scn, wcfg=WormholeConfig(window=l, window_auto=False))
        s = summarize(base, wh)
        rows.append(_row(f"fig13b/l={l}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    for theta in (0.02, 0.05, 0.1, 0.2):
        base, wh = run_pair(scn, wcfg=WormholeConfig(theta=theta, theta_auto=False))
        s = summarize(base, wh)
        rows.append(_row(f"fig13c/theta={theta}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    return rows


# ------------------------------------------------------------------ #
# Fig 14 — topologies
# ------------------------------------------------------------------ #
def fig14_topology():
    base_scn = workload(64, cca="hpcc", scale=SCALE)
    topos = {
        "roft": base_scn.topology,
        "fat_tree": TopologySpec("fat_tree", {"k": 8}),
        "clos": TopologySpec("clos", {"n_hosts": 64, "leaf_down": 16,
                                      "n_spines": 8}),
    }
    rows = []
    for name, tspec in topos.items():
        scn = dataclasses.replace(base_scn, name=f"gpt64-{name}",
                                  topology=tspec)
        base, wh = run_pair(scn)
        s = summarize(base, wh)
        rows.append(_row(f"fig14/{name}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5)}))
    return rows


# ------------------------------------------------------------------ #
# Fig 3a/3b — pattern repetition + steady share; MoE vs GPT contrast
# ------------------------------------------------------------------ #
def fig3_patterns_steady():
    rows = []
    for label, moe in (("gpt", False), ("moe", True)):
        base, wh = run_pair(workload(64, cca="hpcc", scale=SCALE, moe=moe))
        rep = wh.kernel_report
        # steady share: steady time / active flow time
        active = sum(base.fcts.values())
        steady = rep["steady_flow_seconds"]
        rows.append(_row(f"fig3/{label}", wh.wall_time, {
            "pattern_instances": rep["db_lookups"],
            "distinct_patterns": rep["db_entries"],
            "repetitions": rep["db_hits"],
            "steady_share": round(steady / max(active, 1e-12), 4),
            "skip_ratio": round(rep["est_events_skipped"] /
                                max(rep["est_events_skipped"]
                                    + wh.events_processed, 1), 4),
        }))
    return rows


# ------------------------------------------------------------------ #
# §6.1 multi-experiment parallelism: a warm-DB what-if sweep.  One shared
# SimDB threads through N scenario variants — the new-capability benchmark:
# run 1's memo entries fast-forward runs 2..N.
# ------------------------------------------------------------------ #
def warm_db_sweep():
    variants = _sweep_variants()
    results = _shared_db_sweep(variants)
    cold, warm = results[0], results[-1]
    base_cold = packet_baseline(variants[0])
    base_warm = packet_baseline(variants[-1])
    warm_hits = sum(r.kernel_report["run_db_hits"] for r in results[1:])
    return [_row("multi_experiment/warm_db_sweep", warm.wall_time, {
        "cold_speedup": round(base_cold.events_processed
                              / max(cold.events_processed, 1), 2),
        "warm_speedup": round(base_warm.events_processed
                              / max(warm.events_processed, 1), 2),
        "warm_fct_err": round(float(warm.fct_errors_vs(base_warm).mean()), 5),
        "warm_hits": warm_hits,
        "db_entries": warm.kernel_report["db_entries"],
    })]


# ------------------------------------------------------------------ #
# §6.1 made durable: a campaign's *cold parallel* sweep (2 worker
# processes, insert deltas merged back, every run committed as it
# finishes) leaves a result store + SimDB on disk; the "next session"
# re-opens the campaign, serves the completed runs as cache hits and runs
# only the held-out variant — warm, in a fresh worker process.  Reported
# against the in-memory warm baseline of warm_db_sweep: same event
# collapse, same FCTs.
# ------------------------------------------------------------------ #
def persist_warm_sweep():
    variants = _sweep_variants()
    # in-memory warm baseline: serial shared-DB sweep, last run is warm
    mem_warm = _shared_db_sweep(variants)[-1]
    with tempfile.TemporaryDirectory() as td:
        cdir = os.path.join(td, "campaign")
        with Campaign.open(cdir, name="persist_warm") as camp:
            cold = camp.sweep(variants[:-1], backend="wormhole", workers=2)
        db_bytes = os.path.getsize(os.path.join(cdir, "simdb.json"))
        # "next session": only the campaign directory carries over — the
        # full-sweep request resumes (N−1 cache hits) and the last variant
        # simulates in a fresh spawn worker fed by the campaign DB
        with Campaign.open(cdir) as camp:
            kinds = []
            camp.subscribe(lambda e: kinds.append(e.kind))
            disk_warm = camp.sweep(variants, backend="wormhole",
                                   workers=2)[-1]
    base_warm = packet_baseline(variants[-1])
    err_vs_mem = float(disk_warm.fct_errors_vs(mem_warm).mean())
    return [_row("multi_experiment/persist_warm_sweep", disk_warm.wall_time, {
        "cold_events_min": min(r.events_processed for r in cold),
        "warm_events": disk_warm.events_processed,
        "mem_warm_events": mem_warm.events_processed,
        "resume_cache_hits": kinds.count("cache_hit"),
        "warm_hits": disk_warm.kernel_report["run_db_hits"],
        "warm_fct_err": round(float(disk_warm.fct_errors_vs(base_warm).mean()), 5),
        "fct_err_vs_mem_warm": round(err_vs_mem, 6),
        "db_file_bytes": db_bytes,
    })]


# ------------------------------------------------------------------ #
# Beyond-paper: speedup vs flow-size scale (extrapolation toward the
# paper's GB-flow regime; paper flows are ~256x our 1/256 default)
# ------------------------------------------------------------------ #
def scale_trend():
    rows = []
    for scale, label in ((1 / 512, "1/512"), (1 / 256, "1/256"),
                         (1 / 128, "1/128")):
        base, wh = run_pair(workload(64, cca="hpcc", scale=scale))
        s = summarize(base, wh)
        rows.append(_row(f"scale_trend/{label}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "skip_ratio": round(s["skip_ratio"], 4),
            "fct_err_mean": round(s["fct_err_mean"], 5),
        }))
    return rows


# paper-faithful detector (plain Eq.6, fixed l and theta) vs hardened
def faithful_vs_hardened():
    scn = workload(64, cca="hpcc", scale=1 / 256)
    rows = []
    for label, cfg in (
        ("paper_faithful", WormholeConfig(confirm=False, theta_auto=False,
                                          window_auto=False, window=16)),
        ("hardened_default", WormholeConfig()),
    ):
        base, wh = run_pair(scn, wcfg=cfg)
        s = summarize(base, wh)
        rows.append(_row(f"detector/{label}", wh.wall_time, {
            "event_speedup": round(s["event_speedup"], 2),
            "fct_err_mean": round(s["fct_err_mean"], 5),
            "fct_err_p99": round(s["fct_err_p99"], 5),
        }))
    return rows


# straggler handling at the simulation layer: a slow rank shifts phase
# launches; Wormhole absorbs them as real-time interrupts (skip-backs)
def straggler_sim():
    scn = workload(64, cca="hpcc", scale=1 / 256, straggler=(0, 3.0),
                   name="gpt64-straggler")
    base, wh = run_pair(scn)
    s = summarize(base, wh)
    return [_row("straggler/rank0_3x", wh.wall_time, {
        "event_speedup": round(s["event_speedup"], 2),
        "fct_err_mean": round(s["fct_err_mean"], 5),
        "iter_err": round(s["iter_err"], 5),
        "skip_backs": s["skip_backs"],
    })]


# ------------------------------------------------------------------ #
# §6.1 intra-run parallelism: the partition-sharded event loop's parallel
# fan-out.  A multi-partition scenario (disjoint intra-leaf incast groups
# = independent partitions by Definition 1) runs on the sharded loop with
# intra_workers in {1, 2, 3}; FCTs must be identical throughout and the
# fan-out's wall-clock speedup over the single-executor sharded loop is
# the repo's intra-run speedup trajectory (BENCH_partition_parallel.json).
# ------------------------------------------------------------------ #
def _partition_parallel_scenario(groups: int = 6, per: int = 8,
                                 size: float = 2e7) -> Scenario:
    """`groups` leaf-local incast partitions that never share a port: all
    flows of group g live under leaf g, so partitions stay disjoint and the
    lanes are genuinely independent.  The explicit sample_interval fattens
    the windows between sampling barriers (the knob that trades detector
    latency for fan-out granularity)."""
    flows, fid = [], 0
    for g in range(groups):
        base = g * 8
        sink = base + 7
        for i in range(per):
            flows.append(FlowSpec(fid, base + (i % 7), sink, size, 0.0,
                                  "dctcp", tag=f"leaf{g}"))
            fid += 1
    return Scenario("partition-parallel",
                    TopologySpec("clos", {"n_hosts": groups * 8,
                                          "leaf_down": 8, "n_spines": 2}),
                    flows=flows, sim={"sample_interval": 1e-3})


def _host_parallel_ceiling() -> float:
    """Measured 2-process compute ceiling of this host (shared/throttled
    boxes often deliver well under 2x for two busy processes) — recorded in
    the artifact so the sharded-loop speedup can be read against what the
    hardware allows."""
    import multiprocessing
    import time as _time

    t0 = _time.perf_counter()
    _bench_burn(12_000_000)
    solo = _time.perf_counter() - t0
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(2) as pool:
        t0 = _time.perf_counter()
        pool.map(_bench_burn, [12_000_000, 12_000_000])
        wall = _time.perf_counter() - t0
    return 2 * solo / wall


def _bench_burn(n: int) -> int:
    x = 0
    for i in range(n):
        x += i * i
    return x


def partition_parallel(repeats: int = 3):
    scn = _partition_parallel_scenario(size=1.5e7)
    warmup = _partition_parallel_scenario(groups=2, per=2, size=1e6)
    t0 = time.perf_counter()
    serial = run(scn, backend="packet")
    wall_single_heap = time.perf_counter() - t0
    walls = {}
    results = {}
    for iw in (1, 2, 3, 4):
        if iw > 1:
            # cold spawn-pool startup (worker interpreter + numpy import)
            # is a per-process one-off, not part of the engine's speedup —
            # warm the shared pool of this size before starting the clock
            run(warmup, backend="packet", parallel="partitions",
                intra_workers=iw)
        # best-of-N: the ratio is what matters and co-tenant noise is
        # additive, so min-wall per config is the stable estimator
        walls[iw] = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            results[iw] = run(scn, backend="packet", parallel="partitions",
                              intra_workers=iw)
            walls[iw] = min(walls[iw], time.perf_counter() - t0)
    identical = all(r.fcts == serial.fcts and
                    r.events_processed == serial.events_processed
                    for r in results.values())
    best_iw = min((2, 3, 4), key=lambda iw: walls[iw])
    payload = {
        "scenario": scn.name,
        "partitions": 6,
        "events": serial.events_processed,
        "host_two_proc_ceiling": round(_host_parallel_ceiling(), 3),
        "wall_single_heap_serial": round(wall_single_heap, 3),
        "wall_sharded": {str(iw): round(w, 3) for iw, w in walls.items()},
        "fcts_identical_to_serial": identical,
        "best_intra_workers": best_iw,
        # headline: parallel fan-out vs the same sharded engine single-
        # executor — the isolated intra-run parallelism win
        "speedup": round(walls[1] / walls[best_iw], 3),
        "speedup_vs_single_heap": round(wall_single_heap / walls[best_iw], 3),
        "shard_stats": results[best_iw].extras["shard"],
    }
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_partition_parallel.json").write_text(
        json.dumps(payload, indent=1, default=str))
    return [_row("partition_parallel/sharded_serial", walls[1],
                 {"events": serial.events_processed,
                  "fcts_identical": identical}),
            _row(f"partition_parallel/intra_workers={best_iw}",
                 walls[best_iw],
                 {"speedup_vs_sharded_serial": payload["speedup"],
                  "speedup_vs_single_heap":
                      payload["speedup_vs_single_heap"],
                  "windows": results[best_iw].extras["shard"]["windows"],
                  "dispatched_events":
                      results[best_iw].extras["shard"]["dispatched_events"]})]


# ------------------------------------------------------------------ #
# Hybrid backend: accuracy/speed tradeoff of the adaptive packet/flow
# granularity switch.  For each scenario (quickstart incast, the 64-GPU
# GPT preset, the MoE/EP preset — the paper's hardest workload), every
# fidelity level runs against the packet oracle: events per granularity
# and FCT error vs fidelity -> artifacts/BENCH_hybrid.json.
# ------------------------------------------------------------------ #
def hybrid_tradeoff():
    scenarios = [
        ("quickstart", quickstart_scenario()),
        ("gpt64", workload(64, cca="hpcc", scale=SCALE)),
        ("moe64", workload(64, cca="hpcc", scale=SCALE, moe=True)),
    ]
    rows, payload = [], {}
    for label, scn in scenarios:
        base = packet_baseline(scn)
        per_fid = {}
        for fidelity in ("packet", "auto", "flow"):
            r = run(scn, backend="hybrid", fidelity=fidelity)
            g = r.extras["granularity"]
            err = float(r.fct_errors_vs(base).mean())
            per_fid[fidelity] = {
                "events_processed": r.events_processed,
                "packet_lane_events": g["packet_lane_events"],
                "flow_lane_events": g["flow_lane_events"],
                "demotions": g["demotions"], "promotions": g["promotions"],
                "resolves": g["resolves"],
                "fct_err_mean": round(err, 5),
                "wall": round(r.wall_time, 3),
            }
            rows.append(_row(f"hybrid_tradeoff/{label}/{fidelity}",
                             r.wall_time, {
                "packet_lane_events": g["packet_lane_events"],
                "packet_event_cut": round(
                    base.events_processed / max(g["packet_lane_events"], 1), 2),
                "fct_err_mean": round(err, 5),
            }))
        payload[label] = {"base_events": base.events_processed,
                          "base_wall": round(base.wall_time, 3),
                          "fidelity": per_fid}
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_hybrid.json").write_text(json.dumps(payload, indent=1))
    return rows


# ------------------------------------------------------------------ #
# Beyond-paper (m4, PAPERS.md): the learned engine's accuracy/cost point
# between `analytic` and `flow`.  Reuses benchmarks.learned_bench — a
# wormhole-ground-truth campaign, a fixed-seed fit, held-out FCT error for
# learned/analytic/fluid on the same scenarios, and the batched serving
# rate -> artifacts/BENCH_learned.json.
# ------------------------------------------------------------------ #
def learned_tradeoff():
    from benchmarks.learned_bench import bench
    payload = bench()
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_learned.json").write_text(json.dumps(payload, indent=1))
    rows = [_row("learned/fit", payload["fit"]["wall"], {
        "records": payload["dataset"]["records"],
        "heldout_records": payload["dataset"]["heldout_records"],
        "heldout_fct_err": payload["heldout_mean_fct_error"],
    })]
    for label, c in payload["heldout_comparison"].items():
        rows.append(_row(f"learned/heldout_vs_{label}",
                         c["wall_per_scenario"], {
                             "fct_err_mean": c["fct_err_mean"],
                             "fct_err_p99": c["fct_err_p99"],
                         }))
    rows.append(_row("learned/serving",
                     payload["serving"]["batch_wall"]
                     / payload["serving"]["batch_queries"], {
                         "queries_per_sec":
                             payload["serving"]["queries_per_sec"],
                         "speedup_vs_wormhole":
                             payload["serving"]["speedup_vs_wormhole"],
                     }))
    return rows


ALL = [fig3_patterns_steady, fig8a_speed_vs_scale, fig8b_10b_cca,
       fig9_partitions_db, fig10a_breakdown, fig11_accuracy, fig12_rtt_nrmse,
       fig13_sensitivity, fig14_topology, warm_db_sweep, persist_warm_sweep,
       scale_trend, faithful_vs_hardened, straggler_sim, partition_parallel,
       hybrid_tradeoff, learned_tradeoff]
