"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — numbers
are correctness-path timings; TPU timings come from real hardware) and the
vectorized fluid engine vs the per-packet oracle."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def kernels():
    from repro.kernels.cca_step.ops import cca_step
    from repro.kernels.cca_step.ref import cca_step_ref
    from repro.kernels.steady_scan.ops import steady_scan
    from repro.kernels.steady_scan.ref import steady_scan_ref
    rows = []
    rng = np.random.default_rng(0)
    F, L = 1024, 256
    M = jnp.asarray((rng.random((F, L)) < 0.1).astype(np.float32))
    args = [jnp.asarray(rng.uniform(1e8, 1e10, F), jnp.float32) for _ in range(2)]
    args += [jnp.asarray(rng.uniform(0, 1, F), jnp.float32),
             jnp.zeros(F, jnp.float32),
             jnp.asarray(rng.uniform(1e6, 1e7, F), jnp.float32),
             jnp.full((F,), 12.5e9, jnp.float32),
             jnp.full((F,), 1e-5, jnp.float32), M,
             jnp.zeros(L, jnp.float32), jnp.full((L,), 12.5e9, jnp.float32)]
    t_k = _time(lambda *a: cca_step(*a, dt=1e-5), *args)
    t_r = _time(lambda *a: jax.jit(lambda *x: cca_step_ref(*x, dt=1e-5))(*a), *args)
    rows.append(("kernel/cca_step_interp", t_k * 1e6,
                 {"ref_us": round(t_r * 1e6, 1), "flows": F, "links": L}))

    hist = jnp.asarray(rng.uniform(1e8, 1e10, (4096, 64)), jnp.float32)
    t_k = _time(lambda h: steady_scan(h, 64), hist)
    t_r = _time(jax.jit(lambda h: steady_scan_ref(h, 64)), hist)
    rows.append(("kernel/steady_scan_interp", t_k * 1e6,
                 {"ref_us": round(t_r * 1e6, 1), "flows": 4096}))
    return rows


def fluid_vs_oracle():
    from repro.net.fluid_jax import FluidScenario, fluid_run
    from repro.net.packet_sim import PacketSim
    from repro.net.flows import FlowSpec
    from repro.net.topology import leaf_spine_clos
    topo = leaf_spine_clos(32, leaf_down=8, n_spines=4)
    flows = [(i, i, 24 + i % 4, 4e6) for i in range(16)]
    t0 = time.perf_counter()
    sim = PacketSim(topo)
    for fid, s, d, sz in flows:
        sim.add_flow(FlowSpec(fid, s, d, sz, 0.0, "dctcp"))
    sim.run()
    t_oracle = time.perf_counter() - t0
    scn = FluidScenario.from_flows(topo, flows)
    args = (jnp.asarray(scn.incidence), jnp.asarray(scn.line_rate),
            jnp.asarray(scn.base_rtt), jnp.asarray(scn.size),
            jnp.asarray(scn.link_bw))
    t_fluid = _time(lambda *a: fluid_run(*a, 1e-5, 200), *args)
    return [("fluid/engine_vs_oracle", t_fluid * 1e6,
             {"oracle_s": round(t_oracle, 2),
              "fluid_speedup": round(t_oracle / t_fluid, 1),
              "oracle_events": sim.events_processed})]


def vmapped_sweep():
    from repro.net.fluid_jax import FluidScenario, sweep
    from repro.net.topology import leaf_spine_clos
    topo = leaf_spine_clos(32, leaf_down=8, n_spines=4)
    scns = [FluidScenario.from_flows(
        topo, [(i, i, 24 + (i + j) % 4, 4e6) for i in range(8)])
        for j in range(16)]
    t = _time(lambda: sweep(scns, dt=1e-5, steps=100))
    return [("fluid/vmap_16_experiments", t * 1e6,
             {"per_experiment_us": round(t * 1e6 / 16, 1)})]


ALL = [kernels, fluid_vs_oracle, vmapped_sweep]
