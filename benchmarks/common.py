"""Shared benchmark machinery: scaled Table-1 workloads, baseline/Wormhole
run pairs with in-process caching (benches share oracle baselines)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.packet_sim import PacketSim
from repro.workload import presets
from repro.workload.driver import WorkloadDriver
from repro.workload.parallelism import ParallelismConfig
from repro.workload.traffic import TrafficModelSpec, build_training_program

_CACHE: dict = {}


def gpt_spec(n_gpus: int) -> TrafficModelSpec:
    if n_gpus in presets.GPT:
        return presets.GPT[n_gpus].spec
    return presets.GPT[64].spec


def workload(n_gpus: int, cca: str = "hpcc", scale: float = 1 / 256,
             moe: bool = False):
    """Scaled Table-1 workload: TP8 fixed, PP2, DP grows with cluster size."""
    ep_over_dp = 0
    if moe and n_gpus in presets.MOE:
        wl = presets.MOE[n_gpus]
        spec, par = wl.spec, wl.par
        ep_over_dp = min(presets.MOE_EP_DOMAIN, par.dp)
    elif n_gpus in presets.GPT and not moe:
        wl = presets.GPT[n_gpus]
        spec, par = wl.spec, wl.par
    else:
        spec = gpt_spec(n_gpus)
        dp = max(1, n_gpus // 16)
        par = ParallelismConfig(tp=8, dp=dp, pp=2)
    topo = presets.topology_for(max(n_gpus, 16))
    phases = build_training_program(spec, par, cca=cca, scale=scale,
                                    ep_over_dp=ep_over_dp)
    return topo, phases


def run_one(topo, phases, kernel=None, record_rtt=(), until=float("inf")):
    sim = PacketSim(topo, kernel=kernel)
    sim.record_rtt_fids = set(record_rtt)
    drv = WorkloadDriver(sim, phases)
    t0 = time.perf_counter()
    sim.run(until=until)
    wall = time.perf_counter() - t0
    assert drv.finished, "program did not finish"
    return {"sim": sim, "driver": drv, "wall": wall,
            "events": sim.events_processed,
            "iter_time": drv.iteration_time,
            "fcts": {fid: r.fct for fid, r in sim.results.items()}}


def run_pair(key: str, topo, phases, wcfg: WormholeConfig | None = None,
             record_rtt=()):
    """(baseline, wormhole, kernel) with the baseline cached per key."""
    base_key = ("base", key, tuple(record_rtt))
    if base_key not in _CACHE:
        _CACHE[base_key] = run_one(topo, phases, record_rtt=record_rtt)
    base = _CACHE[base_key]
    k = WormholeKernel(wcfg or WormholeConfig())
    wh = run_one(topo, phases, kernel=k, record_rtt=record_rtt)
    return base, wh, k


def fct_errors(base, wh) -> np.ndarray:
    return np.array([abs(wh["fcts"][fid] - fct) / fct
                     for fid, fct in base["fcts"].items() if fct > 0])


def summarize(base, wh, k) -> dict:
    errs = fct_errors(base, wh)
    rep = k.report()
    skipped = rep["est_events_skipped"]
    return {
        "event_speedup": base["events"] / max(wh["events"], 1),
        "wall_speedup": base["wall"] / max(wh["wall"], 1e-9),
        "fct_err_mean": float(errs.mean()),
        "fct_err_p99": float(np.quantile(errs, 0.99)),
        "iter_err": abs(wh["iter_time"] - base["iter_time"]) / base["iter_time"],
        "skip_ratio": skipped / max(skipped + wh["events"], 1),
        "memo_hits": rep["db_hits"], "memo_lookups": rep["db_lookups"],
        "db_bytes": rep["db_bytes"], "db_entries": rep["db_entries"],
        "parks": rep["parks"], "replays": rep["replays"],
        "skip_backs": rep["skip_backs"],
        "partitions_seen": k._gen,
        "base_wall": base["wall"], "wh_wall": wh["wall"],
        "base_events": base["events"],
    }
