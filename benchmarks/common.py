"""Shared benchmark machinery on top of `repro.api`: scaled Table-1
workload scenarios, baseline/Wormhole run pairs with in-process caching
(benches share oracle baselines)."""
from __future__ import annotations

from repro.api import RunResult, run, summarize_pair, training_scenario
from repro.api.scenario import Scenario
from repro.workload import presets
from repro.workload.traffic import TrafficModelSpec

_CACHE: dict = {}


def quickstart_scenario() -> Scenario:
    """THE quickstart scenario — paper_figures' hybrid_tradeoff figure and
    the CI regression baseline both claim to measure it, so this delegates
    to the one real definition instead of keeping a copy that could drift.
    Lazy import: benchmarks run as ``python -m benchmarks...`` from the
    repo root, which puts the ``examples`` package on sys.path."""
    from examples.quickstart import make_scenario
    return make_scenario()


def gpt_spec(n_gpus: int) -> TrafficModelSpec:
    return presets.resolve("gpt", n_gpus)[0]


def workload(n_gpus: int, cca: str = "hpcc", scale: float = 1 / 256,
             moe: bool = False, **kw) -> Scenario:
    """Scaled Table-1 workload scenario: TP8 fixed, PP2, DP grows with
    cluster size for off-table GPU counts."""
    return training_scenario(n_gpus=n_gpus, moe=moe, cca=cca, scale=scale, **kw)


def packet_baseline(scn: Scenario, record_rtt=()) -> RunResult:
    """The per-scenario packet-oracle run, cached so benches share it."""
    base_key = ("base", scn.name, tuple(record_rtt))
    if base_key not in _CACHE:
        _CACHE[base_key] = run(scn, backend="packet", record_rtt=record_rtt)
    return _CACHE[base_key]


def run_pair(scn: Scenario, wcfg=None, record_rtt=()) -> tuple[RunResult, RunResult]:
    """(baseline, wormhole) with the packet baseline cached per scenario."""
    base = packet_baseline(scn, record_rtt)
    wh = run(scn, backend="wormhole", config=wcfg, record_rtt=record_rtt)
    return base, wh


def summarize(base: RunResult, wh: RunResult) -> dict:
    """The unified speedup/accuracy row, merged with the kernel report."""
    out = summarize_pair(base, wh)
    rep = wh.kernel_report or {}
    skipped = rep.get("est_events_skipped", 0.0)
    out.update({
        "skip_ratio": skipped / max(skipped + wh.events_processed, 1),
        "memo_hits": rep.get("db_hits", 0),
        "memo_lookups": rep.get("db_lookups", 0),
        "db_bytes": rep.get("db_bytes", 0),
        "db_entries": rep.get("db_entries", 0),
        "parks": rep.get("parks", 0), "replays": rep.get("replays", 0),
        "skip_backs": rep.get("skip_backs", 0),
        "partitions_seen": rep.get("partitions", 0),
        "base_wall": base.wall_time, "wh_wall": wh.wall_time,
        "base_events": base.events_processed,
    })
    return out
