"""Emit the roofline table from the dry-run artifacts (EXPERIMENTS.md
§Roofline reads this)."""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def rows():
    out = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            out.append((f"roofline/{p.stem}", 0.0, {"status": r.get("status"),
                                                    "error": r.get("error", "")[:80]}))
            continue
        out.append((f"roofline/{p.stem}", r["compile_s"] * 1e6, {
            "dominant": r["dominant"],
            "roofline_fraction": round(r["roofline_fraction"], 3),
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "peak_gb": round(r["est_peak_gb_per_device"], 2),
            "fits": r["fits_16gb_hbm"],
        }))
    return out


ALL = [rows]
