"""Learned-engine benchmark: campaign → fit → held-out accuracy + serving
throughput (the m4-style claim, PAPERS.md).

Builds a ≥64-record campaign of wormhole ground truth over a 3-axis wave
family (flow size × CCA × fabric width), fits the learned engine on it,
and measures the two numbers the engine exists for:

* **held-out mean FCT error** — on whole scenarios the fit never saw
  (deterministic ``run_key``-hash split), against the stored packet-level
  ground truth; the same scenarios also run on ``analytic`` and ``fluid``,
  so the artifact pins the accuracy/cost point *between* those two.
* **batched serving throughput** — scenario queries/sec through one
  ``run_batch`` call over a 1024-scenario what-if sweep.

    PYTHONPATH=src python -m benchmarks.learned_bench

writes ``artifacts/BENCH_learned.json``; ``paper_figures`` reuses
:func:`bench` for its learned-tradeoff rows.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.api import Campaign, RunResult, Scenario, get_engine, run
from repro.net.flows import FlowSpec

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def wave_scenario(size_scale: float = 1.0, cca: str = "dctcp",
                  n_hosts: int = 16, name: str = "waves",
                  base_size: float = 8e5) -> Scenario:
    """Two staggered 4-flow waves crossing a clos leaf boundary — the
    repo's canonical small flow scenario, parameterized on the three axes
    the learned model must generalize over."""
    flows, fid = [], 0
    for wave, start in enumerate((0.0, 0.02)):
        for i in range(4):
            flows.append(FlowSpec(fid=fid, src=i, dst=8 + i + wave,
                                  size=base_size * size_scale, start=start,
                                  cca=cca, tag=f"w{wave}"))
            fid += 1
    return Scenario.from_dict({
        "name": name,
        "topology": {"kind": "clos", "params": {"n_hosts": n_hosts}},
        "flows": [f.__dict__ for f in flows], "kernel": {}, "sim": {}})


def wave_family(n_sizes: int = 16, ccas=("dctcp", "hpcc"), hosts=(16, 32),
                base_size: float = 8e5) -> list[Scenario]:
    """The campaign grid: ``n_sizes`` flow-size scales × CCAs × fabric
    widths (default 16 × 2 × 2 = 64 distinct scenarios)."""
    return [wave_scenario(float(s), cca=cca, n_hosts=h, base_size=base_size,
                          name=f"waves-{cca}-h{h}-s{i}")
            for cca in ccas for h in hosts
            for i, s in enumerate(np.linspace(0.5, 2.0, n_sizes))]


def bench(n_sizes: int = 16, n_queries: int = 1024, seed: int = 0,
          steps: int = 1200) -> dict:
    """The full loop; returns the BENCH_learned payload."""
    from repro.learned import fit, heldout_fct_error

    family = wave_family(n_sizes=n_sizes)
    with Campaign.in_memory(name="learned-bench") as camp:
        t0 = time.perf_counter()
        camp.sweep(family, backend="wormhole")
        truth_wall = time.perf_counter() - t0

        ds = camp.export_dataset()
        t0 = time.perf_counter()
        params = fit(ds, seed=seed, steps=steps)
        fit_wall = time.perf_counter() - t0
        heldout_err = heldout_fct_error(params, ds)

        # --- held-out scenarios: learned vs the analytic/fluid bracket --- #
        held_keys = {k for k, h in zip(ds.record_key, ds.heldout) if h}
        held = [(Scenario.from_dict(rec["scenario"]),
                 RunResult.from_dict(rec["result"]))
                for rec in camp.records() if rec["key"] in held_keys]
    scns = [s for s, _ in held]
    engine = get_engine("learned")
    comparison = {}
    for label, results in (
        ("learned", engine.run_batch(scns, params=params)),
        ("analytic", [run(s, backend="analytic") for s in scns]),
        ("fluid", get_engine("fluid").run_batch(scns)),
    ):
        errs = np.concatenate([r.fct_errors_vs(t)
                               for r, (_, t) in zip(results, held)])
        comparison[label] = {
            "fct_err_mean": round(float(errs.mean()), 5),
            "fct_err_p99": round(float(np.quantile(errs, 0.99)), 5),
            "wall_per_scenario": float(
                np.mean([r.wall_time for r in results])),
        }

    # --- batched serving throughput over an in-range what-if sweep ------ #
    rng = np.random.default_rng(seed)
    queries = [wave_scenario(float(s), cca=("dctcp", "hpcc")[i % 2],
                             n_hosts=(16, 32)[(i // 2) % 2], name=f"q{i}")
               for i, s in enumerate(rng.uniform(0.55, 1.95, n_queries))]
    engine.run_batch(queries[:8], params=params)       # warm jit/caches
    t0 = time.perf_counter()
    out = engine.run_batch(queries, params=params)
    batch_wall = time.perf_counter() - t0
    qps = len(out) / batch_wall

    payload = {
        "campaign_records": len(family),
        "ground_truth_backend": "wormhole",
        "ground_truth_wall": round(truth_wall, 3),
        "dataset": {"flows": len(ds), "records": ds.n_records,
                    "heldout_records": ds.n_heldout_records,
                    "heldout_flows": int(ds.heldout.sum())},
        "fit": {"seed": seed, "wall": round(fit_wall, 3),
                "params_fingerprint": params.fingerprint,
                **params.meta["train"]},
        "heldout_mean_fct_error": round(float(heldout_err), 6),
        "heldout_error_under_10pct": bool(heldout_err < 0.10),
        "heldout_comparison": comparison,
        "serving": {
            "batch_queries": len(out),
            "batch_wall": round(batch_wall, 4),
            "queries_per_sec": round(qps, 1),
            "meets_1000_qps": bool(qps >= 1000),
            "wormhole_wall_per_run": round(truth_wall / len(family), 4),
            "speedup_vs_wormhole": round(
                (truth_wall / len(family)) / (batch_wall / len(out)), 1),
        },
    }
    return payload


def main() -> int:
    payload = bench()
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_learned.json").write_text(json.dumps(payload, indent=1))
    print(json.dumps(payload, indent=1))
    ok = (payload["heldout_error_under_10pct"]
          and payload["serving"]["meets_1000_qps"])
    print("acceptance:", "ok" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
