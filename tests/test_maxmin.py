"""The max-min water-filling solver stack (`repro.kernels.maxmin`) and the
struct-of-arrays substrate (`repro.net.soa`):

* the exact array solver must be **bit-identical** to the historical dict
  loop (`repro.net.flows.maxmin_rates_dict`) — duplicates-in-path quirk,
  tie-breaks, zero-bandwidth links and all;
* the jax ref and the Pallas kernel agree with each other exactly and with
  the exact solver to float32 accuracy on simple paths;
* `FlowTable.solve_rates` is the same function as `maxmin_rates` over the
  same fid order, and `LaneState.pop_run` drains in verbatim serial order.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.kernels.maxmin import (paths_to_arrays, reset_counters,
                                  solve_paths)
from repro.kernels.maxmin.ops import (SOLVER_COUNTERS, incidence_from_csr,
                                      maxmin_rates_arrays, maxmin_rates_jax)
from repro.net.flows import maxmin_rates, maxmin_rates_dict
from repro.net.soa import FlowTable, LaneState


def random_case(r, n_flows=None, n_links=None, simple=False,
                allow_zero_bw=True, allow_empty=True):
    """A random (paths, link_bw) pair.  ``simple=True`` keeps every path
    duplicate-free (the jax implementations' documented scope); otherwise
    repeated links exercise the dict solver's per-occurrence-decrement
    quirk."""
    L = n_links if n_links is not None else r.randint(1, 12)
    F = n_flows if n_flows is not None else r.randint(1, 16)
    paths = {}
    for i in range(F):
        fid = 100 + i
        if allow_empty and r.random() < 0.1:
            paths[fid] = []
        elif simple:
            k = r.randint(1, min(6, L))
            paths[fid] = r.sample(range(L), k)
        else:
            k = r.randint(1, 6)
            paths[fid] = [r.randint(0, L - 1) for _ in range(k)]
    bw = [r.uniform(1.0, 100.0) for _ in range(L)]
    if allow_zero_bw and r.random() < 0.25:
        bw[r.randint(0, L - 1)] = 0.0
    kind = r.random()
    if kind < 0.4:
        link_bw = np.asarray(bw, dtype=np.float64)
    elif kind < 0.7:
        link_bw = list(bw)
    else:
        link_bw = {i: v for i, v in enumerate(bw)}
    return paths, link_bw


# --------------------------------------------------------------------- #
# exact array solver vs the historical dict loop — bitwise
# --------------------------------------------------------------------- #
@settings(max_examples=100, deadline=None)
@given(st.randoms(use_true_random=False))
def test_array_solver_bit_identical_to_dict(r):
    paths, link_bw = random_case(r)
    got = solve_paths(paths, link_bw)
    want = maxmin_rates_dict(paths, link_bw)
    assert set(got) == set(want)
    for fid in want:
        # bitwise, not approx: the packet fidelity guarantee rests on this
        assert got[fid] == want[fid], (fid, paths, link_bw)


def test_net_flows_maxmin_rates_is_the_array_solver():
    paths = {7: [0, 1], 8: [1, 2], 9: [2], 10: []}
    bw = {0: 10.0, 1: 4.0, 2: 6.0}
    got = maxmin_rates(paths, bw)
    assert got == maxmin_rates_dict(paths, bw)
    assert got == solve_paths(paths, bw)


def test_duplicate_link_quirk_is_preserved():
    # a repeated link counts one user but its capacity is decremented per
    # occurrence — the dict solver's historical behaviour, kept bit-for-bit
    paths = {1: [0, 0], 2: [0]}
    got = solve_paths(paths, [12.0])
    assert got == maxmin_rates_dict(paths, [12.0])


def test_degenerate_cases_match_dict():
    for paths, bw in [
        ({}, [5.0]),                              # no flows
        ({1: []}, [5.0]),                         # only link-less flows
        ({1: [0]}, [0.0]),                        # zero-bandwidth link
        ({1: [0], 2: [0]}, [0.0]),                # shared zero-bw link
        ({1: [0]}, [7.5]),                        # single flow
        ({1: [0], 2: []}, [3.0]),                 # mixed
    ]:
        assert solve_paths(paths, bw) == maxmin_rates_dict(paths, bw)


def test_single_flow_gets_bottleneck():
    assert solve_paths({5: [0, 1, 2]}, [9.0, 3.0, 6.0]) == {5: 3.0}
    assert solve_paths({5: []}, [9.0]) == {5: 1e12}


def test_solver_counters_track_invocations():
    reset_counters()
    solve_paths({1: [0], 2: [0]}, [4.0])
    solve_paths({1: [0]}, [4.0])
    assert SOLVER_COUNTERS["invocations"] == 2
    assert SOLVER_COUNTERS["max_flows"] == 2
    held = reset_counters()
    assert held["invocations"] == 2
    assert SOLVER_COUNTERS["invocations"] == 0


# --------------------------------------------------------------------- #
# jax ref / Pallas kernel parity (simple paths: the documented scope)
# --------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(st.randoms(use_true_random=False))
def test_jax_ref_tracks_exact_solver(r):
    paths, link_bw = random_case(r, simple=True, allow_zero_bw=False)
    fids, links, off = paths_to_arrays(paths)
    exact = maxmin_rates_arrays(links, off, link_bw)
    ref = maxmin_rates_jax(links, off, link_bw, impl="ref")
    np.testing.assert_allclose(ref, exact, rtol=1e-5,
                               err_msg=repr((paths, link_bw)))


@settings(max_examples=15, deadline=None)
@given(st.randoms(use_true_random=False))
def test_kernel_matches_ref_exactly(r):
    paths, link_bw = random_case(r, simple=True)
    fids, links, off = paths_to_arrays(paths)
    ref = maxmin_rates_jax(links, off, link_bw, impl="ref")
    ker = maxmin_rates_jax(links, off, link_bw, impl="kernel")
    assert np.array_equal(ref, ker), repr((paths, link_bw))


def test_kernel_zero_bandwidth_link():
    paths = {1: [0, 1], 2: [1]}
    fids, links, off = paths_to_arrays(paths)
    ref = maxmin_rates_jax(links, off, [5.0, 0.0], impl="ref")
    ker = maxmin_rates_jax(links, off, [5.0, 0.0], impl="kernel")
    assert np.array_equal(ref, ker)
    np.testing.assert_allclose(ref, [0.0, 0.0], atol=1e-9)


def test_kernel_single_flow_and_no_links():
    fids, links, off = paths_to_arrays({1: [0]})
    assert maxmin_rates_jax(links, off, [7.0], impl="kernel")[0] == \
        pytest.approx(7.0)
    fids, links, off = paths_to_arrays({1: [], 2: []})
    out = maxmin_rates_jax(links, off, [7.0], impl="kernel")
    np.testing.assert_allclose(out, [1e12, 1e12])


def test_kernel_parity_at_10k_flows():
    """The acceptance bar: kernel↔ref ≤ 1e-6 relative at 10k flows."""
    rng = np.random.default_rng(11)
    F, L = 10_000, 128
    # 3 *distinct* links per flow (simple paths — the jax scope; a single
    # duplicate-link flow shifts every rate through the global coupling)
    links = rng.random((F, L)).argpartition(3, axis=1)[:, :3] \
               .astype(np.int64).ravel()
    off = np.arange(0, 3 * (F + 1), 3, dtype=np.int64)
    bw = rng.uniform(1e9, 1e10, L)
    ref = maxmin_rates_jax(links, off, bw, impl="ref")
    ker = maxmin_rates_jax(links, off, bw, impl="kernel")
    np.testing.assert_allclose(ker, ref, rtol=1e-6)
    # and the exact solver agrees to float32 accuracy on the same case
    exact = maxmin_rates_arrays(links, off, bw)
    np.testing.assert_allclose(ref, exact, rtol=1e-4)


def test_incidence_from_csr_layout():
    fids, links, off = paths_to_arrays({1: [4, 2], 2: [2, 9]})
    inc, cap = incidence_from_csr(links, off, {4: 1.0, 2: 2.0, 9: 3.0})
    # first-appearance link order: 4, 2, 9
    np.testing.assert_array_equal(cap, np.asarray([1.0, 2.0, 3.0], np.float32))
    np.testing.assert_array_equal(
        inc, np.asarray([[1, 1, 0], [0, 1, 1]], np.float32))


# --------------------------------------------------------------------- #
# SoA substrate
# --------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.randoms(use_true_random=False))
def test_flow_table_solve_matches_dict_path(r):
    paths, link_bw = random_case(r)
    table = FlowTable()
    for fid, p in paths.items():
        table.add(fid, p)
    assert len(table) == len(paths)
    got = table.solve_rates(list(paths), link_bw)
    assert got == maxmin_rates_dict(paths, link_bw)
    # subset solves preserve iteration order (the tie-break contract)
    sub = [fid for fid in paths if r.random() < 0.5]
    assert table.solve_rates(sub, link_bw) == \
        maxmin_rates_dict({fid: paths[fid] for fid in sub}, link_bw)


def test_flow_table_verify_against():
    class Dummy:
        def __init__(self, path):
            self.path = path

    table = FlowTable()
    table.add(1, [0, 1])
    table.verify_against({1: Dummy([0, 1])})
    with pytest.raises(AssertionError, match="diverged"):
        table.verify_against({1: Dummy([0, 2])})


@settings(max_examples=50, deadline=None)
@given(st.randoms(use_true_random=False))
def test_lane_pop_run_preserves_serial_order(r):
    """Draining via pop_run yields exactly the serial heappop sequence,
    and every run is a maximal same-timestamp prefix."""
    import heapq

    lane = LaneState(0)
    times = [r.choice([0.0, 1.0, 1.0, 2.0, r.uniform(0, 3)])
             for _ in range(r.randint(1, 40))]
    for i, t in enumerate(times):
        lane.push(t, i % 4, (i,))
    serial = sorted(lane.heap)
    shadow = list(lane.heap)
    heapq.heapify(shadow)

    drained = []
    while lane.heap:
        run = lane.pop_run()
        assert len({ev[0] for ev in run}) == 1          # same-timestamp run
        assert run == sorted(run)                        # (t, seq) order
        # maximal: nothing at this timestamp is left behind
        assert not (lane.heap and lane.heap[0][0] == run[0][0])
        drained.extend(run)
    assert drained == serial


@pytest.mark.slow
def test_gpt128_hybrid_bench_smoke():
    """CI-scale smoke at the paper's largest GPT row (128 GPUs, scaled):
    the hybrid run completes, every flow finishes, and the batched-drain
    instrumentation actually fires at this fan-out."""
    from repro.api import run, training_scenario

    scn = training_scenario(n_gpus=128, cca="hpcc", scale=1 / 4096)
    r = run(scn, backend="hybrid")
    assert r.fcts and all(v > 0 for v in r.fcts.values())
    sh = r.extras["shard"]
    assert sh["batched_drains"] > 0
    assert sh["max_batch_width"] >= 2


def test_lane_pop_run_respects_seq_watermark():
    lane = LaneState(3)
    for i in range(5):
        lane.push(1.0, 0, (i,))          # seqs 1..5 at t=1.0
    run = lane.pop_run(max_seq=3)
    assert [ev[1] for ev in run] == [1, 2, 3]
    assert len(lane.heap) == 2           # seqs 4, 5 rest in the lane
    run2 = lane.pop_run()
    assert [ev[1] for ev in run2] == [4, 5]
