"""Property tests for port-level network partitioning (Algorithm 1 + 2)."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.core.partition import PartitionIndex, network_partitioner


def brute_force(flow_ports):
    """Reference: transitive closure of the 'shares a port' relation."""
    fids = list(flow_ports)
    parent = {f: f for f in fids}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i, a in enumerate(fids):
        for b in fids[i + 1:]:
            if flow_ports[a] & flow_ports[b]:
                parent[find(a)] = find(b)
    groups = {}
    for f in fids:
        groups.setdefault(find(f), set()).add(f)
    return {frozenset(g) for g in groups.values()}


flow_ports_st = st.dictionaries(
    keys=st.integers(0, 40),
    values=st.frozensets(st.integers(0, 25), min_size=1, max_size=5),
    min_size=1, max_size=20,
)


@given(flow_ports_st)
@settings(max_examples=200, deadline=None)
def test_algorithm1_matches_transitive_closure(flow_ports):
    parts = network_partitioner(flow_ports)
    assert {frozenset(p) for p in parts} == brute_force(flow_ports)


@given(flow_ports_st, st.randoms(use_true_random=False))
@settings(max_examples=100, deadline=None)
def test_incremental_tracks_algorithm1_under_churn(flow_ports, rnd):
    """Add all flows in random order, then remove half in random order; the
    incremental index must match a fresh Algorithm 1 run at every step."""
    idx = PartitionIndex()
    fids = list(flow_ports)
    rnd.shuffle(fids)
    for fid in fids:
        idx.add_flow(fid, flow_ports[fid])
        idx.check_invariants()
    rnd.shuffle(fids)
    for fid in fids[: len(fids) // 2]:
        idx.remove_flow(fid)
        idx.check_invariants()


def test_merge_and_split():
    idx = PartitionIndex()
    idx.add_flow(1, frozenset({10, 11}))
    idx.add_flow(2, frozenset({20, 21}))
    assert len(idx.parts) == 2
    # flow 3 bridges both partitions -> merge
    pid, merged = idx.add_flow(3, frozenset({11, 20}))
    assert len(merged) == 2 and len(idx.parts) == 1
    # removing the bridge splits again
    _, splits = idx.remove_flow(3)
    assert len(splits) == 2
    idx.check_invariants()


def test_port_exclusivity_invariant():
    """No port may be owned by two partitions (Definition 1)."""
    idx = PartitionIndex()
    idx.add_flow(1, frozenset({1, 2}))
    idx.add_flow(2, frozenset({2, 3}))
    idx.add_flow(3, frozenset({7}))
    assert idx.flow_pid[1] == idx.flow_pid[2] != idx.flow_pid[3]
    idx.check_invariants()
