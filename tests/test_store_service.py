"""Shared campaign-store service: served RunStore + mergeable SimDB.

Acceptance (ISSUE 9): a sweep through the served store returns RunResults
bit-identical to the same sweep against a local RunStore (same run_keys,
same record JSON); a second host with empty local state gets warm wormhole
replays from the server; server loss mid-sweep degrades gracefully to
local commits with no lost or duplicated records on reconnect; and two
processes sweeping overlapping scenario sets commit exactly N records.

This file doubles as the multi-host worker harness: run directly
(``python tests/test_store_service.py URL LO HI``) it opens the served
campaign at URL and sweeps the overlap scenarios [LO, HI) with claims on.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import pytest

from repro.api import (Campaign, Engine, FlowSpec, RunResult, Scenario,
                       TopologySpec, compare, register_engine, run,
                       run_key, run_many)
from repro.api.engines import _REGISTRY
from repro.api.serve import RemoteBackend, StoreServer
from repro.api.store import (CLAIM_PREFIX, LocalDirBackend, MemoryBackend,
                             RunStore)
from repro.core.memo import SimDB

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def svc_scenario(scale: float = 1.0, name: str = "svc") -> Scenario:
    flows = [FlowSpec(i, i % 4, 12 + (i % 2), size=2e5 * scale,
                      start=0.0, cca="dctcp") for i in range(4)]
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}), flows=flows)


def waves_scenario(scale: float = 1.0, name: str = "svc-waves") -> Scenario:
    """Two identical flow waves — the repetition wormhole memoizes."""
    flows = []
    fid = 0
    for wave in (0.0, 0.02):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=4e6 * scale,
                                  start=wave, cca="dctcp"))
            fid += 1
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}), flows=flows)


def overlap_scenarios(lo: int, hi: int) -> list[Scenario]:
    """The two-host overlap sweep — must build identically in both worker
    processes and the asserting parent (content-addressed keys)."""
    return [svc_scenario(1.0 + 0.05 * i, name=f"ov{i}") for i in range(lo, hi)]


class SvcCountingEngine(Engine):
    """Deterministic engine with wall_time=0.0, so two runs of the same
    scenario produce byte-identical records — the bit-identity probe."""
    calls = 0

    def run(self, scenario, **opts):
        type(self).calls += 1
        return RunResult(backend=self.name, scenario=scenario.name,
                         fcts={f.fid: 1.0 + f.size * 1e-9
                               for f in scenario.flows},
                         flow_bytes={f.fid: f.size for f in scenario.flows},
                         tags={f.fid: f.tag for f in scenario.flows},
                         iteration_time=1.0, events_processed=7,
                         wall_time=0.0, extras={})


@pytest.fixture
def svc_engine():
    register_engine("svc-counting")(SvcCountingEngine)
    SvcCountingEngine.calls = 0
    yield SvcCountingEngine
    _REGISTRY.pop("svc-counting", None)


@pytest.fixture
def server(tmp_path):
    srv = StoreServer(tmp_path / "served").start()
    yield srv
    srv.shutdown()


def _fast(remote: RemoteBackend) -> RemoteBackend:
    remote.retries, remote.backoff, remote.timeout = 1, 0.01, 10.0
    return remote


# --------------------------------------------------------------------- #
# the StoreBackend protocol: one contract, three transports
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["memory", "localdir", "remote"])
def test_backend_protocol_roundtrip(kind, tmp_path):
    srv = None
    if kind == "memory":
        b = MemoryBackend()
    elif kind == "localdir":
        b = LocalDirBackend(tmp_path / "runs")
    else:
        srv = StoreServer(tmp_path / "served").start()
        b = _fast(RemoteBackend(srv.url))
    try:
        ka, kb = "a" * 40, "b" * 40
        rec = {"record_version": 1, "key": ka, "x": [1, 2, {"y": "z"}]}
        assert b.get("0" * 40) is None
        b.put(ka, rec)
        assert b.get(ka) == rec
        assert b.put_new(ka, rec) is False          # already exists
        assert b.put_new(kb, {"record_version": 1, "key": kb}) is True
        assert b.keys() == [ka, kb]
        assert sorted(r["key"] for r in b.records()) == [ka, kb]
        assert b.delete(kb) is True and b.delete(kb) is False
        assert b.keys() == [ka]
        age = b.age(ka)
        if kind == "remote":
            assert age is None                      # ages live server-side
        else:
            assert age is not None and age >= 0.0
        b.close()
    finally:
        if srv is not None:
            srv.shutdown()


# --------------------------------------------------------------------- #
# satellite: put on an existing key verifies content, reports dedup
# --------------------------------------------------------------------- #
def test_put_verifies_content_on_existing_key(tmp_path, svc_engine):
    for store in (RunStore(tmp_path / "runs"), RunStore(None)):
        scn = svc_scenario()
        r1 = SvcCountingEngine().run(scn)
        key = run_key(scn, "svc-counting", {})
        assert store.put(key, scn, "svc-counting", {}, r1) is False  # fresh
        # same content modulo wall-clock: a dedup hit, nothing rewritten
        r2 = dataclasses.replace(r1, wall_time=123.0)
        assert store.put(key, scn, "svc-counting", {}, r2) is True
        assert store.get(key)["result"]["wall_time"] == 0.0
        # conflicting content: warn (nondeterminism canary) and overwrite
        r3 = dataclasses.replace(r1, fcts={0: 9.9})
        with pytest.warns(RuntimeWarning, match="different content"):
            assert store.put(key, scn, "svc-counting", {}, r3) is False
        assert store.get(key)["result"]["fcts"] == {"0": 9.9}


# --------------------------------------------------------------------- #
# claims: atomic, advisory, stealable
# --------------------------------------------------------------------- #
def test_claims_acquire_release_steal(tmp_path):
    store = RunStore(tmp_path / "runs")
    key = "a1" * 20
    assert store.claim(key, "w1") is True
    assert store.claim(key, "w1") is True           # re-entrant for owner
    assert store.claim(key, "w2") is False
    assert store.claim_owner(key) == "w1"
    # claims are invisible to the run-record API
    assert store.keys() == [] and len(store) == 0
    assert list(store.records()) == []
    store.release(key, "w2")                        # not yours: no-op
    assert store.claim_owner(key) == "w1"
    store.release(key, "w1")
    assert store.claim_owner(key) is None
    # expiry: a dead worker's claim is steal-able after its TTL
    assert store.claim(key, "w2", ttl=0.05) is True
    time.sleep(0.1)
    assert store.claim_owner(key) is None
    assert store.claim(key, "w3") is True
    assert store.claim_owner(key) == "w3"


def test_gc_expires_old_records_and_stale_claims(tmp_path, svc_engine):
    store = RunStore(tmp_path / "runs")
    scns = [svc_scenario(1.0 + i, name=f"gc{i}") for i in range(2)]
    keys = [run_key(s, "svc-counting", {}) for s in scns]
    for s, k in zip(scns, keys):
        store.put(k, s, "svc-counting", {}, SvcCountingEngine().run(s))
    store.claim(keys[1], "w", ttl=0.01)
    time.sleep(0.05)
    old = time.time() - 100
    os.utime(tmp_path / "runs" / f"{keys[0]}.json", (old, old))
    assert store.gc(None) == []                     # no TTL: records kept
    removed = store.gc(ttl=50)
    assert removed == [keys[0]]
    assert store.keys() == [keys[1]]
    # the stale claim went with the sweep
    assert not list((tmp_path / "runs").glob(f"{CLAIM_PREFIX}*"))


def test_remote_gc_runs_on_the_server(tmp_path, server, svc_engine):
    camp = Campaign.open(server.url)
    _fast(camp.remote)
    h_old = camp.submit(svc_scenario(1.0, name="old"), backend="svc-counting")
    h_new = camp.submit(svc_scenario(2.0, name="new"), backend="svc-counting")
    old = time.time() - 100
    os.utime(tmp_path / "served" / "runs" / f"{h_old.key}.json", (old, old))
    assert camp.gc(ttl=50) == [h_old.key]
    assert camp.store.peek(h_old.key) is None
    assert camp.store.peek(h_new.key) is not None
    camp.close()


# --------------------------------------------------------------------- #
# acceptance: served sweep is bit-identical to a local sweep
# --------------------------------------------------------------------- #
def test_served_sweep_bit_identical_to_local(tmp_path, server, svc_engine):
    scns = [svc_scenario(1.0 + 0.1 * i, name=f"bi{i}") for i in range(3)]
    local = Campaign.open(tmp_path / "localcamp")
    res_local = local.sweep(scns, backend="svc-counting")
    local_recs = {k: local.store.get(k) for k in local.store.keys()}
    local.close()

    remote = Campaign.open(server.url)
    _fast(remote.remote)
    res_remote = remote.sweep(scns, backend="svc-counting")
    # same results, same run_keys, same record JSON — byte for byte
    assert [r.to_dict() for r in res_remote] == \
        [r.to_dict() for r in res_local]
    remote_recs = {k: remote.store.get(k) for k in remote.store.keys()}
    assert remote_recs == local_recs
    remote.close()
    # and the wire really was JSON: the server's files parse to the same
    for k, rec in local_recs.items():
        on_disk = json.loads(
            (tmp_path / "served" / "runs" / f"{k}.json").read_text())
        assert on_disk == rec


def test_second_host_gets_warm_wormhole_replays(tmp_path, server):
    """Host A runs cold; host B (fresh process, empty local state) sees
    A's record as a cache hit and fast-forwards a *new* variant off the
    served SimDB — events collapse to the warm-sweep level."""
    a = Campaign.open(server.url)
    _fast(a.remote)
    cold = a.submit(waves_scenario(1.0, name="w1"), backend="wormhole").result
    a.close()

    b = Campaign.open(server.url)
    _fast(b.remote)
    assert b.submit(waves_scenario(1.0, name="w1"), backend="wormhole").cached
    warm = b.submit(waves_scenario(1.1, name="w2"), backend="wormhole").result
    assert warm.kernel_report["run_db_hits"] > 0
    assert warm.events_processed < cold.events_processed
    b.close()
    # both hosts' memo entries compounded on the server
    assert len(server.db) > 0


# --------------------------------------------------------------------- #
# acceptance: server loss mid-sweep — degrade, then recover losslessly
# --------------------------------------------------------------------- #
def test_server_loss_mid_sweep_degrades_and_recovers(tmp_path):
    server = StoreServer(tmp_path / "served").start()
    camp = Campaign.open(tmp_path / "local", store=server.url)
    remote = _fast(camp.remote)
    remote.retry_interval = 3600          # stay degraded once down
    scns = [svc_scenario(1.0 + 0.05 * i, name=f"k{i}") for i in range(6)]
    keys = [run_key(s, "analytic", {}) for s in scns]

    finished = []
    def chaos(event):
        if event.kind == "finished":
            finished.append(event.key)
            if len(finished) == 2:
                server.shutdown()         # kill the server mid-sweep
    camp.subscribe(chaos)
    with pytest.warns(RuntimeWarning, match="degrading to local-only"):
        results = camp.sweep(scns, backend="analytic")

    # the sweep completed: every result present, later commits went local
    assert all(r is not None for r in results)
    assert remote.degraded and len(remote.pending) == 4
    local_keys = set(RunStore(tmp_path / "local" / "runs").keys())
    assert local_keys == set(keys[2:]) | set(keys[:2]) - (set(keys[:2]) -
                                                          local_keys)
    assert set(keys[2:]) <= local_keys    # degraded commits are durable

    # restart on the same port; the next store op reconnects and flushes
    server2 = StoreServer(tmp_path / "served", port=server.port).start()
    try:
        remote.retry_interval = 0.0
        assert camp.store.peek(keys[-1]) is not None
        assert not remote.degraded and remote.reconnects == 1
        assert remote.pending == set()
        # no lost, no duplicated records: exactly the 6 sweep keys
        assert set(RunStore(tmp_path / "served" / "runs").keys()) == set(keys)

        # the store is resumable: a fresh host sweeps all-cache-hit
        fresh = Campaign.open(server2.url)
        _fast(fresh.remote)
        kinds = []
        fresh.subscribe(lambda e: kinds.append(e.kind))
        fresh.sweep(scns, backend="analytic")
        assert kinds.count("cache_hit") == 6 and "started" not in kinds
        fresh.close()
        camp.close()
    finally:
        server2.shutdown()


def test_unreachable_server_degrades_from_the_start(tmp_path):
    with pytest.warns(RuntimeWarning, match="degrading to local-only"):
        camp = Campaign.open(tmp_path / "local",
                             store="http://127.0.0.1:9")   # nothing there
    h = camp.submit(svc_scenario(name="iso"), backend="analytic")
    assert h.result is not None and not h.cached
    assert camp.remote.degraded and len(camp.remote.pending) == 1
    # the commit landed in the durable local fallback
    assert len(RunStore(tmp_path / "local" / "runs")) == 1
    camp.close()


def test_attaching_a_second_server_is_refused(tmp_path, server):
    camp = Campaign.open(tmp_path / "local", store=server.url)
    _fast(camp.remote)
    with pytest.raises(ValueError, match="already attached"):
        camp.sweep([svc_scenario()], backend="analytic",
                   store="http://127.0.0.1:9")
    camp.close()


# --------------------------------------------------------------------- #
# acceptance: two hosts, overlapping sweeps, exactly N records
# --------------------------------------------------------------------- #
def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_two_hosts_overlapping_sweeps_commit_exactly_n(tmp_path):
    """Two processes sweep overlapping scenario sets [0,8) and [2,10)
    against one server: claims split the overlap, both finish every
    result, and the store ends with exactly 10 untorn records."""
    server = StoreServer(tmp_path / "served").start()
    try:
        procs = [subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             server.url, str(lo), str(hi)],
            env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True) for lo, hi in ((0, 8), (2, 10))]
        for p in procs:
            out, err = p.communicate(timeout=300)
            assert p.returncode == 0, (out, err)
            assert "worker done: 8" in out
    finally:
        server.shutdown()
    store = RunStore(tmp_path / "served" / "runs")
    expected = {run_key(s, "analytic", {}) for s in overlap_scenarios(0, 10)}
    assert set(store.keys()) == expected            # exactly N, no extras
    recs = list(store.records())                    # every record parses
    assert len(recs) == 10 and store.corrupt_keys() == []
    for rec in recs:
        RunResult.from_dict(rec["result"])
    # no leftover claim markers
    assert not list((tmp_path / "served" / "runs").glob(f"{CLAIM_PREFIX}*"))


# --------------------------------------------------------------------- #
# satellite: unified engine-option validation
# --------------------------------------------------------------------- #
def test_unknown_engine_opts_raise_shared_error(svc_engine):
    with pytest.raises(ValueError, match="does not accept"):
        run(svc_scenario(), backend="analytic", fidelity="auto")
    with pytest.raises(ValueError, match="accepted opts: until"):
        run(svc_scenario(), backend="analytic", bogus=1)
    with pytest.raises(ValueError, match="'packet' does not accept"):
        Campaign.in_memory().sweep([svc_scenario()], backend="packet",
                                   fidelity="flow")
    with pytest.raises(ValueError, match="does not accept"):
        run_many([svc_scenario()], backend="hybrid", parallel="partitions")
    # engines that have not declared option_names stay unvalidated
    r = run(svc_scenario(), backend="svc-counting", anything_goes=1)
    assert r is not None


def test_compare_backend_opts_scope_and_validate(svc_engine):
    cmp = compare(svc_scenario(), backends=("analytic", "svc-counting"),
                  backend_opts={"svc-counting": {"private": 1}})
    assert set(cmp.results) == {"analytic", "svc-counting"}
    with pytest.raises(ValueError, match="backend_opts"):
        compare(svc_scenario(), backends=("analytic",),
                backend_opts={"packet": {"until": 1.0}})


# --------------------------------------------------------------------- #
# satellite: db_path=/save_db= shim removed; GET /metrics counters
# --------------------------------------------------------------------- #
def test_db_path_engine_kwargs_removed(tmp_path):
    """The PR 9 deprecation shim is gone: db_path=/save_db= now fail like
    any unknown engine opt, and the campaign replacement stays silent."""
    with pytest.raises(ValueError, match="does not accept"):
        run(waves_scenario(1.1, name="dep2"), backend="wormhole",
            db_path=str(tmp_path / "db.json"), save_db=False)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        with Campaign.open(tmp_path / "camp") as camp:
            camp.submit(waves_scenario(1.2, name="dep3"), backend="wormhole")


def test_metrics_counts_store_claims_and_dedup(server):
    b = _fast(RemoteBackend(server.url))
    m0 = b.metrics()
    assert m0["store_gets"] == 0 and m0["store_hit_rate"] is None
    assert m0["simdb_replay_rate"] is None

    key, ck = "m" * 40, CLAIM_PREFIX + "m" * 40
    rec = {"record_version": 1, "key": key}
    assert b.get(key) is None                       # miss
    b.put(key, rec)
    assert b.get(key) == rec                        # hit
    b.put(key, dict(rec))                           # same content: dedup

    # claim lifecycle: create, reject the loser, release — none of it
    # pollutes the store hit/miss counters
    assert b.put_new(ck, {"owner": "w1", "t": time.time(), "ttl": 600}) is True
    assert b.put_new(ck, {"owner": "w2", "t": time.time(), "ttl": 600}) is False
    assert b.delete(ck) is True

    m = b.metrics()
    assert m["store_gets"] == 2
    assert m["store_misses"] == 1 and m["store_hits"] == 1
    assert m["store_hit_rate"] == 0.5
    assert m["store_puts"] == 2 and m["dedup_hits"] == 1
    assert m["claim_creates"] == 1 and m["claim_rejects"] == 1
    assert m["claim_releases"] == 1 and m["claim_steals"] == 0
    assert m["runs"] == 1


def test_metrics_counts_claim_steals_and_simdb_replay(server):
    remote = _fast(RemoteBackend(server.url))
    store = RunStore(backend=remote)
    key = "s1" * 20
    assert store.claim(key, "w1", ttl=0.05) is True
    time.sleep(0.1)
    assert store.claim(key, "w2") is True           # stale claim: stolen
    m = remote.metrics()
    assert m["claim_creates"] == 1 and m["claim_steals"] == 1

    # the same memo delta pushed twice: the second push is pure replay
    db = SimDB()
    run(waves_scenario(1.0, name="mx"), backend="wormhole", db=db)
    assert len(db) > 0
    payload = db.to_dict()
    assert remote.simdb_push(payload["entries"], payload["fingerprint"])
    m1 = remote.metrics()
    assert m1["simdb_pushes"] == 1
    # merge dedups isomorphic entries, so added <= pushed even when cold
    assert 0 < m1["simdb_entries_added"] == m1["db_entries"]
    assert remote.simdb_push(payload["entries"], payload["fingerprint"])
    assert remote.simdb_pull() is not None
    m = remote.metrics()
    assert m["simdb_pushes"] == 2 and m["simdb_pulls"] == 1
    assert m["simdb_entries_pushed"] == 2 * len(db)
    # the second push was pure replay: nothing new landed
    assert m["simdb_entries_added"] == m1["simdb_entries_added"]
    assert m["simdb_replay_rate"] == pytest.approx(
        1.0 - m["simdb_entries_added"] / m["simdb_entries_pushed"])
    assert m["simdb_replay_rate"] >= 0.5


# --------------------------------------------------------------------- #
# CLI: serve + remote clients
# --------------------------------------------------------------------- #
def _cli(*args):
    return subprocess.run([sys.executable, "-m", "repro", *args],
                          env=_env(), capture_output=True, text=True,
                          timeout=300)


def test_cli_serve_and_remote_ls_show_rm(tmp_path):
    scn_file = tmp_path / "svc.json"
    scn_file.write_text(svc_scenario(name="cli-svc").to_json())
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "serve", "-c",
         str(tmp_path / "served"), "--port", "0", "-q"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True)
    try:
        line = proc.stdout.readline()
        assert "serving campaign store at http://" in line, line
        url = line.split()[4]

        out = _cli("run", str(scn_file), "--backend", "analytic", "-c", url)
        assert out.returncode == 0, out.stderr
        out = _cli("run", str(scn_file), "--backend", "analytic", "-c", url)
        assert out.returncode == 0 and "cache hit" in out.stdout

        out = _cli("ls", "-c", url)
        assert out.returncode == 0 and "analytic" in out.stdout
        assert "1 stored runs" in out.stdout
        key = out.stdout.split()[0]

        out = _cli("show", key, "-c", url)
        assert out.returncode == 0
        assert json.loads(out.stdout)["scenario"]["name"] == "cli-svc"

        out = _cli("rm", key, "-c", url)
        assert out.returncode == 0 and "removed 1" in out.stdout
        assert "0 stored runs" in _cli("ls", "-c", url).stdout
    finally:
        proc.terminate()
        proc.wait(timeout=10)


def test_cli_scoped_opts(tmp_path):
    scn_file = tmp_path / "svc.json"
    scn_file.write_text(svc_scenario(name="cli-opts").to_json())
    # a scoped opt for a backend this command will not run is an error
    out = _cli("run", str(scn_file), "--backend", "analytic",
               "--opt", "packet:until=1.0")
    assert out.returncode != 0 and "scoped" in (out.stdout + out.stderr)
    # compare fans scoped opts to their backend only
    out = _cli("compare", str(scn_file), "--backends", "analytic,packet",
               "--opt", "packet:record_rtt=[0]")
    assert out.returncode == 0, out.stderr
    assert "analytic" in out.stdout and "packet" in out.stdout
    # unknown opt fails loudly with the accepted list
    out = _cli("run", str(scn_file), "--backend", "analytic",
               "--opt", "bogus=1")
    assert out.returncode != 0
    assert "does not accept" in (out.stdout + out.stderr)


if __name__ == "__main__":
    # multi-host worker harness (see module docstring)
    url, lo, hi = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
    camp = Campaign.open(url)
    results = camp.sweep(overlap_scenarios(lo, hi), backend="analytic",
                         poll=0.05)
    assert all(r is not None for r in results)
    camp.close()
    print(f"worker done: {len(results)}")
