"""FCG canonicalisation + weighted-isomorphism matching (paper §4.2/§4.4)."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.core.fcg import build_fcg, isomorphism


def mk(fids, ports, rates, lr=12.5e9, cca="dctcp"):
    return build_fcg(
        fids, {f: frozenset(p) for f, p in ports.items()},
        rates={f: rates.get(f, lr) for f in fids},
        line_rates={f: lr for f in fids}, ccas={f: cca for f in fids},
    )


def test_relabeling_invariance():
    """Same contention structure under different flow ids / port ids must
    produce the same canonical key and an exact isomorphism."""
    a = mk([1, 2, 3], {1: {10, 11}, 2: {11, 12}, 3: {12, 13}}, {})
    b = mk([7, 8, 9], {9: {20, 21}, 8: {21, 22}, 7: {22, 23}}, {})
    assert a.key == b.key
    m = isomorphism(a, b)
    assert m is not None
    # chain ends map to chain ends
    deg_a = {0: 1, 1: 2, 2: 1}
    for u, v in m.items():
        assert deg_a[u] == deg_a[v]


def test_different_structure_rejected():
    chain = mk([1, 2, 3], {1: {10}, 2: {10, 11}, 3: {11}}, {})
    tri = mk([1, 2, 3], {1: {10, 12}, 2: {10, 11}, 3: {11, 12}}, {})
    assert chain.key != tri.key
    assert isomorphism(chain, tri) is None


def test_edge_weight_mismatch_rejected():
    one = mk([1, 2], {1: {10}, 2: {10}}, {})
    two = mk([1, 2], {1: {10, 11}, 2: {10, 11}}, {})
    assert isomorphism(one, two) is None


def test_rate_buckets_affect_key():
    a = mk([1, 2], {1: {10}, 2: {10}}, {1: 12.5e9, 2: 12.5e9})
    b = mk([1, 2], {1: {10}, 2: {10}}, {1: 6.0e9, 2: 6.0e9})
    assert isomorphism(a, b) is None


def test_cca_affects_key():
    a = mk([1, 2], {1: {10}, 2: {10}}, {}, cca="dctcp")
    b = mk([1, 2], {1: {10}, 2: {10}}, {}, cca="hpcc")
    assert isomorphism(a, b) is None


@given(st.integers(2, 9), st.randoms(use_true_random=False))
@settings(max_examples=60, deadline=None)
def test_random_graph_permutation_isomorphic(n, rnd):
    """Permuting vertex identities of a random conflict graph always yields
    an isomorphism, and the mapping preserves edges + weights."""
    ports = {f: set() for f in range(n)}
    pid = 0
    for i in range(n):
        for j in range(i + 1, n):
            if rnd.random() < 0.4:
                w = rnd.randint(1, 3)
                for _ in range(w):
                    ports[i].add(pid)
                    ports[j].add(pid)
                    pid += 1
    for f in range(n):
        if not ports[f]:
            ports[f].add(pid)
            pid += 1
    a = mk(list(range(n)), ports, {})
    perm = list(range(n))
    rnd.shuffle(perm)
    ports_b = {perm[f]: ports[f] for f in range(n)}
    b = mk(list(range(n)), ports_b, {})
    assert a.key == b.key
    m = isomorphism(a, b)
    assert m is not None
    inv_edges = {}
    for (i, j), w in b.edges.items():
        inv_edges[(i, j)] = w
    for (i, j), w in a.edges.items():
        mi, mj = sorted((m[i], m[j]))
        assert inv_edges.get((mi, mj)) == w
