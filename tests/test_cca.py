import pytest

from repro.core.steady import fluctuation
from repro.net.flows import FlowSpec
from repro.net.packet_sim import PacketSim
from repro.net.topology import leaf_spine_clos

CCAS = ["dctcp", "dcqcn", "timely", "hpcc"]


def incast(cca, n=2, size=3e6, window=16):
    topo = leaf_spine_clos(8, leaf_down=4, n_spines=2)
    sim = PacketSim(topo, window=window)
    for i in range(n):
        sim.add_flow(FlowSpec(i, i, 5, size, 0.0, cca))
    sim.run()
    return sim


@pytest.mark.parametrize("cca", CCAS)
def test_convergence_and_stability(cca):
    # keep a long history; the flow must have stabilised in *some* window
    # (the final samples cover the end-of-flow drain and may ramp)
    sim = incast(cca, size=4e6, window=256)
    assert sim.all_done()
    hist = list(sim.flows[0].rate_hist)
    assert len(hist) >= 24
    best = min(fluctuation(hist[i:i + 8]) for i in range(len(hist) - 8))
    assert best < 0.5, f"{cca} never stabilised (best window fluctuation {best:.2f})"


@pytest.mark.parametrize("cca", CCAS)
def test_fair_share_utilisation(cca):
    sim = incast(cca)
    bw = 12.5e9
    # two flows share one 12.5GB/s downlink: aggregate goodput within [30%, 100%]
    fct = max(r.finish for r in sim.results.values())
    agg = 2 * 3e6 / fct
    assert 0.3 * bw <= agg <= 1.01 * bw, f"{cca}: aggregate {agg/1e9:.2f} GB/s"
    # fairness: FCTs within 35% of each other
    fcts = [sim.results[i].fct for i in (0, 1)]
    assert abs(fcts[0] - fcts[1]) / max(fcts) < 0.35


@pytest.mark.parametrize("cca", CCAS)
def test_single_flow_reaches_line_rate(cca):
    topo = leaf_spine_clos(8, leaf_down=4, n_spines=2)
    sim = PacketSim(topo)
    sim.add_flow(FlowSpec(0, 0, 5, 4e6, 0.0, cca))
    sim.run()
    ideal = 4e6 / 12.5e9
    assert sim.results[0].fct < 3.5 * ideal, f"{cca} too slow: {sim.results[0].fct/ideal:.2f}x ideal"


def test_conservation_every_byte_delivered():
    sim = incast("dctcp", n=2, size=2.5e6)
    for f in sim.flows.values():
        assert f.done
        assert abs(f.delivered - f.spec.size) < 1e-6


def test_ecn_keeps_queues_bounded():
    sim = incast("dctcp", n=4, size=2e6)
    # no port backlog may exceed the buffer (otherwise drops were mishandled)
    assert sim.all_done()
    assert all(r.fct > 0 for r in sim.results.values())
