"""Simulation database semantics (paper §4.3/§4.4)."""
from repro.core.fcg import build_fcg
from repro.core.memo import COMPLETION, STEADY, MemoEntry, SimDB


def fcg(fids, ports, rates=None, lr=12.5e9):
    rates = rates or {}
    return build_fcg(fids, {f: frozenset(p) for f, p in ports.items()},
                     {f: rates.get(f, lr) for f in fids},
                     {f: lr for f in fids}, {f: "dctcp" for f in fids})


def entry(g, sizes, reason=STEADY, rates=None):
    return MemoEntry(fcg=g, end_rates=rates or [6e9] * g.n, sizes=sizes,
                     t_conv=1e-3, end_reason=reason)


def test_hit_on_isomorphic_scene():
    db = SimDB()
    g1 = fcg([1, 2], {1: {10}, 2: {10}})
    db.insert(entry(g1, [1e6, 1e6]))
    g2 = fcg([40, 41], {40: {99}, 41: {99}})
    hit = db.lookup(g2, remaining=[5e6, 5e6])
    assert hit is not None
    assert sorted(hit.mapping.keys()) == [0, 1]


def test_remaining_size_guard():
    """A stored transient longer than the current flows' remaining bytes
    would run past a completion -> must miss (fall back to packet sim)."""
    db = SimDB()
    g1 = fcg([1, 2], {1: {10}, 2: {10}})
    db.insert(entry(g1, [4e6, 4e6]))
    assert db.lookup(fcg([3, 4], {3: {5}, 4: {5}}), remaining=[1e6, 9e6]) is None
    assert db.lookup(fcg([3, 4], {3: {5}, 4: {5}}), remaining=[9e6, 9e6]) is not None


def test_no_hit_across_structures():
    db = SimDB()
    db.insert(entry(fcg([1, 2], {1: {10}, 2: {10}}), [1e6, 1e6]))
    g3 = fcg([1, 2, 3], {1: {10}, 2: {10}, 3: {10}})
    assert db.lookup(g3, [9e6] * 3) is None


def test_stats_and_size_accounting():
    db = SimDB()
    for i in range(10):
        g = fcg([i, 100 + i], {i: {i * 2}, 100 + i: {i * 2}},
                rates={i: 12.5e9 * (1 - 0.05 * i)})
        db.insert(entry(g, [1e6, 1e6]))
    s = db.stats()
    assert s["entries"] == 10
    assert 0 < s["bytes"] < 100_000, "DB must stay tiny (Fig 9b)"


def test_completion_entries_roundtrip():
    db = SimDB()
    g = fcg([1], {1: {10}})
    db.insert(entry(g, [2e6], reason=COMPLETION))
    hit = db.lookup(fcg([9], {9: {77}}), remaining=[2e6])
    assert hit is not None and hit.entry.end_reason == COMPLETION


def test_nbytes_counts_sizes_and_completed():
    """Fig 9b DB-footprint accounting: ``sizes`` is as long as ``end_rates``
    and ``completed`` is stored too — omitting them undercounted ~2x."""
    g = fcg([1, 2], {1: {10}, 2: {10}})
    e = MemoEntry(fcg=g, end_rates=[6e9, 6e9], sizes=[1e6, 1e6], t_conv=1e-3,
                  end_reason=STEADY, completed=(0,))
    assert e.nbytes() == g.nbytes() + 16 * 2 + 16 * 2 + 8 * 1 + 32
    # the per-flow lists dominate: the entry must cost at least 16 bytes per
    # stored rate AND per stored size on top of the key graph
    assert e.nbytes() >= g.nbytes() + 16 * len(e.end_rates) + 16 * len(e.sizes)
    no_sizes_no_completed = g.nbytes() + 16 * len(e.end_rates) + 32
    assert e.nbytes() > no_sizes_no_completed


def test_completion_match_tolerance_scales_with_mtu():
    """The completion-ending guard compares byte counts: 2e3 is ~2 MTUs only
    at the scaled 1000B default — callers pass atol=2*mtu instead."""
    db = SimDB()
    g = fcg([1], {1: {10}})
    db.insert(MemoEntry(fcg=g, end_rates=[6e9], sizes=[2e6], t_conv=1e-3,
                        end_reason=COMPLETION, completed=(0,)))
    probe = fcg([9], {9: {77}})
    # 3000B past the stored completion point: outside 2 default MTUs...
    assert db.lookup(probe, remaining=[2e6 + 3e3]) is None
    # ...but within 2 jumbo-frame MTUs — same scene, different packet size
    assert db.lookup(probe, remaining=[2e6 + 3e3], atol=2 * 9000.0) is not None
    # and a small-MTU sim must get the *tighter* guard, not the 1500B one
    assert db.lookup(probe, remaining=[2e6 + 1.5e3], atol=2 * 500.0) is None
    assert db.lookup(probe, remaining=[2e6 + 0.9e3], atol=2 * 500.0) is not None


def test_completion_tolerance_capped_relative_to_flow_size():
    """For small flows, 2 MTUs is a large *fraction* of the flow: a merged
    multi-variant DB holds completion transients at closely spaced sizes,
    and accepting a 5%-off match mis-fast-forwards the whole flow (observed
    as ~70% FCT error on ~17KB flows in the 64-GPU warm sweep)."""
    db = SimDB()
    g = fcg([1], {1: {10}})
    db.insert(MemoEntry(fcg=g, end_rates=[6e9], sizes=[18923.0], t_conv=2e-5,
                        end_reason=COMPLETION, completed=(0,)))
    probe = fcg([9], {9: {77}})
    # adjacent sweep variant: 860B off — inside 2 MTUs, but 4.3% of the flow
    assert db.lookup(probe, remaining=[19783.0]) is None
    # the genuine recurrence (sub-packet drift) still hits
    assert db.lookup(probe, remaining=[18930.0]) is not None
