"""Persistent SimDB (save/load/merge, regime fingerprinting) and
process-parallel `run_many` — the durable half of the paper's §6.1
multi-experiment reuse: a memo DB recorded by one sweep warm-starts the
next session's runs, and a cold sweep fans out over worker processes whose
insert deltas merge back into one shared DB."""
import json
import os
import subprocess
import sys

import pytest

import repro.core.fcg as fcg_mod
from repro.api import run, run_many
from repro.core.fcg import FCG, build_fcg, isomorphism, stable_hash
from repro.core.memo import (COMPLETION, FORMAT_VERSION, STEADY, MemoEntry,
                             SimDB, SimDBMismatch, sim_fingerprint)
from test_api import wave_scenario

# .../src/repro/core/fcg.py -> .../src  (repro is a namespace package)
SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(fcg_mod.__file__))))


def fcg(fids, ports, lr=12.5e9, cca="dctcp"):
    return build_fcg(fids, {f: frozenset(p) for f, p in ports.items()},
                     {f: lr for f in fids}, {f: lr for f in fids},
                     {f: cca for f in fids})


def entry(g, sizes, reason=STEADY, rates=None, t_conv=1e-3):
    return MemoEntry(fcg=g, end_rates=rates or [6e9] * g.n, sizes=sizes,
                     t_conv=t_conv, end_reason=reason)


# --------------------------------------------------------------------- #
# FCG serialization + cross-process key stability
# --------------------------------------------------------------------- #
def test_fcg_dict_roundtrip_preserves_key_and_matching():
    g = fcg([3, 7, 9], {3: {10, 11}, 7: {11, 12}, 9: {12, 13}})
    d = g.to_dict()
    json.dumps(d)                                  # JSON-serializable
    back = FCG.from_dict(json.loads(json.dumps(d)))
    assert back.key == g.key
    assert back.labels == g.labels and back.edges == g.edges
    assert back.fids == g.fids
    assert isomorphism(g, back) is not None


def test_fcg_key_stable_across_interpreters():
    """Bucket keys must survive a process boundary: a fresh interpreter
    with a different hash salt must canonicalise to the same key (else a
    persisted DB could never be looked up by the next session)."""
    code = ("from repro.core.fcg import build_fcg\n"
            "g = build_fcg([1, 2], {1: frozenset({10}), 2: frozenset({10})},"
            " {1: 12.5e9, 2: 12.5e9}, {1: 12.5e9, 2: 12.5e9},"
            " {1: 'dctcp', 2: 'dctcp'})\n"
            "print(g.key)")
    keys = set()
    for seed in ("0", "1", "31337"):
        env = {**os.environ, "PYTHONHASHSEED": seed,
               "PYTHONPATH": SRC + os.pathsep + os.environ.get("PYTHONPATH", "")}
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        keys.add(int(out.stdout.strip()))
    assert len(keys) == 1
    assert keys == {fcg([1, 2], {1: {10}, 2: {10}}).key}


def test_stable_hash_is_deterministic_constant():
    # pin a value: a silent change to the hash orphans every saved DB
    assert stable_hash(("dctcp", 40, 12, 0)) == \
        stable_hash(("dctcp", 40, 12, 0))
    assert stable_hash(("a",)) != stable_hash(("b",))


# --------------------------------------------------------------------- #
# SimDB save / load / merge / fingerprint
# --------------------------------------------------------------------- #
def test_save_load_roundtrips_lookup_behavior(tmp_path):
    db = SimDB(fingerprint="fp-test")
    db.insert(entry(fcg([1, 2], {1: {10}, 2: {10}}), [1e6, 1e6]))
    db.insert(entry(fcg([1], {1: {10}}), [2e6], reason=COMPLETION))
    db.insert(entry(fcg([1, 2, 3], {1: {10}, 2: {10, 11}, 3: {11}}),
                    [1e6, 2e6, 1e6]))
    path = str(tmp_path / "db.json")
    db.save(path)
    back = SimDB.load(path)
    assert back.fingerprint == "fp-test"
    assert len(back) == len(db) == 3

    probes = [
        (fcg([40, 41], {40: {99}, 41: {99}}), [5e6, 5e6]),      # hit e1
        (fcg([9], {9: {77}}), [2e6]),                            # hit e2 (completion)
        (fcg([9], {9: {77}}), [9e6]),                            # completion miss
        (fcg([5, 6], {5: {1, 2}, 6: {1, 2}}), [5e6, 5e6]),       # structural miss
        (fcg([7, 8, 9], {7: {1}, 8: {1, 2}, 9: {2}}), [9e6] * 3),  # hit e3
    ]
    for g, remaining in probes:
        a = db.lookup(g, list(remaining))
        b = back.lookup(g, list(remaining))
        assert (a is None) == (b is None)
        if a is not None:
            assert a.entry.to_dict() == b.entry.to_dict() or \
                a.entry.sizes == b.entry.sizes
            assert a.mapping == b.mapping


def test_load_rejects_other_format_version(tmp_path):
    db = SimDB()
    db.insert(entry(fcg([1], {1: {10}}), [1e6]))
    path = str(tmp_path / "db.json")
    db.save(path)
    with open(path) as fh:
        d = json.load(fh)
    d["format_version"] = FORMAT_VERSION + 1
    with open(path, "w") as fh:
        json.dump(d, fh)
    with pytest.raises(SimDBMismatch, match="format_version"):
        SimDB.load(path)


def test_merge_dedups_weighted_isomorphic_entries():
    a, b = SimDB(), SimDB()
    shared = entry(fcg([1, 2], {1: {10}, 2: {10}}), [1e6, 2e6])
    a.insert(shared)
    a.insert(entry(fcg([1], {1: {10}}), [4e6]))
    # same transient memoized by another worker under relabeled flows/ports
    b.insert(entry(fcg([7, 8], {8: {55}, 7: {55}}), [2e6, 1e6]))
    # same structure but genuinely different transient -> kept
    b.insert(entry(fcg([7, 8], {8: {55}, 7: {55}}), [3e6, 3e6]))
    added = a.merge(b)
    assert added == 1 and len(a) == 3
    # merge is idempotent
    assert a.merge(b) == 0 and len(a) == 3


def test_merge_and_bind_reject_fingerprint_mismatch():
    a = SimDB(fingerprint="mtu=1000;x")
    with pytest.raises(SimDBMismatch):
        a.merge(SimDB(fingerprint="mtu=9000;y"))
    with pytest.raises(SimDBMismatch):
        a.bind_fingerprint("mtu=9000;y")
    a.bind_fingerprint("mtu=1000;x")               # matching is fine
    unbound = SimDB()
    unbound.merge(SimDB(fingerprint="mtu=1000;x"))  # adopts on first bind
    assert unbound.fingerprint == "mtu=1000;x"


def test_kernel_attach_refuses_foreign_regime_db():
    """A DB recorded at one MTU must not be silently replayed at another:
    the wormhole engine raises when handed the mismatched DB."""
    db = SimDB()
    run(wave_scenario(), backend="wormhole", db=db)
    assert db.fingerprint == sim_fingerprint(1000.0, 64_000.0, 512_000.0)
    with pytest.raises(SimDBMismatch, match="recorded under"):
        run(wave_scenario(mtu=2000.0), backend="wormhole", db=db)


# --------------------------------------------------------------------- #
# process-parallel run_many
# --------------------------------------------------------------------- #
def test_run_many_workers_matches_serial_fcts():
    """Acceptance: workers=2 returns per-flow FCTs equal to the serial
    path (independent runs are deterministic, so equality is exact)."""
    scns = [wave_scenario(s, name=f"w{s:g}") for s in (1.0, 1.15, 1.3)]
    serial = run_many(scns, backend="wormhole")
    par = run_many(scns, backend="wormhole", workers=2)
    assert [r.scenario for r in par] == [s.name for s in scns]
    for rs, rp in zip(serial, par):
        assert rs.fcts == rp.fcts
        assert rs.events_processed == rp.events_processed


def test_run_many_parallel_delta_merges_into_warm_db():
    """A cold parallel sweep converges to one warm DB: the workers' insert
    deltas merge back (deduped), and a follow-up run fast-forwards."""
    scns = [wave_scenario(s, name=f"w{s:g}") for s in (1.0, 1.1)]
    db = SimDB()
    cold = run_many(scns, backend="wormhole", workers=2, db=db)
    assert len(db) > 0
    assert db.fingerprint is not None
    # dedup: both workers memoized the same wave transients
    assert len(db) < sum(r.kernel_report["db_inserts"] for r in cold)
    warm = run(wave_scenario(1.2, name="w1.2"), backend="wormhole", db=db)
    assert warm.kernel_report["run_db_hits"] > 0
    assert warm.events_processed < min(r.events_processed for r in cold) / 10


def test_explicit_simdb_roundtrip_cross_session(tmp_path):
    """Acceptance: cold parallel sweep -> SimDB.save -> fresh-process load
    -> warm run reproduces the in-memory warm event collapse.  (The
    db_path=/save_db= shim is gone: durable DBs are campaign-owned or an
    explicit load_or_new/save pair like this one.)"""
    path = str(tmp_path / "simdb.json")
    scns = [wave_scenario(s, name=f"w{s:g}") for s in (1.0, 1.1, 1.2)]
    cold_db = SimDB()
    run_many(scns[:2], backend="wormhole", workers=2, db=cold_db)
    cold_db.save(path)
    assert os.path.exists(path)

    # in-memory warm baseline for the held-out variant
    mem_db = SimDB()
    run_many(scns[:2], backend="wormhole", db=mem_db)
    mem_warm = run(scns[2], backend="wormhole", db=mem_db)

    # "next session": the only carried state is the file; run in a worker
    # process so even in-process caches cannot leak
    disk_warm = run_many([scns[2]], backend="wormhole", workers=2,
                         db=SimDB.load_or_new(path))[0]
    assert disk_warm.kernel_report["run_db_hits"] > 0
    assert disk_warm.fcts == mem_warm.fcts
    assert disk_warm.events_processed == mem_warm.events_processed

    base = run(scns[2], backend="packet")
    assert disk_warm.fct_errors_vs(base).mean() < 0.01


def test_run_many_db_opts_rejected_for_other_backends():
    with pytest.raises(ValueError, match="wormhole"):
        run_many([wave_scenario()], backend="packet", db=SimDB())
    with pytest.raises(ValueError, match="wormhole"):
        run_many([wave_scenario()], backend="fluid", workers=2,
                 shared_db=True)


def test_removed_db_path_opts_fail_loudly(tmp_path):
    """The PR 9 one-release db_path=/save_db= DeprecationWarning shim is
    removed: the opts now fail engine opt validation like any other typo
    instead of silently keying a phantom experiment."""
    with pytest.raises(ValueError, match="does not accept"):
        run(wave_scenario(), backend="wormhole",
            db_path=str(tmp_path / "db.json"))
    with pytest.raises(ValueError, match="does not accept"):
        run_many([wave_scenario()], backend="wormhole", save_db=False)


def test_explicit_sample_interval_changes_regime():
    """The steady detector's cadence shapes every stored t_conv/end-rate
    snapshot: an explicit sample_interval override is a different recording
    regime (the derived default is not — it follows mtu/line-rate)."""
    db = SimDB()
    run(wave_scenario(), backend="wormhole", db=db)
    assert ";si=default" in db.fingerprint
    with pytest.raises(SimDBMismatch, match="recorded under"):
        run(wave_scenario(sample_interval=5e-5), backend="wormhole", db=db)
