"""Workload layer: parallelism groups, collective decomposition, traffic
programs, and the end-to-end Table-1 GPT iteration under Wormhole."""
import pytest

from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.packet_sim import PacketSim
from repro.workload import presets
from repro.workload.collectives import (FidAlloc, all_to_all, ring_allreduce,
                                         ring_reduce_scatter, total_bytes)
from repro.workload.driver import WorkloadDriver
from repro.workload.parallelism import ParallelismConfig, build_groups, rank_of
from repro.workload.traffic import build_training_program, program_stats


def test_group_construction():
    par = ParallelismConfig(tp=2, dp=4, pp=2, ep=1)
    g = build_groups(par)
    assert par.world == 16
    # DP rings: tp*ep*pp of them, each with dp members
    assert len(g.dp_groups) == 2 * 1 * 2
    assert all(len(x) == 4 for x in g.dp_groups)
    # all ranks covered exactly once per (pp, tp) slice
    ranks = sorted(r for grp in g.dp_groups for r in grp)
    assert ranks == list(range(16))
    # stage mapping
    assert g.stage_of[rank_of(par, 0, 0, 0, 0)] == 0
    assert g.stage_of[rank_of(par, 0, 0, 0, 1)] == 1
    # PP pairs connect consecutive stages pointwise
    assert len(g.pp_pairs) == 1
    for a, b in g.pp_pairs[0]:
        assert g.stage_of[a] == 0 and g.stage_of[b] == 1


def test_ep_groups_all_to_all_domains():
    par = ParallelismConfig(tp=1, dp=2, pp=1, ep=4)
    g = build_groups(par)
    assert len(g.ep_groups) == 2
    assert all(len(x) == 4 for x in g.ep_groups)


def test_collective_byte_accounting():
    fid = FidAlloc()
    members = [0, 1, 2, 3]
    ar = ring_allreduce(members, 1e6, fid, "dctcp", "t")
    # ring AR moves 2(n-1)/n * bytes per member in total
    assert total_bytes(ar) == pytest.approx(4 * 2 * 3 / 4 * 1e6)
    rs = ring_reduce_scatter(members, 1e6, FidAlloc(), "dctcp", "t")
    assert total_bytes(rs) == pytest.approx(4 * 3 / 4 * 1e6)
    a2a = all_to_all(members, 1e6, FidAlloc(), "dctcp", "t")
    assert len(a2a) == 12
    assert total_bytes(a2a) == pytest.approx(4 * 3 / 4 * 1e6)


def test_program_structure_gpt():
    wl = presets.GPT[64]
    phases = build_training_program(wl.spec, wl.par, scale=1 / 1024)
    st = program_stats(phases)
    assert st["dp_bytes"] > 0 and st["pp_bytes"] > 0 and st["ep_bytes"] == 0
    # DP gradient sync dominates the wire bytes for GPT (elephant flows)
    assert st["dp_bytes"] > 5 * st["pp_bytes"]
    # dependencies are acyclic and reference earlier phases only
    for i, p in enumerate(phases):
        assert all(d < i for d in p.deps)


def test_program_structure_moe_has_a2a():
    wl = presets.moe_with_ep(presets.MOE[64])
    assert wl.par.ep == 4  # carved from dp=4
    phases = build_training_program(wl.spec, wl.par, scale=1 / 1024)
    st = program_stats(phases)
    assert st["ep_bytes"] > 0


def test_driver_executes_dag_and_measures_iteration():
    wl = presets.GPT[64]
    topo = presets.topology_for(64)
    phases = build_training_program(wl.spec, wl.par, scale=1 / 2048)
    sim = PacketSim(topo)
    drv = WorkloadDriver(sim, phases)
    sim.run()
    assert drv.finished
    assert drv.iteration_time > 0
    assert sim.all_done()


def test_straggler_slows_iteration():
    wl = presets.GPT[64]
    topo = presets.topology_for(64)
    base_p = build_training_program(wl.spec, wl.par, scale=1 / 2048)
    slow_p = build_training_program(wl.spec, wl.par, scale=1 / 2048,
                                    straggler=(0, 4.0))
    def run(ph):
        sim = PacketSim(topo)
        d = WorkloadDriver(sim, ph)
        sim.run()
        assert d.finished
        return d.iteration_time
    assert run(slow_p) > run(base_p) * 1.05


@pytest.mark.slow
def test_full_gpt64_iteration_wormhole_accuracy():
    wl = presets.GPT[64]
    topo = presets.topology_for(64)
    phases = build_training_program(wl.spec, wl.par, scale=1 / 256)

    def run(kernel=None):
        sim = PacketSim(topo, kernel=kernel)
        drv = WorkloadDriver(sim, phases)
        sim.run()
        assert drv.finished
        return sim, drv

    base, bdrv = run()
    k = WormholeKernel(WormholeConfig())
    wh, wdrv = run(k)
    errs = [abs(wh.results[f].fct - r.fct) / r.fct for f, r in base.results.items()]
    assert sum(errs) / len(errs) < 0.01, "paper claim: <1% average FCT error"
    it_err = abs(wdrv.iteration_time - bdrv.iteration_time) / bdrv.iteration_time
    assert it_err < 0.02
    assert base.events_processed / wh.events_processed > 2.0
    assert k.db.hits > 0
