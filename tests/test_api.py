"""The unified `repro.api` experiment layer: Scenario serialization,
engine-registry dispatch, backend parity through compare(), and the
shared-memo-DB batched sweep (cross-run warm cache, §6.1)."""
import json

import pytest

from repro.api import (Comparison, Engine, FlowSpec, RunResult, Scenario,
                       TopologySpec, WorkloadSpec, available_backends,
                       compare, get_engine, register_engine, run, run_many,
                       training_scenario)
from repro.api.engines import _REGISTRY


def wave_scenario(size_scale: float = 1.0, name: str = "waves",
                  **sim) -> Scenario:
    """The quickstart contention pattern (two identical waves on a small
    clos) — also imported by test_persist; ``**sim`` sets PacketSim knobs
    (mtu, sample_interval, ...) to probe regime fingerprinting."""
    flows = []
    fid = 0
    for wave in (0.0, 0.02):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6 * size_scale,
                                  start=wave, cca="dctcp", tag=f"wave@{wave}"))
            fid += 1
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}),
                    flows=flows, sim=dict(sim))


# --------------------------------------------------------------------- #
# Scenario: declarative + serializable
# --------------------------------------------------------------------- #
def test_flow_scenario_roundtrip():
    scn = wave_scenario()
    d = scn.to_dict()
    json.dumps(d)                                    # JSON-serializable
    assert Scenario.from_dict(d).to_dict() == d
    assert Scenario.from_json(scn.to_json()).to_dict() == d


def test_workload_scenario_roundtrip():
    scn = training_scenario(n_gpus=64, moe=True, cca="dcqcn", scale=1 / 512,
                            straggler=(3, 2.0))
    d = scn.to_dict()
    json.dumps(d)
    back = Scenario.from_dict(d)
    assert back.to_dict() == d
    assert back.workload.straggler == (3, 2.0)
    # the rebuilt scenario produces the identical traffic program
    a = scn.build_phases()
    b = back.build_phases()
    assert [(p.name, len(p.flows), p.deps) for p in a] == \
           [(p.name, len(p.flows), p.deps) for p in b]


def test_scenario_needs_exactly_one_traffic_source():
    tspec = TopologySpec("clos", {"n_hosts": 8})
    with pytest.raises(ValueError):
        Scenario("none", tspec)
    with pytest.raises(ValueError):
        Scenario("both", tspec, flows=[FlowSpec(0, 0, 1, 1e6)],
                 workload=WorkloadSpec())


def test_unknown_topology_kind_raises():
    with pytest.raises(ValueError, match="unknown topology"):
        TopologySpec("torus", {}).build()


def test_variant_sweep_axes():
    scn = wave_scenario()
    v = scn.variant(name="v", cca="hpcc", size_scale=2.0)
    assert v.name == "v" and scn.name == "waves"
    assert all(f.cca == "hpcc" and f.size == 16e6 for f in v.flows)
    assert all(f.cca == "dctcp" for f in scn.flows)   # original untouched
    w = training_scenario(n_gpus=64).variant(cca="dctcp", n_gpus=128)
    assert w.workload.cca == "dctcp" and w.workload.n_gpus == 128


# --------------------------------------------------------------------- #
# engine registry
# --------------------------------------------------------------------- #
def test_registry_has_all_backend_families():
    assert set(available_backends()) >= {"packet", "wormhole", "hybrid",
                                         "fluid", "analytic", "learned"}


def test_unknown_backend_raises_with_available_list():
    with pytest.raises(ValueError, match="unknown backend"):
        run(wave_scenario(), backend="ns3")
    # the message lists available_backends(), sorted
    with pytest.raises(ValueError,
                       match="analytic.*fluid.*hybrid.*packet.*wormhole"):
        get_engine("nope")


def test_register_engine_dispatch():
    @register_engine("nulltest")
    class NullEngine(Engine):
        def run(self, scenario, **opts):
            return RunResult(backend=self.name, scenario=scenario.name,
                             fcts={}, flow_bytes={}, tags={},
                             iteration_time=None, events_processed=0,
                             wall_time=0.0)
    try:
        r = run(wave_scenario(), backend="nulltest")
        assert r.backend == "nulltest" and r.scenario == "waves"
    finally:
        _REGISTRY.pop("nulltest", None)


# --------------------------------------------------------------------- #
# all four backends answer the same scenario; packet-vs-wormhole parity
# --------------------------------------------------------------------- #
def test_all_backends_return_runresult_for_same_scenario():
    scn = wave_scenario()
    for backend in ("packet", "wormhole", "fluid", "analytic"):
        r = run(scn, backend=backend)
        assert isinstance(r, RunResult)
        assert r.backend == backend
        assert set(r.fcts) == {f.fid for f in scn.flows}
        assert all(v > 0 for v in r.fcts.values())
        assert r.iteration_time and r.iteration_time > 0


def test_compare_packet_wormhole_parity():
    cmp = compare(wave_scenario(), backends=("packet", "wormhole"))
    assert isinstance(cmp, Comparison)
    wh, base = cmp["wormhole"], cmp["packet"]
    errs = wh.fct_errors_vs(base)
    assert errs.mean() < 0.01, "wormhole must stay within the paper's 1% bound"
    assert wh.events_processed < base.events_processed
    assert wh.kernel_report["parks"] + wh.kernel_report["replays"] > 0
    row = cmp.rows()[0]
    assert row["event_speedup"] > 1.0
    assert "wormhole" in cmp.format() and "fct err%" in cmp.format()


@pytest.fixture(scope="module")
def learned_params():
    """A tiny model fitted on hybrid flow-fidelity wave variants (~ms per
    ground-truth run), covering size scales 0.5-2.0 so the quickstart wave
    scenario is in-distribution for the learned backend."""
    from repro.api import Campaign
    from repro.learned import fit
    with Campaign.in_memory(name="api-learned") as camp:
        camp.sweep([wave_scenario(0.5 + 0.125 * i, name=f"fit{i}")
                    for i in range(13)], backend="hybrid", fidelity="flow")
        return fit(camp.export_dataset(), seed=0, hidden=(16, 16), steps=200)


def test_compare_covers_every_registered_backend(learned_params):
    """Registry seam acceptance: every name in available_backends() runs
    the quickstart scenario through compare() and returns a well-formed
    RunResult — the contract new backends (like hybrid and learned) plug
    into.  The learned backend's params= rides compare() scoped via
    backend_opts, so no other backend ever sees a foreign opt (engines
    now validate their opts instead of silently ignoring strangers)."""
    scn = wave_scenario()
    backends = available_backends()
    cmp = compare(scn, backends=backends, baseline="packet",
                  backend_opts={"learned": {"params": learned_params}})
    want_fids = {f.fid for f in scn.flows}
    for b in backends:
        r = cmp[b]
        assert isinstance(r, RunResult)
        assert r.backend == b and r.scenario == scn.name
        assert set(r.fcts) == want_fids, f"{b}: fcts incomplete"
        assert all(v > 0 for v in r.fcts.values())
        assert set(r.flow_bytes) == want_fids and set(r.tags) == want_fids
        assert r.events_processed >= 0 and r.wall_time >= 0
        assert isinstance(r.extras, dict)
        json.dumps(r.to_dict())           # serializable, extras included
    # per-family extras schema the benchmarks rely on
    g = cmp["hybrid"].extras["granularity"]
    assert {"packet_lane_events", "flow_lane_events", "demotions",
            "promotions", "resolves"} <= set(g)
    assert cmp["wormhole"].kernel_report is not None
    lr = cmp["learned"].extras["learned"]
    assert lr["params_fingerprint"] == learned_params.fingerprint
    assert lr["ood_violations"] == []
    assert len(cmp.rows()) == len(backends) - 1


def test_compare_rejects_foreign_baseline():
    with pytest.raises(ValueError, match="baseline"):
        compare(wave_scenario(), backends=("packet",), baseline="wormhole")


# --------------------------------------------------------------------- #
# RunResult JSON round-trip — every backend family (the contract the
# campaign RunStore persists results through)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend,opts", [
    ("packet", {}),
    ("wormhole", {}),
    ("hybrid", {"fidelity": "auto"}),
    ("fluid", {"steps": 60, "dt": 1e-5}),
    ("analytic", {}),
])
def test_runresult_json_roundtrip_per_backend_family(backend, opts):
    r = run(wave_scenario(), backend=backend, **opts)
    d = r.to_dict()
    wire = json.loads(json.dumps(d))          # an actual trip through JSON
    back = RunResult.from_dict(wire)
    assert back.to_dict() == d                # canonical-form fixpoint
    # typed fields reconstruct exactly (ints back from string keys,
    # floats preserved bit-for-bit by JSON repr round-tripping)
    assert back.backend == backend and back.scenario == r.scenario
    assert back.fcts == r.fcts
    assert back.flow_bytes == r.flow_bytes and back.tags == r.tags
    assert back.iteration_time == r.iteration_time
    assert back.events_processed == r.events_processed
    assert back.kernel_report == r.kernel_report
    if backend == "hybrid":                   # extras payloads ride along
        assert back.extras["granularity"] == r.extras["granularity"]
    if backend == "wormhole":
        assert back.kernel_report["db_hits"] == r.kernel_report["db_hits"]


def test_runresult_roundtrip_keeps_rtt_extras_usable():
    r = run(wave_scenario(), backend="packet", record_rtt=(0,))
    back = RunResult.from_dict(json.loads(json.dumps(r.to_dict())))
    samples = back.extras["rtt_samples"]["0"]   # JSON shape: str keys, lists
    assert len(samples) == len(r.extras["rtt_samples"][0])
    assert all(len(pair) == 2 for pair in samples)


# --------------------------------------------------------------------- #
# batched sweeps
# --------------------------------------------------------------------- #
def test_run_many_wormhole_shared_db_warm_cache():
    """Acceptance: in a N>=4 sweep with one shared SimDB, runs after the
    first get memo hits and stay under 1% mean FCT error vs their own
    per-run packet baseline."""
    variants = [wave_scenario(s, name=f"waves-x{s:g}")
                for s in (1.0, 1.1, 1.2, 1.3)]
    results = run_many(variants, backend="wormhole", shared_db=True)
    assert len(results) == 4
    for scn, r in zip(variants[1:], results[1:]):
        assert r.kernel_report["run_db_hits"] > 0, \
            f"{scn.name}: warm runs must hit the shared memo DB"
        base = run(scn, backend="packet")
        assert r.fct_errors_vs(base).mean() < 0.01
    # warm runs fast-forward nearly everything the cold run simulated
    assert results[-1].events_processed < results[0].events_processed


def test_run_many_shared_db_rejected_for_other_backends():
    with pytest.raises(ValueError, match="wormhole"):
        run_many([wave_scenario()], backend="packet", shared_db=True)


def test_run_many_fluid_vmapped_batch():
    scns = [wave_scenario(s, name=f"f{s:g}") for s in (1.0, 2.0)]
    results = run_many(scns, backend="fluid", dt=1e-5, steps=100)
    assert [r.scenario for r in results] == ["f1", "f2"]
    for scn, r in zip(scns, results):
        assert set(r.fcts) == {f.fid for f in scn.flows}
        assert all(v > 0 for v in r.fcts.values())
    # double the bytes at the same converged rates -> double the FCT
    for fid, fct in results[0].fcts.items():
        assert results[1].fcts[fid] == pytest.approx(2 * fct, rel=0.05)
