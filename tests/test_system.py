"""End-to-end behaviour of the full system through the `repro.api` layer:
Wormhole as a user-transparent drop-in backend over the packet-level
oracle, across CCAs and topologies."""
import pytest

from repro.api import FlowSpec, Scenario, SimDB, TopologySpec, run


def pair_scenario(tspec: TopologySpec, n_hosts: int, cca: str = "dctcp",
                  size: float = 4e6, pairs: int = 8) -> Scenario:
    flows = []
    for i in range(pairs):
        src = i % n_hosts
        dst = (i + n_hosts // 2) % n_hosts
        if src == dst:
            dst = (dst + 1) % n_hosts
        flows.append(FlowSpec(i, src, dst, size, 0.0, cca))
    return Scenario(f"pairs-{tspec.kind}-{cca}", tspec, flows=flows)


TOPOS = [
    (TopologySpec("fat_tree", {"k": 4}), 16),
    (TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4, "n_spines": 2}), 16),
    (TopologySpec("roft", {"n_servers": 4, "gpus_per_server": 4,
                           "leaf_radix": 4, "n_spines": 2}), 16),
]


@pytest.mark.parametrize("tspec,n_hosts", TOPOS)
def test_transparent_across_topologies(tspec, n_hosts):
    scn = pair_scenario(tspec, n_hosts)
    base = run(scn, backend="packet")
    wh = run(scn, backend="wormhole")
    assert set(base.fcts) == set(wh.fcts)
    errs = wh.fct_errors_vs(base)
    assert errs.mean() < 0.02
    # never slower than the baseline in event count (worst case: equal, the
    # paper's graceful-degradation guarantee)
    assert wh.events_processed <= base.events_processed


def test_kernel_composability_same_db_across_runs():
    """The simulation DB is reusable knowledge across simulations (the
    multi-experiment setting of §6.1): a second run with a warm DB skips the
    transients it saw in the first run.  Expressed with explicit run(db=)
    calls — run_many/Campaign now dedup an identical scenario to the stored
    result instead of re-simulating it."""
    tspec, n_hosts = TOPOS[1]
    scn = pair_scenario(tspec, n_hosts)
    db = SimDB()
    r1 = run(scn, backend="wormhole", db=db)
    r2 = run(scn, backend="wormhole", db=db)
    assert r2.kernel_report["replays"] >= 1, "warm DB must produce replays"
    assert r2.kernel_report["run_db_hits"] >= 1
    assert r2.events_processed <= r1.events_processed
