"""End-to-end behaviour of the full system: Wormhole as a user-transparent
drop-in kernel over the packet-level oracle, across CCAs and topologies."""
import pytest

from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.flows import FlowSpec
from repro.net.packet_sim import PacketSim
from repro.net.topology import fat_tree, leaf_spine_clos, rail_optimized_fat_tree


def run_workload(topo, kernel=None, cca="dctcp", size=4e6, pairs=8):
    sim = PacketSim(topo, kernel=kernel)
    n = topo.n_hosts
    for i in range(pairs):
        src = i % n
        dst = (i + n // 2) % n
        if src == dst:
            dst = (dst + 1) % n
        sim.add_flow(FlowSpec(i, src, dst, size, 0.0, cca))
    sim.run()
    assert sim.all_done()
    return sim


@pytest.mark.parametrize("mktopo", [
    lambda: fat_tree(4),
    lambda: leaf_spine_clos(16, leaf_down=4, n_spines=2),
    lambda: rail_optimized_fat_tree(4, gpus_per_server=4, leaf_radix=4, n_spines=2),
])
def test_transparent_across_topologies(mktopo):
    base = run_workload(mktopo())
    k = WormholeKernel(WormholeConfig())
    wh = run_workload(mktopo(), kernel=k)
    assert set(base.results) == set(wh.results)
    errs = [abs(wh.results[f].fct - r.fct) / r.fct for f, r in base.results.items()]
    assert sum(errs) / len(errs) < 0.02
    # never slower than the baseline in event count (worst case: equal, the
    # paper's graceful-degradation guarantee)
    assert wh.events_processed <= base.events_processed


def test_kernel_composability_same_db_across_runs():
    """The simulation DB is reusable knowledge across simulations (the
    multi-experiment setting of §6.1): a second run with a warm DB skips the
    transients it saw in the first run."""
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    k1 = WormholeKernel(WormholeConfig())
    run_workload(topo, kernel=k1)
    db = k1.db
    k2 = WormholeKernel(WormholeConfig(), db=db)
    run_workload(topo, kernel=k2)
    assert k2.stats["replays"] >= 1, "warm DB must produce replays"
