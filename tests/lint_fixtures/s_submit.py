"""Deliberate S401 violations (reprolint fixture corpus)."""
from concurrent.futures import ProcessPoolExecutor


def fan_out(tasks) -> list:
    pool = ProcessPoolExecutor(2)
    futures = [pool.submit(lambda t: t * 2, t) for t in tasks]  # S401 (line 7)

    def _local_worker(t):
        return t * 2

    futures.append(pool.submit(_local_worker, tasks[0]))        # S401 (line 12)
    return futures
