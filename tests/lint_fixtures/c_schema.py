"""Deliberate C303 violation (reprolint fixture corpus).

The fixture fingerprint (c_schema_fingerprint.json) records FixtureRecord
at version 1 with fields ["key", "value"]; this file adds a field WITHOUT
bumping SCHEMA_VERSION — exactly the mutation C303 exists to catch.
"""
import dataclasses

SCHEMA_VERSION = 1


@dataclasses.dataclass
class FixtureRecord:
    key: str
    value: float
    added_without_bump: int
