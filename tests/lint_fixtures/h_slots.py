"""Deliberate H205/C304 violations (reprolint fixture corpus).

The test config registers FixtureHot as a hot class; its __slots__ is
missing "b" (H205), and the committed fixture fingerprint records the
original ("a", "b") layout so the current one-slot layout is also a C304
drift.
"""


class FixtureHot:
    __slots__ = ("a",)

    def __init__(self) -> None:
        self.a = 0

    def tick(self) -> None:
        self.b = 1                           # H205 (line 17)
