"""Deliberate S402 violation, hop 1 (reprolint fixture corpus).

The test config registers this module as a spawn-worker entry; it reaches
jax at import time through s_jaxy.
"""
import s_jaxy


def worker_main(blob: bytes) -> bytes:
    return s_jaxy.crunch(blob)
