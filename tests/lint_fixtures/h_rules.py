"""Deliberate H-rule violations (reprolint fixture corpus)."""
import itertools
import logging

from repro.hotpath import hot_path


@hot_path
def h201_logging(events) -> None:
    for ev in events:
        logging.debug("event %s", ev)        # H201 (line 11)


@hot_path
def h202_counter() -> int:
    seq = itertools.count(1)                 # H202 (line 16)
    return next(seq)


@hot_path
def h203_closure(items) -> list:
    return sorted(items, key=lambda x: x[1])     # H203 (line 22)


class H204NoSlots:
    @hot_path
    def step(self) -> None:
        self.ticks = 1                       # H204 (line 28)
