"""Deliberate D-rule violations (reprolint fixture corpus — never imported,
never executed; tests/test_reprolint.py asserts each rule fires here)."""
import random

import numpy as np


def d101_builtin_hash(scenario) -> int:
    return hash(scenario.name)              # D101 (line 9)


def d102_id_key(obj, cache: dict) -> None:
    cache[id(obj)] = obj                     # D102 (line 13)


def d103_global_rng() -> float:
    return random.random() + np.random.rand()   # D103 x2 (line 17)


def d104_set_iteration(fids: set) -> list:
    out = []
    for fid in fids:                         # D104 (line 22)
        out.append(fid)
    return out
