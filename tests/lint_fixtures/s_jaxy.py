"""Deliberate S402 violation, hop 2 (reprolint fixture corpus)."""
import jax                                   # S402 (line 2): module-level jax


def crunch(blob: bytes) -> bytes:
    return jax.numpy.asarray(blob).tobytes()
