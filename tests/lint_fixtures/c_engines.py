"""Deliberate C301/C302 violations (reprolint fixture corpus)."""
from repro.api.engines import register_engine


@register_engine("fixture-bad-return")
class BadReturnEngine:
    def run(self, scenario, **opts):
        return {"fcts": {}}                  # C301 (line 8): not a RunResult


@register_engine("fixture-no-db")
class NoDbEngine:
    uses_db = True

    def run(self, scenario):                 # C302 (line 15): no db param
        return self._solve(scenario)
