"""Pragma-suppressed violations (reprolint fixture corpus): every finding
in this file is covered by an allow pragma, so the file lints clean."""


def suppressed_inline(scenario) -> int:
    return hash(scenario)  # reprolint: allow[D101] — fixture: inline pragma


def suppressed_next_line(fids: set) -> None:
    # reprolint: allow[D104] — fixture: comment-line pragma covers next line
    for fid in fids:
        print(fid)


def suppressed_wildcard(obj, cache: dict) -> None:
    cache[id(obj)] = obj  # reprolint: allow[*] — fixture: wildcard pragma
