"""The learned engine subsystem (`repro.learned`): dataset extraction from
campaign stores (ground-truth guard, deterministic split), fitted-params
persistence (version refusal, fingerprint checks), and the engine's
serving contract (missing-params / out-of-distribution errors, run_batch
parity, well-formed RunResults)."""
import json
import warnings

import numpy as np
import pytest

from repro.api import Campaign, FlowSpec, Scenario, TopologySpec, get_engine
from repro.learned import (OutOfDistributionError, build_dataset, fit,
                           flow_table, heldout_fct_error,
                           heldout_fraction_of, model)


def wave_scenario(size_scale: float = 1.0, cca: str = "dctcp",
                  name: str = "waves") -> Scenario:
    flows, fid = [], 0
    for wave in (0.0, 0.02):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2),
                                  size=8e6 * size_scale, start=wave,
                                  cca=cca, tag=f"wave@{wave}"))
            fid += 1
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}),
                    flows=flows)


@pytest.fixture(scope="module")
def campaign():
    """16 hybrid flow-fidelity runs (legitimate ground truth, ~ms each) in
    an anonymous campaign — the training source for every fixture fit."""
    camp = Campaign.in_memory(name="learned-test")
    camp.sweep([wave_scenario(0.5 + 0.1 * i, name=f"tw{i}")
                for i in range(16)], backend="hybrid", fidelity="flow")
    yield camp
    camp.close()


@pytest.fixture(scope="module")
def dataset(campaign):
    return campaign.export_dataset()


@pytest.fixture(scope="module")
def params(dataset):
    return fit(dataset, seed=0, hidden=(16, 16), steps=250)


# --------------------------------------------------------------------- #
# dataset extraction
# --------------------------------------------------------------------- #
def test_flow_table_is_pure_scenario_math():
    scn = wave_scenario()
    table = flow_table(scn)
    assert list(table.fids) == [f.fid for f in scn.flows]
    assert np.isfinite(table.numeric).all()
    assert (table.ideal_fct > 0).all()
    assert table.kind == "flows" and len(table.phases) == 2
    assert set(table.phase_of) == {0, 1}                  # two waves
    assert table.cca == ["dctcp"] * 8 and table.topo_kind == "clos"


def test_dataset_shapes_and_split(campaign, dataset):
    assert len(dataset) == 16 * 8
    assert dataset.n_records == 16
    assert dataset.X.shape == (128, dataset.n_numeric
                               + len(dataset.cca_vocab)
                               + len(dataset.topo_vocab))
    # targets are log slowdowns of the stored FCTs
    assert np.allclose(np.exp(dataset.y) * dataset.ideal_fct, dataset.fct)
    # the split is record-granular: a record's flows land on one side
    for key in set(dataset.record_key):
        rows = [h for k, h in zip(dataset.record_key, dataset.heldout)
                if k == key]
        assert len(set(rows)) == 1
        assert rows[0] == (heldout_fraction_of(key) < 0.25)


def test_dataset_split_is_deterministic(campaign, dataset):
    again = campaign.export_dataset()
    assert np.array_equal(dataset.heldout, again.heldout)
    assert np.array_equal(dataset.X, again.X)
    assert np.array_equal(dataset.y, again.y)


def test_dataset_refuses_non_ground_truth_backends(campaign):
    with pytest.raises(ValueError, match="not packet-level ground truth"):
        build_dataset(campaign, backends=("analytic",))
    with pytest.raises(ValueError, match="no ground-truth records"):
        with Campaign.in_memory() as camp:
            camp.submit(wave_scenario(), backend="analytic")
            build_dataset(camp)


def test_dataset_dedups_scenarios_by_fidelity_rank():
    """One scenario evaluated on two ground-truth backends must collapse
    to a single record (highest fidelity wins) so it can't straddle the
    train/held-out split."""
    with Campaign.in_memory() as camp:
        scn = wave_scenario(name="dup")
        camp.submit(scn, backend="hybrid", fidelity="flow")
        camp.submit(scn, backend="packet")
        ds = build_dataset(camp)
    assert ds.n_records == 1
    # the surviving targets are the packet FCTs, not the hybrid ones
    from repro.api import run
    truth = run(wave_scenario(name="dup"), backend="packet")
    assert np.allclose(sorted(ds.fct), sorted(truth.fcts.values()))


# --------------------------------------------------------------------- #
# fit + params persistence
# --------------------------------------------------------------------- #
def test_fixed_seed_fit_is_deterministic(dataset, params):
    again = fit(dataset, seed=0, hidden=(16, 16), steps=250)
    assert again.fingerprint == params.fingerprint
    other_seed = fit(dataset, seed=1, hidden=(16, 16), steps=250)
    assert other_seed.fingerprint != params.fingerprint


def test_fit_learns_the_family(dataset, params):
    err = heldout_fct_error(params, dataset)
    assert err == err, "fixture split must hold records out"
    assert err < 0.10, f"held-out mean FCT error {err:.3f} over the bound"


def test_params_save_load_roundtrip(tmp_path, params):
    path = tmp_path / "params.json"
    model.save(params, path)
    assert path.exists() and path.with_suffix(".npz").exists()
    back = model.load(path)
    assert back.fingerprint == params.fingerprint
    assert back.meta["cca_vocab"] == params.meta["cca_vocab"]
    for (w0, b0), (w1, b1) in zip(back.weights, params.weights):
        assert np.array_equal(w0, w1) and np.array_equal(b0, b1)
    X = np.zeros((3, params.d_in))
    assert np.allclose(model.predict(back, X), model.predict(params, X))


def test_load_refuses_foreign_params_version(tmp_path, params):
    path = tmp_path / "params.json"
    model.save(params, path)
    meta = json.loads(path.read_text())
    meta["params_version"] = 99
    path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="params_version"):
        model.load(path)


def test_load_refuses_mismatched_weights(tmp_path, params, dataset):
    """A meta file paired with the wrong npz (e.g. a partial copy of two
    different fits) must refuse, not silently serve the wrong model."""
    path = tmp_path / "params.json"
    model.save(params, path)
    other = fit(dataset, seed=7, hidden=(16, 16), steps=50)
    model.save(other, tmp_path / "other.json")
    (tmp_path / "other.npz").rename(tmp_path / "params.npz")
    with pytest.raises(ValueError, match="fingerprint"):
        model.load(path)


# --------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------- #
def test_missing_params_is_a_clear_error(tmp_path):
    with pytest.raises(FileNotFoundError, match="python -m repro fit"):
        get_engine("learned").run(wave_scenario(),
                                  params=tmp_path / "nope.json")


def test_params_cache_detects_same_size_same_mtime_rewrite(
        tmp_path, params, dataset):
    """Rewriting the params file with an equal-size payload at a forced
    identical mtime (os.utime) must still invalidate the serving cache —
    the cache key carries a content fingerprint, not just (mtime, size)."""
    import os

    from repro.learned.engine import load_params

    path = tmp_path / "params.json"
    npz = tmp_path / "params.npz"
    fixed_ns = (1_700_000_000_000_000_000,) * 2
    # trailing whitespace is valid JSON: pad both saves to one fixed size
    # so (path, size, mtime) alone cannot tell the two models apart
    pad_to = 4096

    def save_padded(p):
        model.save(p, path)
        raw = path.read_bytes()
        path.write_bytes(raw + b"\n" * (pad_to - len(raw)))
        os.utime(path, ns=fixed_ns)
        os.utime(npz, ns=fixed_ns)

    save_padded(params)
    size_a = path.stat().st_size
    first = load_params(path)
    assert first.fingerprint == params.fingerprint

    other = fit(dataset, seed=1, hidden=(16, 16), steps=250)
    assert other.fingerprint != params.fingerprint
    save_padded(other)
    assert path.stat().st_size == size_a
    second = load_params(path)
    assert second.fingerprint == other.fingerprint


def test_engine_runresult_contract(params):
    scn = wave_scenario(1.23, name="query")
    r = get_engine("learned").run(scn, params=params)
    assert r.backend == "learned" and r.scenario == "query"
    assert set(r.fcts) == {f.fid for f in scn.flows}
    assert all(v > 0 for v in r.fcts.values())
    assert r.iteration_time and r.iteration_time > 0
    assert r.events_processed == 0                  # nothing simulated
    learned = r.extras["learned"]
    assert learned["params_fingerprint"] == params.fingerprint
    assert learned["ood_violations"] == []
    assert r.extras["predicted_fcts"] == r.fcts
    # survives the store's JSON round-trip
    from repro.api import RunResult
    back = RunResult.from_dict(json.loads(json.dumps(r.to_dict())))
    assert back.fcts == r.fcts


def test_run_batch_matches_run(params):
    scns = [wave_scenario(s, name=f"b{s:g}") for s in (0.8, 1.0, 1.4)]
    eng = get_engine("learned")
    batch = eng.run_batch(scns, params=params)
    for scn, br in zip(scns, batch):
        solo = eng.run(scn, params=params)
        assert solo.fcts == pytest.approx(br.fcts)
        assert solo.iteration_time == pytest.approx(br.iteration_time)


def test_out_of_distribution_policies(params):
    far = wave_scenario(80.0, name="far")            # way past the envelope
    eng = get_engine("learned")
    with pytest.raises(OutOfDistributionError, match="log_size"):
        eng.run(far, params=params)
    with pytest.warns(RuntimeWarning, match="extrapolating"):
        r = eng.run(far, params=params, ood="warn")
    assert r.extras["learned"]["ood_violations"]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        r = eng.run(far, params=params, ood="ignore")
    assert r.extras["learned"]["ood_violations"]     # still reported
    with pytest.raises(ValueError, match="ood policy"):
        eng.run(far, params=params, ood="loud")


def test_unknown_category_is_out_of_distribution(params):
    alien = wave_scenario(1.0, cca="hpcc", name="alien-cca")
    with pytest.raises(OutOfDistributionError, match="cca"):
        get_engine("learned").run(alien, params=params)


def test_engine_through_campaign_sweep(tmp_path, params):
    """The learned engine rides the campaign layer like any other backend
    (params passed by path so the runs stay cacheable)."""
    path = tmp_path / "params.json"
    model.save(params, path)
    scns = [wave_scenario(s, name=f"c{s:g}") for s in (0.9, 1.1)]
    with Campaign.open(tmp_path / "camp") as camp:
        first = camp.sweep(scns, backend="learned", params=str(path))
        again = camp.sweep(scns, backend="learned", params=str(path))
    assert [r.fcts for r in first] == [r.fcts for r in again]
    assert camp.store.hits >= 2                      # second pass cached
