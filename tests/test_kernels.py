"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp ref.py oracles (interpret mode on CPU), plus hypothesis property
tests on the kernels' invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.kernels.cca_step.ops import cca_step
from repro.kernels.cca_step.ref import cca_step_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.steady_scan.ops import steady_scan
from repro.kernels.steady_scan.ref import steady_scan_ref

RNG = np.random.default_rng(7)


# --------------------------------------------------------------------- #
# cca_step
# --------------------------------------------------------------------- #
def _cca_inputs(F, L, dtype=jnp.float32):
    M = (RNG.random((F, L)) < 0.3).astype(np.float32)
    M[:, 0] = 1.0
    mk = lambda x: jnp.asarray(x, dtype)
    return dict(
        R=mk(RNG.uniform(1e8, 1e10, F)), W=mk(RNG.uniform(1e4, 1e6, F)),
        alpha=mk(RNG.uniform(0, 1, F)), delivered=mk(RNG.uniform(0, 1e6, F)),
        size=mk(RNG.uniform(5e5, 2e6, F)), line=mk(np.full(F, 12.5e9)),
        rtt0=mk(RNG.uniform(5e-6, 2e-5, F)), M=mk(M),
        q=mk(RNG.uniform(0, 2e5, L)), bw=mk(np.full(L, 12.5e9)),
    )


@pytest.mark.parametrize("F,L", [(1, 1), (7, 5), (128, 128), (129, 130),
                                 (256, 64), (300, 384)])
def test_cca_step_matches_ref(F, L):
    a = _cca_inputs(F, L)
    out = cca_step(**a, dt=1e-5)
    ref = cca_step_ref(**a, dt=1e-5)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-3)


def test_cca_step_conservation_property():
    """Link arrivals must equal the incidence-weighted sum of rates, and
    delivered must be monotone and size-capped — for any random state."""
    for _ in range(10):
        F = int(RNG.integers(1, 200))
        L = int(RNG.integers(1, 150))
        a = _cca_inputs(F, L)
        a["delivered"] = jnp.minimum(a["delivered"], a["size"])  # valid state
        R2, W2, a2, d2, arr = cca_step(**a, dt=2e-5)
        np.testing.assert_allclose(arr, np.asarray(R2) @ np.asarray(a["M"]),
                                   rtol=1e-4, atol=1.0)
        assert (np.asarray(d2) >= np.asarray(a["delivered"]) - 1e-3).all()
        assert (np.asarray(d2) <= np.asarray(a["size"]) + 1e-3).all()
        assert (np.asarray(R2) <= np.asarray(a["line"]) * (1 + 1e-6)).all()


def test_cca_step_fixed_point_when_uncongested():
    """With empty queues and windows below the BDP cap, windows grow
    (additive increase)."""
    F, L = 64, 16
    a = _cca_inputs(F, L)
    a["q"] = jnp.zeros(L)
    a["alpha"] = jnp.zeros(F)
    cap = 2 * np.asarray(a["line"]) * np.asarray(a["rtt0"])
    a["W"] = jnp.asarray(np.minimum(np.asarray(a["W"]), 0.5 * cap), jnp.float32)
    R2, W2, *_ = cca_step(**a, dt=1e-5)
    assert (np.asarray(W2) >= np.asarray(a["W"]) - 1e-6).all()


# --------------------------------------------------------------------- #
# steady_scan
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("F,H,w", [(1, 8, 8), (64, 32, 16), (128, 128, 128),
                                   (131, 64, 33), (500, 16, 7)])
def test_steady_scan_matches_ref(F, H, w):
    hist = RNG.uniform(1e8, 1e10, (F, H)).astype(np.float32)
    fl, mn = steady_scan(hist, w)
    fr, mr = steady_scan_ref(jnp.asarray(hist), w)
    np.testing.assert_allclose(fl, fr, rtol=1e-4)
    np.testing.assert_allclose(mn, mr, rtol=1e-5)


@given(st.integers(1, 60), st.integers(2, 32))
@settings(max_examples=30, deadline=None)
def test_steady_scan_flat_rows_have_zero_fluct(F, w):
    hist = np.tile(RNG.uniform(1e8, 1e10, (F, 1)).astype(np.float32), (1, w))
    fl, mn = steady_scan(hist, w)
    assert np.all(np.asarray(fl) < 1e-5)
    np.testing.assert_allclose(mn, hist[:, 0], rtol=1e-5)


def test_steady_scan_agrees_with_core_detector():
    from repro.core.steady import fluctuation_batch
    hist = RNG.uniform(1e8, 1e10, (37, 48)).astype(np.float32)
    fl, _ = steady_scan(hist, 48)
    np.testing.assert_allclose(fl, fluctuation_batch(hist), rtol=1e-4)


def test_steady_scan_atol_dead_band_scalar_batch_kernel_parity():
    """Regression: a zero-pinned metric (empty qlen) is steady under the
    scalar detector's atol band but was inf (0/0-unsteady) under the numpy
    oracle and the Pallas kernel — all three must agree now."""
    from repro.core.steady import fluctuation, fluctuation_batch
    atol = 2000.0
    hist = np.zeros((130, 32), np.float32)       # crosses the tile boundary
    hist[1] = 1500.0                             # pinned inside the band
    hist[2] = RNG.uniform(1e8, 1e10, 32)         # live row
    fl_k, _ = steady_scan(hist, 32, atol=atol)
    fl_b = fluctuation_batch(hist, atol)
    fl_r, _ = steady_scan_ref(jnp.asarray(hist), 32, atol=atol)
    np.testing.assert_allclose(fl_k, fl_b, rtol=1e-4)
    np.testing.assert_allclose(fl_k, fl_r, rtol=1e-4)
    for i in (0, 1, 2):
        assert float(fl_k[i]) == pytest.approx(
            fluctuation(list(hist[i]), atol), rel=1e-4), i
    assert float(fl_k[0]) == 0.0 and float(fl_k[1]) == 0.0
    # default atol=0 matches the scalar too: an exactly-zero row is inside
    # the (degenerate) band, a pinned-above-zero row is not
    fl0, _ = steady_scan(hist, 32)
    assert float(np.asarray(fl0)[0]) == 0.0
    assert float(np.asarray(fl0)[1]) == pytest.approx(
        fluctuation(list(hist[1])))


# --------------------------------------------------------------------- #
# flash_attention
# --------------------------------------------------------------------- #
CASES = [
    (1, 2, 2, 128, 64, True, None),
    (2, 4, 2, 256, 64, True, None),     # GQA 2:1
    (1, 8, 1, 128, 128, True, None),    # MQA
    (1, 4, 4, 200, 64, True, None),     # ragged (padding path)
    (1, 4, 2, 256, 64, True, 128),      # sliding window
    (1, 2, 2, 256, 64, False, None),    # bidirectional (encoder)
]


@pytest.mark.parametrize("B,Hq,Hk,S,D,causal,window", CASES)
def test_flash_attention_matches_ref(B, Hq, Hk, S, D, causal, window):
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hk, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hk, S, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=2e-2, atol=2e-2)


def test_flash_attention_rows_are_convex_combinations():
    """Property: every output row lies in the convex hull of V rows, so its
    per-dim max is bounded by V's max."""
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64)), jnp.float32)
    out = np.asarray(flash_attention(q, k, v))
    assert out.max() <= float(np.asarray(v).max()) + 1e-4
    assert out.min() >= float(np.asarray(v).min()) - 1e-4


# --------------------------------------------------------------------- #
# fluid engine end-to-end vs the packet oracle
# --------------------------------------------------------------------- #
def test_fluid_engine_matches_oracle_fair_share():
    from repro.net.fluid_jax import FluidScenario, fluid_converged_rates
    from repro.net.topology import leaf_spine_clos
    topo = leaf_spine_clos(8, leaf_down=4, n_spines=2)
    scn = FluidScenario.from_flows(topo, [(0, 0, 5, 4e6), (1, 1, 5, 4e6)])
    r = fluid_converged_rates(scn, steps=300)
    np.testing.assert_allclose(r["rates"].sum(), 12.5e9, rtol=0.15)
    np.testing.assert_allclose(r["rates"][0], r["rates"][1], rtol=0.1)
    rk = fluid_converged_rates(scn, steps=300, use_kernel=True)
    np.testing.assert_allclose(r["rates"], rk["rates"], rtol=1e-4)


def test_cca_step_bf16_inputs():
    """Kernel accepts bf16 state (wrapper upcasts to f32 internally)."""
    a = _cca_inputs(64, 32, dtype=jnp.bfloat16)
    out = cca_step(**a, dt=1e-5)
    ref = cca_step_ref(**{k: jnp.asarray(v, jnp.float32) for k, v in a.items()},
                       dt=1e-5)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o, np.float32), r,
                                   rtol=2e-2, atol=2e2)
