"""Distributed-runtime substrate: optimizer, data pipeline, checkpoint +
elastic restore, failure injection, gradient compression, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.data.pipeline import TokenPipeline
from repro.models.api import build_model
from repro.parallel.compression import (CompressionConfig,
                                        compress_decompress, init_residuals)
from repro.parallel.sharding import DEFAULT_RULES, resolve, rules_for
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.fault import FailureInjector, InjectedFailure
from repro.train.train_loop import TrainConfig, train


# --------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------- #
def test_adamw_matches_reference_numpy():
    cfg = O.AdamWConfig(lr=1e-2, warmup=0, weight_decay=0.0, clip_norm=1e9,
                        total_steps=10)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]])}
    state = O.init_state(params, cfg)
    p2, s2, _ = O.update(params, grads, state, cfg)
    # numpy reference
    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.05 * g ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    lr = O.schedule(cfg, jnp.asarray(1))
    ref = np.asarray(params["w"]) - float(lr) * mhat / (np.sqrt(vhat) + cfg.eps)
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)


def test_adamw_clipping():
    cfg = O.AdamWConfig(clip_norm=0.001, warmup=0)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = O.init_state(params, cfg)
    p2, _, _ = O.update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 0.01


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = ARCHS["granite-3-2b"].reduced()
    model = build_model(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=1)
    out = train(model, pipe, TrainConfig(
        steps=30, log_every=1000,
        opt=O.AdamWConfig(lr=3e-3, warmup=5, total_steps=30)))
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


# --------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------- #
def test_pipeline_deterministic_and_elastic():
    a = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3)
    b1 = a.next_batch()
    b2 = a.next_batch()
    a2 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3)
    np.testing.assert_array_equal(a2.next_batch()["tokens"], b1["tokens"])
    # elastic: 2 hosts each produce half of the same global batch
    h0 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3,
                       n_hosts=2, host_id=0)
    h1 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3,
                       n_hosts=2, host_id=1)
    merged = np.concatenate([h0.next_batch()["tokens"],
                             h1.next_batch()["tokens"]])
    np.testing.assert_array_equal(merged, b1["tokens"])
    # skip-ahead restore
    h0.restore({"step": 1, "seed": 3})
    np.testing.assert_array_equal(h0.next_batch()["tokens"], b2["tokens"][:4])


# --------------------------------------------------------------------- #
# checkpoint / restart / elastic re-mesh
# --------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_reshard(tmp_path):
    params = {"a": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
              "b": {"c": jnp.ones((3,))}}
    C.save(tmp_path, 7, params, n_shards=4)
    assert C.latest_step(tmp_path) == 7
    restored, manifest = C.restore(tmp_path, template={"params": params})
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(params["a"]))
    np.testing.assert_array_equal(np.asarray(restored["params"]["b"]["c"]),
                                  np.asarray(params["b"]["c"]))
    assert manifest["step"] == 7


@pytest.mark.slow
def test_failure_injection_and_resume(tmp_path):
    cfg = ARCHS["granite-3-2b"].reduced()
    model = build_model(cfg)

    def mkpipe():
        return TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=5)

    tcfg = TrainConfig(steps=12, log_every=1000, ckpt_dir=str(tmp_path),
                       ckpt_every=5,
                       opt=O.AdamWConfig(lr=1e-3, warmup=2, total_steps=12))
    inj = FailureInjector(fail_at_step=8)
    with pytest.raises(InjectedFailure):
        train(model, mkpipe(), tcfg, injector=inj)
    # restart: resumes from step 5 and completes
    out = train(model, mkpipe(), tcfg)
    assert out["resumed_from"] == 5
    assert len(out["losses"]) == 12 - 5
    # and the resumed run consumed the right data (pipeline step matches)
    uninterrupted = train(build_model(cfg), mkpipe(),
                          TrainConfig(steps=12, log_every=1000,
                                      opt=tcfg.opt))
    assert abs(out["losses"][-1] - uninterrupted["losses"][-1]) < 0.5


# --------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback_accumulates(kind):
    cfg = CompressionConfig(kind=kind, topk_frac=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32)}
    r = init_residuals(g)
    total_sent = jnp.zeros((64,))
    for _ in range(20):
        sent, r = compress_decompress(g, r, cfg)
        total_sent = total_sent + sent["w"]
    # with error feedback, the *cumulative* transmitted gradient converges
    # to the cumulative true gradient
    rel = float(jnp.linalg.norm(total_sent - 20 * g["w"])
                / jnp.linalg.norm(20 * g["w"]))
    assert rel < (0.15 if kind == "topk" else 0.05), rel


def test_compression_wire_ratio():
    assert CompressionConfig("topk", topk_frac=0.01).wire_ratio() == pytest.approx(0.03)
    assert CompressionConfig("int8").wire_ratio() == 0.25
    assert CompressionConfig("none").wire_ratio() == 1.0


# --------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------- #
def _fake_mesh(shape):
    devs = np.array(jax.devices() * int(np.prod(list(shape.values()))))
    # build a mesh object lazily only if enough devices; otherwise use Mesh
    import jax.sharding as js
    n = int(np.prod(list(shape.values())))
    return js.Mesh(np.array([jax.devices()[0]] * n).reshape(*shape.values()),
                   tuple(shape))


def test_resolve_divisibility_and_reuse():
    mesh = _fake_mesh({"data": 4, "model": 2})
    rules = dict(DEFAULT_RULES)
    # embed 8 divisible by data=4 -> sharded; heads 3 not divisible by 2 -> None
    spec = resolve(("embed", "heads"), (8, 3), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("data")
    # one mesh axis cannot be used twice in a tensor
    spec = resolve(("mlp", "heads"), (8, 8), rules, mesh)
    assert spec == jax.sharding.PartitionSpec("model")


def test_rules_for_moe_fallback():
    mesh = _fake_mesh({"data": 2, "model": 16})
    cfg = ARCHS["mixtral-8x22b"]
    rules = rules_for(cfg, mesh, "train")
    assert rules["expert"] is None          # 8 experts % 16 != 0
    assert rules["expert_mlp"] == "model"   # shard expert hidden instead
    cfg2 = ARCHS["deepseek-v3-671b"]
    rules2 = rules_for(cfg2, mesh, "train")
    assert rules2["expert"] == "model"      # 256 % 16 == 0
