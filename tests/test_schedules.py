"""Collective-schedule library: staged allreduces (tree, halving-doubling,
hierarchical), pipeline send/recv schedules, and their integration into the
workload traffic program via ``WorkloadSpec.collective``.

The acceptance checks mirror the repo's fidelity contract: every schedule's
phase DAG executes in strict step order under the :class:`WorkloadDriver`,
the wormhole kernel stays inside the paper's 1% FCT bound on every
schedule (memoization survives schedule diversity), and the analytic
engine lands in the right iteration-time ballpark.
"""
import pytest

from repro.api import run
from repro.api.scenario import Scenario, training_scenario
from repro.net.packet_sim import PacketSim
from repro.workload import presets
from repro.workload.collectives import FidAlloc, total_bytes
from repro.workload.driver import WorkloadDriver
from repro.workload.schedules import (SCHEDULES, allreduce_steps,
                                      halving_doubling_allreduce,
                                      hierarchical_allreduce,
                                      pipeline_bubble_fraction,
                                      pipeline_phases, steps_to_phases,
                                      tree_allreduce)

B = 1e6


# --------------------------------------------------------------------- #
# step builders: shapes, byte accounting, validation
# --------------------------------------------------------------------- #
def test_tree_allreduce_shape_and_mirror():
    members = list(range(8))
    steps = tree_allreduce(members, B, FidAlloc(), tag="t")
    assert [s[0] for s in steps] == ["t.up0", "t.up1", "t.up2",
                                     "t.down0", "t.down1", "t.down2"]
    assert [len(s[1]) for s in steps] == [4, 2, 1, 1, 2, 4]
    # every hop carries the full buffer; down rounds mirror the up rounds
    assert all(f.size == B for _, fl in steps for f in fl)
    up_pairs = {(f.src, f.dst) for _, fl in steps[:3] for f in fl}
    down_pairs = {(f.dst, f.src) for _, fl in steps[3:] for f in fl}
    assert up_pairs == down_pairs
    # fresh fids throughout (no flow id reused between rounds)
    fids = [f.fid for _, fl in steps for f in fl]
    assert len(fids) == len(set(fids))
    with pytest.raises(ValueError, match=">= 2 members"):
        tree_allreduce([0], B, FidAlloc())


def test_halving_doubling_shape_and_optimal_bytes():
    n = 8
    steps = halving_doubling_allreduce(list(range(n)), B, FidAlloc(), tag="h")
    assert [s[0] for s in steps] == ["h.rs0", "h.rs1", "h.rs2",
                                     "h.ag0", "h.ag1", "h.ag2"]
    assert all(len(fl) == n for _, fl in steps)
    # per-rank wire bytes match the ring-optimal 2(n-1)/n * B
    sent = sum(f.size for _, fl in steps for f in fl if f.src == 0)
    assert sent == pytest.approx(2 * (n - 1) / n * B)
    # XOR partners: round k of rs pairs i with i^(n/2^(k+1))
    assert {(f.src, f.dst) for f in steps[0][1]} == \
        {(i, i ^ 4) for i in range(n)}


def test_halving_doubling_requires_power_of_two():
    for n in (3, 6, 12):
        with pytest.raises(ValueError, match="power-of-two"):
            halving_doubling_allreduce(list(range(n)), B, FidAlloc())


def test_hierarchical_groups_by_rail_and_stays_local():
    # hosts 0,1,8,9 on an 8-GPU-per-server fabric: rails {0,8} and {1,9}
    meta = {"gpus_per_server": 8, "leaf_radix": 32}
    steps = hierarchical_allreduce([0, 1, 8, 9], B, FidAlloc(), tag="x",
                                   topo_meta=meta)
    assert [s[0] for s in steps] == ["x.rs", "x.xg", "x.ag"]
    # local stages never cross a rail; the exchange stage only crosses
    for name, fl in steps:
        for f in fl:
            same_rail = f.src % 8 == f.dst % 8
            assert same_rail == (name != "x.xg")
    # wire bytes: rs and ag each move (m-1)*B per local ring, the exchange
    # moves 2*(n_subs-1)*(B/m) per shard ring — here 2B + 2B + 2B
    assert total_bytes([f for _, fl in steps for f in fl]) == \
        pytest.approx(2 * 1 * B + 2 * 1 * B + 2 * (2 * 1 * B / 2))


def test_hierarchical_rejects_unequal_groups_and_chunks_one_domain():
    meta = {"gpus_per_server": 8, "leaf_radix": 32}
    with pytest.raises(ValueError, match="equal-size"):
        hierarchical_allreduce([0, 1, 2, 8, 9], B, FidAlloc(), topo_meta=meta)
    # a rail-local group (this repo's DP groups) falls through to equal
    # contiguous chunks of the ring
    steps = hierarchical_allreduce([0, 8, 16, 24], B, FidAlloc(), tag="c",
                                   topo_meta=meta)
    assert [s[0] for s in steps] == ["c.rs", "c.xg", "c.ag"]
    assert {f.src for f in steps[0][1]} == {0, 8, 16, 24}
    # prime-size single-domain group degenerates to one plain ring step
    steps = hierarchical_allreduce([0, 8, 16, 24, 32], B, FidAlloc(), tag="p",
                                   topo_meta=meta)
    assert [s[0] for s in steps] == ["p"]


def test_allreduce_steps_dispatch_and_unknown_name():
    assert set(SCHEDULES) == {"ring", "tree", "halving_doubling",
                              "hierarchical"}
    ring = allreduce_steps("ring", [0, 1, 2], B, FidAlloc())
    assert len(ring) == 1                       # flat overlapped baseline
    with pytest.raises(ValueError, match="unknown collective"):
        allreduce_steps("butterfly", [0, 1], B, FidAlloc())


# --------------------------------------------------------------------- #
# phase-DAG execution: steps are strict barriers under the driver
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("collective", ["tree", "halving_doubling",
                                        "hierarchical"])
def test_steps_execute_in_strict_order_under_driver(collective):
    topo = presets.topology_for(16)
    members = list(range(8))
    steps = allreduce_steps(collective, members, 4e5, FidAlloc(),
                            topo_meta={"gpus_per_server": 8})
    phases = steps_to_phases(steps, compute=1e-5)
    assert phases[0].compute == 1e-5 and phases[0].deps == []
    assert [p.deps for p in phases[1:]] == [[k] for k in range(len(phases) - 1)]

    fid2step = {f.fid: k for k, (_n, fl) in enumerate(steps) for f in fl}
    finish: dict[int, float] = {}
    sim = PacketSim(topo)
    sim.finish_listeners.append(lambda fl, t: finish.setdefault(fl.fid, t))
    drv = WorkloadDriver(sim, phases)
    sim.run()
    assert drv.finished
    assert set(finish) == set(fid2step)
    # barrier semantics: every flow of step k finishes after the whole of
    # step k-1 (it cannot even start earlier)
    for k in range(1, len(steps)):
        prev_done = max(t for f, t in finish.items() if fid2step[f] == k - 1)
        first_done = min(t for f, t in finish.items() if fid2step[f] == k)
        assert first_done >= prev_done


# --------------------------------------------------------------------- #
# pipeline schedules
# --------------------------------------------------------------------- #
def test_pipeline_phases_dag_and_bubble_fraction():
    S, M, t_fwd = 4, 6, 2e-4
    phases = pipeline_phases(list(range(S)), M, 1e3, FidAlloc(), t_fwd=t_fwd)
    assert len(phases) == 2 * S * M
    for i, p in enumerate(phases):
        assert all(d < i for d in p.deps)       # acyclic, earlier-only
    # first forward microbatch is dependency-free; everything backward
    # waits (transitively) on the last forward
    assert phases[0].deps == []
    topo = presets.topology_for(16)
    sim = PacketSim(topo)
    drv = WorkloadDriver(sim, phases)
    sim.run()
    # with negligible network time the DAG's critical path is the classic
    # GPipe (M+S-1) fwd slots + (M+S-1) bwd slots
    ideal = (M + S - 1) * (t_fwd + 2 * t_fwd)
    assert ideal <= drv.iteration_time == pytest.approx(ideal, rel=0.1)
    assert pipeline_bubble_fraction(S, M) == pytest.approx((S - 1) / (M + S - 1))
    assert pipeline_bubble_fraction(1, M) == 0.0
    with pytest.raises(ValueError, match=">= 2 stages"):
        pipeline_phases([0], M, 1e3, FidAlloc())
    with pytest.raises(ValueError, match=">= 1 microbatch"):
        pipeline_phases([0, 1], 0, 1e3, FidAlloc())


# --------------------------------------------------------------------- #
# WorkloadSpec integration: collective= selects the gradient-sync DAG
# --------------------------------------------------------------------- #
def test_ring_collective_is_the_exact_legacy_default():
    base = training_scenario(n_gpus=32, scale=1 / 256)
    ring = training_scenario(n_gpus=32, scale=1 / 256, collective="ring")
    # serialized form elides the default, so fingerprints/run_keys of every
    # pre-collective scenario are untouched
    assert "collective" not in base.to_dict()["workload"]
    assert ring.to_dict() == base.to_dict()
    assert ring.build_phases() == base.build_phases()


def test_collective_scenario_roundtrip_variant_and_naming():
    scn = training_scenario(n_gpus=32, scale=1 / 256, collective="tree")
    assert scn.name.endswith("-tree")
    back = Scenario.from_json(scn.to_json())
    assert back.to_dict() == scn.to_dict()
    assert back.workload.collective == "tree"
    var = scn.variant(name="v", collective="hierarchical")
    assert var.workload.collective == "hierarchical"
    assert scn.workload.collective == "tree"    # variant deep-copies
    with pytest.raises(ValueError, match="unknown collective"):
        training_scenario(n_gpus=32, collective="nope").build_phases()


def test_staged_collectives_grow_the_phase_dag():
    base = training_scenario(n_gpus=32, scale=1 / 256)
    tree = base.variant(name="t", collective="tree")
    pb, pt = base.build_phases(), tree.build_phases()
    # the single dp.s phase per stage splits into chained dp.s.k steps
    assert len(pt) > len(pb)
    names = [p.name for p in pt]
    assert "dp.s0.k0" in names and "dp.s0.k1" in names
    k0, k1 = names.index("dp.s0.k0"), names.index("dp.s0.k1")
    assert pt[k1].deps == [k0]


# --------------------------------------------------------------------- #
# acceptance: per-schedule analytic-vs-packet agreement + wormhole bound
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("collective", ["tree", "halving_doubling",
                                        "hierarchical"])
def test_schedule_fidelity_across_backends(collective):
    scn = training_scenario(n_gpus=64, cca="hpcc", scale=1 / 1024,
                            collective=collective)
    pkt = run(scn, backend="packet")
    ana = run(scn, backend="analytic")
    wh = run(scn, backend="wormhole")
    assert set(ana.fcts) == set(pkt.fcts) == set(wh.fcts)
    # analytic: right iteration-time ballpark on every schedule (it has no
    # packet effects, so per-flow FCTs are only ballpark too)
    assert ana.iteration_time == pytest.approx(pkt.iteration_time, rel=0.2)
    assert ana.fct_errors_vs(pkt).mean() < 0.7
    # wormhole: the paper's 1% bound survives schedule diversity
    assert wh.fct_errors_vs(pkt).mean() < 0.01
    assert wh.events_processed < pkt.events_processed
