"""Steady-state identification + theory bounds (paper §5.1/§5.2, Thm 2/3)."""
import math

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.core import theory
from repro.core.steady import (fluctuation, fluctuation_batch, is_steady,
                               rate_estimate, rate_estimate_batch,
                               steady_mask_batch)


def test_flat_signal_is_steady():
    assert is_steady([5.0] * 16, 16, 0.05)


def test_sawtooth_within_theta_is_steady():
    saw = [10.0 + 0.2 * math.sin(i) for i in range(32)]
    assert is_steady(saw, 32, 0.05)


def test_ramp_is_not_steady():
    ramp = [float(i) for i in range(1, 33)]
    assert not is_steady(ramp, 32, 0.05)


def test_short_history_not_steady():
    assert not is_steady([5.0] * 7, 8, 0.05)


@given(st.lists(st.floats(1.0, 100.0), min_size=8, max_size=64))
@settings(max_examples=200, deadline=None)
def test_theorem2_bound_holds(window):
    """Whenever the detector fires, the rate-estimate error vs the true
    window mean is within θ/(1-θ) of any point in the window — the premise
    of Theorem 2 (|R(t)-R̄| ≤ (max-min) < θ·R̂)."""
    theta = 0.08
    l = len(window)
    if not is_steady(window, l, theta):
        return
    r_hat = rate_estimate(window, l)
    r_bar = sum(window) / l
    assert abs(r_hat - r_bar) / r_bar <= theory.rate_error_bound(theta) + 1e-12
    for r in window:
        assert abs(r - r_bar) / r_bar < theta / (1 - theta) + 1e-9


def test_theorem3_duration_bound():
    """T̂ = F/R̂ vs T̄ = F/R̄ differ by < θ when the window passed the test."""
    theta = 0.05
    rng = np.random.default_rng(0)
    for _ in range(100):
        base = rng.uniform(1, 20)
        window = base * (1 + rng.uniform(-theta / 2.5, theta / 2.5, size=32))
        if not is_steady(list(window), 32, theta):
            continue
        r_hat = rate_estimate(list(window), 32)
        r_bar = window.mean()
        err = abs(1 / r_hat - 1 / r_bar) * r_bar
        assert err < theory.duration_error_bound(theta)


def test_batch_matches_scalar():
    rng = np.random.default_rng(1)
    hist = rng.uniform(1, 10, size=(17, 23))
    fl = fluctuation_batch(hist)
    for i in range(17):
        assert abs(fl[i] - fluctuation(list(hist[i]))) < 1e-12
    np.testing.assert_allclose(rate_estimate_batch(hist), hist.mean(-1))
    mask = steady_mask_batch(hist, 0.3)
    assert mask.shape == (17,)


def test_theta_guidance_monotone():
    """More flows / slower links -> larger steady sawtooth -> larger θ."""
    t1 = theory.theta_guidance(2, 12.5e9, 10e-6)
    t2 = theory.theta_guidance(8, 12.5e9, 10e-6)
    assert t2 > t1
    assert theory.theta_guidance(2, 1.25e9, 10e-6) > t1


def test_l_guidance_covers_period():
    l = theory.l_guidance(2, 12.5e9, 10e-6, 64_000, sample_interval_s=4e-6)
    assert l >= 4
    # the window span must cover >= 2 sawtooth periods
    t_c = theory.sawtooth_period_rtts(2, 12.5e9, 10e-6, 64_000) * 10e-6
    assert (l - 1) * 4e-6 >= 2 * t_c - 4e-6


# --------------------------------------------------------------------- #
# atol dead-band parity: scalar <-> batch (regression — the batch forms
# dropped the zero-pinned-metric special case the scalar detector has)
# --------------------------------------------------------------------- #
def test_batch_atol_dead_band_matches_scalar():
    """A zero-pinned metric (e.g. an empty qlen under HPCC) is steady by
    definition: the scalar detector returns fluctuation 0 inside the atol
    band; the vectorized oracle must agree instead of reporting inf/0/0."""
    atol = 2000.0
    hist = np.zeros((4, 16))
    hist[1] = 1500.0                     # pinned inside the band
    hist[2] = np.linspace(0, 1e6, 16)    # genuinely moving
    hist[3] = 5e5                        # steady but far above the band
    fb = fluctuation_batch(hist, atol)
    for i in range(4):
        assert fb[i] == pytest.approx(fluctuation(list(hist[i]), atol)), i
    mask = steady_mask_batch(hist, 0.05, atol)
    assert mask.tolist() == [True, True, False, True]
    # default atol=0 still matches the scalar: an exactly-zero row has
    # mx <= 0 and is steady-by-definition there too (the old batch form
    # returned inf for it — that divergence was the bug)
    assert fluctuation_batch(hist)[0] == fluctuation(list(hist[0])) == 0.0


@given(st.lists(st.floats(0.0, 1e4), min_size=4, max_size=32),
       st.floats(0.0, 5e3))
@settings(max_examples=40, deadline=None)
def test_batch_matches_scalar_with_atol_property(row, atol):
    hist = np.asarray([row])
    fb = float(fluctuation_batch(hist, atol)[0])
    fs = fluctuation(row, atol)
    if math.isinf(fs):
        assert math.isinf(fb)
    else:
        assert fb == pytest.approx(fs, rel=1e-9, abs=1e-12)
