"""Per-architecture smoke tests (assignment deliverable f): a REDUCED config
of the same family runs one forward/train step and one decode step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation) — here we also check their
parameter counts against the published sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.models.api import build_model

ALL = sorted(ARCHS)
# the big reduced configs still dominate tier-1 wall clock — deselect the
# end-to-end smokes with -m "not slow" for a quick loop
_HEAVY = {"jamba-v0.1-52b", "gemma3-27b", "xlstm-125m", "deepseek-v3-671b"}
SMOKE = [pytest.param(n, marks=pytest.mark.slow) if n in _HEAVY else n
         for n in ALL]


def smoke_batch(cfg, B=2, S=64):
    t = lambda b, s: jnp.zeros((b, s), jnp.int32)
    if cfg.enc_dec:
        return {"prefix_embeds": jnp.full((B, S, cfg.d_model), 0.01, jnp.float32),
                "tokens": t(B, S), "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision_patches":
        return {"prefix_embeds": jnp.full((B, cfg.n_patches, cfg.d_model), 0.01,
                                          jnp.float32),
                "tokens": t(B, S - cfg.n_patches),
                "labels": jnp.ones((B, S - cfg.n_patches), jnp.int32)}
    return {"tokens": t(B, S), "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("name", SMOKE)
def test_smoke_train_step(name):
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: m.loss(p, batch)))(params)
    assert np.isfinite(float(loss)), name
    assert loss.shape == ()
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, name


@pytest.mark.parametrize("name", ALL)
def test_smoke_decode_step(name):
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 128
    cache = m.init_cache(B, S)
    logits, cache2 = jax.jit(
        lambda p, c, t: m.decode_step(p, c, t, 7))(params, cache,
                                                   jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.vocab), name
    assert bool(jnp.isfinite(logits).all()), name
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", SMOKE)
def test_smoke_prefill(name):
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg)
    batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: m.prefill(p, b))(params, batch)
    assert logits.shape == (2, cfg.vocab), name
    assert bool(jnp.isfinite(logits).all()), name


EXPECTED_B = {
    "xlstm-125m": (0.10, 0.21), "mixtral-8x22b": (135, 146),
    "deepseek-v3-671b": (650, 690), "llava-next-34b": (32, 36),
    "granite-3-2b": (2.2, 2.9), "mistral-nemo-12b": (11, 13.5),
    "mistral-large-123b": (118, 128), "gemma3-27b": (26, 30),
    "jamba-v0.1-52b": (49, 55), "whisper-large-v3": (1.4, 1.8),
}


@pytest.mark.parametrize("name", ALL)
def test_full_config_param_count(name):
    n = build_model(ARCHS[name]).n_params / 1e9
    lo, hi = EXPECTED_B[name]
    assert lo <= n <= hi, f"{name}: {n:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("name", ALL)
def test_input_specs_cover_all_cells(name):
    m = build_model(ARCHS[name])
    for sname in SHAPES:
        if sname == "long_500k" and not ARCHS[name].subquadratic:
            continue
        specs = m.input_specs(sname)
        assert specs, (name, sname)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_decode_matches_prefill_continuation():
    """Decode after prefill must give the same next-token logits as running
    the full sequence through the train forward (dense arch)."""
    cfg = ARCHS["granite-3-2b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    # reference: prefill over S+1 tokens -> logits for the last position
    ref_logits, _ = m.prefill(params, {"tokens": toks})
    # prefill S tokens then decode token S
    _, cache = m.prefill(params, {"tokens": toks[:, :S]})
    # grow the prefill cache to decode capacity
    full = m.init_cache(B, S + 8)
    def blend(dst, src):
        if src.ndim >= 3 and src.shape[2] <= dst.shape[2] and src.ndim == dst.ndim:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return src.astype(dst.dtype)
    cache = jax.tree.map(blend, full, cache)
    logits, _ = m.decode_step(params, cache, toks[:, S:S + 1], S)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_dispatch_variants_equivalent():
    """gather (sort+scatter) and einsum (GShard one-hot) dispatch are the
    same function; fp8 a2a and save_moe remat stay finite and close."""
    import dataclasses
    cfg = ARCHS["mixtral-8x22b"].reduced()
    batch = smoke_batch(cfg)
    m_g = build_model(dataclasses.replace(cfg, moe_dispatch="gather"))
    m_e = build_model(dataclasses.replace(cfg, moe_dispatch="einsum"))
    params = m_g.init(jax.random.PRNGKey(0))
    lg = float(m_g.loss(params, batch))
    le = float(m_e.loss(params, batch))
    assert abs(lg - le) < 1e-3, (lg, le)
    m_f8 = build_model(dataclasses.replace(cfg, moe_a2a_dtype="float8_e4m3fn",
                                           remat=True, remat_policy="save_moe"))
    lf = float(jax.jit(jax.value_and_grad(lambda p: m_f8.loss(p, batch)))(params)[0])
    assert np.isfinite(lf) and abs(lf - lg) < 0.3


@pytest.mark.parametrize("name", [
    "mixtral-8x22b", "deepseek-v3-671b",
    pytest.param("jamba-v0.1-52b", marks=pytest.mark.slow), "xlstm-125m"])
def test_decode_matches_prefill_continuation_all_mixers(name):
    """Decode-after-prefill == full-sequence forward for SWA ring caches,
    compressed MLA caches, Mamba/mLSTM/sLSTM recurrent state."""
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab)
    ref_logits, _ = m.prefill(params, {"tokens": toks})
    _, cache = m.prefill(params, {"tokens": toks[:, :S]})
    full = m.init_cache(B, S + 8)

    def blend(dst, src):
        if src.ndim == dst.ndim and src.shape != dst.shape:
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * dst.ndim)
        return src.astype(dst.dtype)
    cache = jax.tree.map(blend, full, cache)
    logits, _ = m.decode_step(params, cache, toks[:, S:S + 1], S)
    a = np.asarray(logits, np.float32)
    b = np.asarray(ref_logits, np.float32)
    # MoE routing is a discrete boundary: tiny numeric deltas can flip an
    # expert choice, so compare distributionally + argmax for those archs
    if ARCHS[name].moe_experts:
        assert np.argmax(a) == np.argmax(b), name
        assert np.abs(a - b).max() < 0.25, (name, np.abs(a - b).max())
    else:
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)
