"""Chaos-injection subsystem: deterministic seeded perturbations declared
on the scenario and honored identically by every engine.

The acceptance contract: an empty injector list is bit-identical to the
pre-chaos run; phase-level injectors (mice, stragglers) expand into the
same phase DAG for every backend; link-level injectors retarget port
capacities mid-run on the packet family and are *refused* (never silently
dropped) by flow-level backends; and the wormhole/hybrid kernels react to
a capacity change (skip-back / promotion) instead of replaying stale
rates.
"""
import pytest

from repro.api import run
from repro.api.scenario import Scenario, training_scenario
from repro.net.chaos import CHAOS_FID_BASE, DOWN_FACTOR, ChaosPlan
from test_api import wave_scenario

# port 25 carries the wave traffic on wave_scenario's little clos (probed
# once; any change to the topology builder shows up as a no-op injector)
HOT_LINK = 25
DEGRADE = {"kind": "degrade_link", "link": HOT_LINK, "t": 0.001,
           "factor": 0.25}
MICE = {"kind": "mice", "seed": 7, "rate": 2000.0, "size": 4e4,
        "duration": 0.004}


# --------------------------------------------------------------------- #
# declaration parsing: loud validation, stable plans
# --------------------------------------------------------------------- #
def test_parse_validates_injector_declarations():
    cases = [
        ([{"seed": 1}], "'kind' key"),
        ([{"kind": "meteor"}], "unknown kind"),
        ([{"kind": "mice", "seed": 0, "rate": 100.0}], "missing keys"),
        ([{**MICE, "bogus": 1}], "unknown keys"),
        ([{**MICE, "rate": 0.0}], "rate/size must be > 0"),
        ([{"kind": "straggler", "factor": 1.5}], "not both / neither"),
        ([{"kind": "straggler", "factor": 1.5, "seed": 0, "ranks": [1]}],
         "not both / neither"),
        ([{"kind": "straggler", "ranks": [1], "factor": 0.0}],
         "factor must be > 0"),
        ([{"kind": "degrade_link", "link": 1, "t": 0.1, "factor": 1.5}],
         r"in \(0, 1\]"),
        ([{"kind": "degrade_link", "link": 1, "t": -0.1, "factor": 0.5}],
         ">= 0"),
        ([{"kind": "degrade_link", "link": 1, "t": 0.2, "factor": 0.5,
           "t_end": 0.1}], "t_end must be > t"),
        ([{"kind": "link_flap", "link": 1, "t_down": 0.2, "t_up": 0.1}],
         "t_up must be > t_down"),
    ]
    for chaos, match in cases:
        with pytest.raises(ValueError, match=match):
            ChaosPlan.parse(chaos)


def test_plan_splits_and_orders_link_events():
    plan = ChaosPlan.parse([
        {"kind": "link_flap", "link": 3, "t_down": 0.004, "t_up": 0.006},
        {"kind": "degrade_link", "link": 1, "t": 0.002, "factor": 0.5,
         "t_end": 0.005},
        {"kind": "link_down", "link": 2, "t": 0.001},
        MICE,
        {"kind": "straggler", "ranks": [3], "factor": 1.5},
    ])
    assert len(plan.mice) == 1 and len(plan.stragglers) == 1
    assert [(e.t, e.link, e.factor) for e in plan.link_events] == [
        (0.001, 2, DOWN_FACTOR), (0.002, 1, 0.5), (0.004, 3, DOWN_FACTOR),
        (0.005, 1, 1.0), (0.006, 3, 1.0)]
    assert plan.has_link_events
    assert not ChaosPlan.parse([MICE]).has_link_events


def test_straggler_map_explicit_seeded_and_merged():
    plan = ChaosPlan.parse([
        {"kind": "straggler", "ranks": [2, 5], "factor": 1.5},
        {"kind": "straggler", "seed": 3, "count": 2, "factor": 2.0},
    ])
    m = plan.straggler_map(16)
    assert m[2] in (1.5, 3.0) and m[5] in (1.5, 3.0)
    seeded = {r for r, f in m.items() if f in (2.0, 3.0)}
    assert len(seeded) == 2
    # seeded draws are deterministic
    assert plan.straggler_map(16) == m
    # count clamps to the rank universe
    big = ChaosPlan.parse([{"kind": "straggler", "seed": 0, "count": 99,
                            "factor": 1.1}])
    assert len(big.straggler_map(4)) == 4


def test_mice_phases_deterministic_and_seed_sensitive():
    plan = ChaosPlan.parse([MICE])
    a, b = plan.mice_phases(16), plan.mice_phases(16)
    assert len(a) > 3
    assert [(p.name, p.compute, p.flows[0].src, p.flows[0].dst)
            for p in a] == \
        [(p.name, p.compute, p.flows[0].src, p.flows[0].dst) for p in b]
    for p in a:
        assert p.deps == [] and len(p.flows) == 1
        f = p.flows[0]
        assert f.fid >= CHAOS_FID_BASE and f.src != f.dst
        assert 0 <= f.src < 16 and 0 <= f.dst < 16
        assert f.tag == "chaos.mice"
    other = ChaosPlan.parse([{**MICE, "seed": 8}]).mice_phases(16)
    assert [p.compute for p in other] != [p.compute for p in a]


# --------------------------------------------------------------------- #
# serialization: chaos rides the scenario, empty list is elided
# --------------------------------------------------------------------- #
def test_chaos_serialization_roundtrip_and_default_elision():
    scn = wave_scenario().variant(name="c", chaos=[MICE, DEGRADE])
    back = Scenario.from_json(scn.to_json())
    assert back.to_dict() == scn.to_dict()
    assert back.chaos == [MICE, DEGRADE]
    # empty chaos serializes exactly as the pre-chaos schema
    assert "chaos" not in wave_scenario().to_dict()
    assert "chaos" not in scn.variant(name="c2", chaos=[]).to_dict()
    # auto-named training scenarios key on the chaos digest
    a = training_scenario(n_gpus=32, chaos=[MICE])
    b = training_scenario(n_gpus=32, chaos=[{**MICE, "seed": 8}])
    assert "-chaos" in a.name and a.name != b.name


# --------------------------------------------------------------------- #
# acceptance: empty injector list is bit-identical, seeds reproduce
# --------------------------------------------------------------------- #
def test_empty_chaos_is_bit_identical():
    base = run(wave_scenario(), backend="packet")
    empty = run(wave_scenario().variant(name="waves", chaos=[]),
                backend="packet")
    assert empty.fcts == base.fcts
    assert empty.events_processed == base.events_processed


def test_chaos_runs_are_reproducible():
    scn = wave_scenario().variant(name="rep", chaos=[MICE, DEGRADE])
    a = run(scn, backend="packet")
    b = run(Scenario.from_json(scn.to_json()), backend="packet")
    assert a.fcts == b.fcts and a.events_processed == b.events_processed


# --------------------------------------------------------------------- #
# phase-level injectors across engines
# --------------------------------------------------------------------- #
def test_mice_seen_identically_by_all_backends():
    scn = wave_scenario().variant(name="mice", chaos=[MICE])
    pkt = run(scn, backend="packet")
    mice_fids = {f for f in pkt.fcts if f >= CHAOS_FID_BASE}
    assert mice_fids
    for backend in ("wormhole", "analytic", "fluid"):
        r = run(scn, backend=backend)
        assert set(r.fcts) == set(pkt.fcts)
    wh = run(scn, backend="wormhole")
    assert wh.fct_errors_vs(pkt).mean() < 0.01


def test_straggler_slows_the_iteration():
    base = training_scenario(n_gpus=32, scale=1 / 256)
    slow = training_scenario(n_gpus=32, scale=1 / 256, chaos=[
        {"kind": "straggler", "ranks": [0], "factor": 2.0}])
    rb = run(base, backend="analytic")
    rs = run(slow, backend="analytic")
    assert rs.iteration_time > rb.iteration_time * 1.05


# --------------------------------------------------------------------- #
# link-level injectors: capacity retargeting on the packet family
# --------------------------------------------------------------------- #
def test_degrade_and_flap_stretch_fcts():
    base = run(wave_scenario(), backend="packet")
    deg = run(wave_scenario().variant(name="deg", chaos=[DEGRADE]),
              backend="packet")
    assert deg.fcts[0] > base.fcts[0] * 1.5
    flap = run(wave_scenario().variant(name="flap", chaos=[
        {"kind": "link_flap", "link": HOT_LINK, "t_down": 0.001,
         "t_up": 0.002}]), backend="packet")
    # a 1ms dead port hurts wave 1 even more than a permanent 25% degrade
    assert flap.fcts[0] > deg.fcts[0] > base.fcts[0]
    # but it recovers: wave 2 (starts after t_up) is untouched
    assert flap.fcts[4] == pytest.approx(base.fcts[4], rel=1e-6)
    # a bounded degrade (t_end restore) sits between clean and permanent
    rest = run(wave_scenario().variant(name="rest", chaos=[
        {**DEGRADE, "t_end": 0.002}]), backend="packet")
    assert base.fcts[0] < rest.fcts[0] <= deg.fcts[0]


def test_link_chaos_out_of_range_and_flow_level_refusals():
    bad = wave_scenario().variant(name="oob", chaos=[
        {"kind": "degrade_link", "link": 10_000, "t": 0.001, "factor": 0.5}])
    with pytest.raises(ValueError, match="out of range"):
        run(bad, backend="packet")
    scn = wave_scenario().variant(name="ref", chaos=[DEGRADE])
    for backend in ("analytic", "fluid", "learned"):
        with pytest.raises(ValueError, match="no port queues"):
            run(scn, backend=backend)
    with pytest.raises(ValueError, match="intra_workers=1"):
        run(scn, backend="packet", parallel="partitions", intra_workers=2)
    # phase-level chaos stays allowed on flow-level backends
    assert run(wave_scenario().variant(name="ok", chaos=[MICE]),
               backend="analytic") is not None


def test_sharded_loop_observes_chaos_identically():
    scn = wave_scenario().variant(name="shard", chaos=[DEGRADE])
    plain = run(scn, backend="packet")
    shard = run(scn, backend="packet", parallel="partitions")
    assert shard.fcts == plain.fcts
    assert shard.events_processed == plain.events_processed


# --------------------------------------------------------------------- #
# acceptance: kernels react to capacity changes instead of going stale
# --------------------------------------------------------------------- #
def test_wormhole_skips_back_and_stays_accurate_under_chaos():
    scn = wave_scenario().variant(name="whchaos", chaos=[DEGRADE])
    pkt = run(scn, backend="packet")
    wh = run(scn, backend="wormhole")
    rep = wh.kernel_report
    assert rep["skip_backs"] >= 1          # a parked partition re-measured
    assert rep["parks"] > 0
    assert wh.fct_errors_vs(pkt).mean() < 0.01
    assert wh.events_processed < pkt.events_processed


def test_wormhole_memo_entries_do_not_leak_across_capacity_regimes():
    """The second wave runs under degraded capacity: its partitions must
    miss the entries memoized at full capacity (the FCG line-rate labels
    track the live capacities), not replay the clean-regime rates."""
    scn = wave_scenario().variant(name="leak", chaos=[
        {"kind": "degrade_link", "link": HOT_LINK, "t": 0.01,
         "factor": 0.25}])          # between the two waves
    pkt = run(scn, backend="packet")
    wh = run(scn, backend="wormhole")
    assert wh.fct_errors_vs(pkt).mean() < 0.01


def test_hybrid_promotes_flow_lanes_and_stays_close_under_chaos():
    scn = wave_scenario().variant(name="hychaos", chaos=[DEGRADE])
    pkt = run(scn, backend="packet")
    hy = run(scn, backend="hybrid")
    rep = hy.kernel_report
    assert rep["promotions"] >= 1          # a demoted lane re-packetized
    assert rep["demotions"] > 0
    assert hy.fct_errors_vs(pkt).mean() < 0.05
