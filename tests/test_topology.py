import numpy as np
import pytest

from repro.net.topology import fat_tree, leaf_spine_clos, rail_optimized_fat_tree


@pytest.mark.parametrize("topo", [
    fat_tree(4), leaf_spine_clos(16, leaf_down=4, n_spines=2),
    rail_optimized_fat_tree(4, gpus_per_server=4, leaf_radix=4, n_spines=2),
])
def test_paths_valid_and_deterministic(topo):
    rng = np.random.default_rng(0)
    for fid in range(50):
        src, dst = rng.choice(topo.n_hosts, size=2, replace=False)
        p1 = topo.route(int(src), int(dst), fid)
        p2 = topo.route(int(src), int(dst), fid)
        assert p1 == p2, "ECMP must be deterministic per flow id"
        assert int(topo.link_src[p1[0]]) == src
        assert int(topo.link_dst[p1[-1]]) == dst
        for a, b in zip(p1, p1[1:]):
            assert int(topo.link_dst[a]) == int(topo.link_src[b])


def test_ecmp_spreads_flows():
    topo = leaf_spine_clos(32, leaf_down=8, n_spines=4)
    first_hops = {topo.route(0, 31, fid)[1] for fid in range(64)}
    assert len(first_hops) > 1, "different flows should spread over spines"


def test_fat_tree_counts():
    k = 4
    topo = fat_tree(k)
    assert topo.n_hosts == k ** 3 // 4
    # every host has exactly one uplink cable (2 directed links)
    for h in range(topo.n_hosts):
        assert len(topo.adj[h]) == 1


def test_same_host_pair_different_flows_may_differ_but_same_len():
    topo = fat_tree(4)
    lens = {len(topo.route(0, 15, fid)) for fid in range(16)}
    assert len(lens) == 1, "equal-cost paths only"
