"""CI smoke: the shared campaign-store service, end to end over real HTTP.

Starts ``python -m repro serve`` as a subprocess, drives a 2-worker
wormhole sweep through it, then proves the service properties the README
advertises: a fresh host with empty local state gets cache hits and warm
replays straight off the server, TTL GC expires old records server-side,
and killing the server degrades commits to the local fallback instead of
losing them.

Runs in the numpy-only ``store-service`` CI job — the serve/campaign
closure must stay jax-free (reprolint S402).  A real file with a
``__main__`` guard because the 2-worker sweep spawns processes that
re-import the main module.  Invoked as:

    PYTHONPATH=src:. python tests/smoke/store_service_smoke.py
"""
import os
import subprocess
import sys
import tempfile
import time
import warnings

from examples.quickstart import make_scenario
from repro.api import Campaign, RunStore


def main():
    scn = make_scenario()
    variants = [scn.variant(name=f"s{s:g}", size_scale=s)
                for s in (1.0, 1.1, 1.2, 1.3)]
    with tempfile.TemporaryDirectory() as td:
        served = os.path.join(td, "served")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro", "serve", "-c", served,
             "--port", "0", "-q"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        try:
            line = proc.stdout.readline()
            assert "serving campaign store at http://" in line, line
            url = line.split()[4]

            # host A: 2-worker sweep committing through the server
            with Campaign.open(os.path.join(td, "hostA"), store=url) as a:
                cold = a.sweep(variants, backend="wormhole", workers=2)
            assert all(r is not None for r in cold)

            # host B: fresh process-equivalent, empty local state — every
            # completed run is a cache hit off the server, and a *new*
            # variant fast-forwards warm off the served SimDB
            with Campaign.open(url) as b:
                kinds = []
                b.subscribe(lambda e: kinds.append(e.kind))
                again = b.sweep(variants, backend="wormhole")
                assert kinds.count("cache_hit") == 4, kinds
                assert "started" not in kinds, kinds
                assert [r.fcts for r in again] == [r.fcts for r in cold]
                warm = b.submit(scn.variant(name="s1.4", size_scale=1.4),
                                backend="wormhole").result
            assert warm.kernel_report["run_db_hits"] > 0, warm.kernel_report
            assert warm.events_processed < cold[0].events_processed / 10

            # TTL GC: age one record on the server, expire it remotely
            store = RunStore(os.path.join(served, "runs"))
            victim = store.keys()[0]
            old = time.time() - 3600
            os.utime(os.path.join(served, "runs", f"{victim}.json"),
                     (old, old))
            with Campaign.open(url) as c:
                removed = c.gc(ttl=600)
                assert removed == [victim], removed
                assert c.store.peek(victim) is None

            # server loss: commits degrade to the local fallback, durably
            with Campaign.open(os.path.join(td, "hostA"), store=url) as a:
                proc.terminate()
                proc.wait(timeout=10)
                a.remote.retries, a.remote.backoff = 1, 0.05
                with warnings.catch_warnings(record=True) as caught:
                    warnings.simplefilter("always")
                    h = a.submit(scn.variant(name="s2", size_scale=2.0),
                                 backend="wormhole")
                assert h.result is not None
                assert a.remote.degraded and len(a.remote.pending) == 1
                assert any("degrading to local-only" in str(w.message)
                           for w in caught), [str(w.message) for w in caught]
            local = RunStore(os.path.join(td, "hostA", "runs"))
            assert h.key in local.keys()
        finally:
            if proc.poll() is None:
                proc.terminate()
            proc.wait(timeout=10)
    # the whole served flow must run without jax (reprolint S402 statically
    # gates the serve/campaign closure; this is the runtime counterpart —
    # the CI job installs numpy only, so an accidental import would crash
    # there, but guard here too so local runs catch it)
    assert "jax" not in sys.modules, "store service path must stay jax-free"
    print("store service smoke ok: 2-worker served sweep, 4 cache hits on a"
          f" fresh host, warm replay {warm.events_processed} events (cold "
          f"{cold[0].events_processed}), TTL GC expired 1, degraded commit "
          "kept locally on server loss")


if __name__ == "__main__":
    main()
