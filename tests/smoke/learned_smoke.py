"""CI smoke: the learned-engine loop — fit on a 20-record synthetic
campaign of wormhole ground truth, predict held-out scenarios, and bound
the error.

A real file with a ``__main__`` guard like its siblings.  Invoked by the
CI matrix as:

    PYTHONPATH=src:. python tests/smoke/learned_smoke.py
"""
import numpy as np

from repro.api import Campaign, Scenario, get_engine
from repro.learned import fit, heldout_fct_error
from repro.net.flows import FlowSpec


def wave_scenario(size_scale: float, name: str) -> Scenario:
    flows, fid = [], 0
    for wave, start in enumerate((0.0, 0.02)):
        for i in range(4):
            flows.append(FlowSpec(fid=fid, src=i, dst=8 + i + wave,
                                  size=4e5 * size_scale, start=start,
                                  cca="dctcp", tag=f"w{wave}"))
            fid += 1
    return Scenario.from_dict({
        "name": name, "topology": {"kind": "clos", "params": {"n_hosts": 16}},
        "flows": [f.__dict__ for f in flows], "kernel": {}, "sim": {}})


def main():
    family = [wave_scenario(0.5 + 0.075 * i, name=f"smoke{i}")
              for i in range(20)]
    with Campaign.in_memory(name="learned-smoke") as camp:
        camp.sweep(family, backend="wormhole")
        ds = camp.export_dataset()
    assert ds.n_records == 20, ds.n_records
    assert ds.n_heldout_records > 0, "run_key split held nothing out"

    params = fit(ds, seed=0, steps=500)
    err = heldout_fct_error(params, ds)
    assert err < 0.10, f"held-out mean FCT error {err:.4f} over the bound"

    # a second fixed-seed fit must reproduce the model bit-for-bit
    again = fit(ds, seed=0, steps=500)
    assert again.fingerprint == params.fingerprint, "fit not deterministic"

    # serve a fresh in-range query through the engine
    query = wave_scenario(1.03, name="query")
    r = get_engine("learned").run(query, params=params)
    assert set(r.fcts) == set(range(8)) and all(
        v > 0 for v in r.fcts.values())
    assert r.extras["learned"]["params_fingerprint"] == params.fingerprint

    from repro.api import run
    truth = run(query, backend="wormhole")
    qerr = float(np.mean([abs(r.fcts[f] - truth.fcts[f]) / truth.fcts[f]
                          for f in truth.fcts]))
    assert qerr < 0.10, f"query error {qerr:.4f} vs wormhole over the bound"
    print(f"learned smoke ok: {ds.n_records} records "
          f"({ds.n_heldout_records} held out), "
          f"held-out err {err * 100:.2f}%, query err {qerr * 100:.2f}%, "
          f"fingerprint {params.fingerprint}")


if __name__ == "__main__":
    main()
