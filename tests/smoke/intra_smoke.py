"""CI smoke: the sharded loop with ``intra_workers=2`` parallel fan-out
must be bit-identical to the serial loop on the quickstart scenario.

A real file with a ``__main__`` guard — spawn-based workers re-import the
main module.  Invoked by the CI matrix as:

    PYTHONPATH=src:. python tests/smoke/intra_smoke.py
"""
from examples.quickstart import make_scenario
from repro.api import run


def main():
    scn = make_scenario()
    serial = run(scn, backend="wormhole")
    par = run(scn, backend="wormhole", parallel="partitions",
              intra_workers=2)
    assert par.fcts == serial.fcts, "fan-out diverged from serial"
    assert par.events_processed == serial.events_processed
    print("intra_workers=2 smoke ok:", par.events_processed,
          "events,", par.extras["shard"]["dispatches"], "dispatches")


if __name__ == "__main__":
    main()
