"""CI smoke: 2-worker ``run_many`` warm start through a persisted SimDB.

A real file with a ``__main__`` guard — the spawn-based worker pool
re-imports the main module, which heredoc/stdin scripts cannot support.
Invoked by the CI matrix as:

    PYTHONPATH=src:. python tests/smoke/warm_start_smoke.py
"""
import os
import tempfile

from examples.quickstart import make_scenario
from repro.api import run_many
from repro.core.memo import SimDB


def main():
    scn = make_scenario()
    variants = [scn.variant(name=f"q{s:g}", size_scale=s)
                for s in (1.0, 1.1)]
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "simdb.json")
        db = SimDB()
        cold = run_many(variants, backend="wormhole", workers=2, db=db)
        db.save(path)
        warm = run_many([scn.variant(name="q1.2", size_scale=1.2)],
                        backend="wormhole", workers=2,
                        db=SimDB.load_or_new(path))[0]
    assert warm.kernel_report["run_db_hits"] > 0, warm.kernel_report
    assert warm.events_processed < cold[0].events_processed / 10
    print("2-worker warm-start smoke ok:",
          [r.events_processed for r in cold], "->",
          warm.events_processed, "events")


if __name__ == "__main__":
    main()
