"""CI smoke: the hybrid backend on the quickstart scenario.

``fidelity="packet"`` must be bit-identical to the packet oracle, and
``fidelity="auto"`` must cut packet-lane events >= 3x while staying under
1% mean FCT error.  Invoked by the CI matrix as:

    PYTHONPATH=src:. python tests/smoke/hybrid_smoke.py
"""
from examples.quickstart import make_scenario
from repro.api import run


def main():
    scn = make_scenario()
    base = run(scn, backend="packet")
    exact = run(scn, backend="hybrid", fidelity="packet")
    assert exact.fcts == base.fcts, "fidelity=packet diverged from oracle"
    assert exact.events_processed == base.events_processed
    auto = run(scn, backend="hybrid", fidelity="auto")
    g = auto.extras["granularity"]
    cut = base.events_processed / max(g["packet_lane_events"], 1)
    err = float(auto.fct_errors_vs(base).mean())
    assert cut >= 3.0, f"packet-lane event cut {cut:.2f}x < 3x"
    assert err < 0.01, f"mean FCT error {err:.4f} >= 1%"
    print(f"hybrid smoke ok: {cut:.2f}x packet-lane cut, "
          f"{100 * err:.2f}% mean FCT err, {g['demotions']} demotions")


if __name__ == "__main__":
    main()
