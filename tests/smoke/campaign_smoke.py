"""CI smoke: campaign resume — run half a sweep, re-open the campaign,
and the completed runs must be skipped (cache hits) while the remainder
simulates warm off the campaign's SimDB.

A real file with a ``__main__`` guard like its siblings (spawn workers
re-import the main module).  Invoked by the CI matrix as:

    PYTHONPATH=src:. python tests/smoke/campaign_smoke.py
"""
import os
import tempfile

from examples.quickstart import make_scenario
from repro.api import Campaign


def main():
    scn = make_scenario()
    variants = [scn.variant(name=f"c{s:g}", size_scale=s)
                for s in (1.0, 1.1, 1.2, 1.3)]
    with tempfile.TemporaryDirectory() as td:
        cdir = os.path.join(td, "campaign")
        with Campaign.open(cdir, name="smoke") as camp:
            half = camp.sweep(variants[:2], backend="wormhole")
        # "next session": only the campaign dir survives
        with Campaign.open(cdir) as camp:
            kinds = []
            camp.subscribe(lambda e: kinds.append(e.kind))
            results = camp.sweep(variants, backend="wormhole")
    assert kinds.count("cache_hit") == 2, kinds
    assert kinds.count("started") == kinds.count("finished") == 2, kinds
    assert results[0].fcts == half[0].fcts
    warm = results[-1]
    assert warm.kernel_report["run_db_hits"] > 0, warm.kernel_report
    assert warm.events_processed < half[0].events_processed / 10
    print("campaign resume smoke ok: 2 cache hits, 2 simulated,",
          f"warm run {warm.events_processed} events "
          f"(cold was {half[0].events_processed})")


if __name__ == "__main__":
    main()
