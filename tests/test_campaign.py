"""Campaign API: durable, resumable, observable experiment sessions.

Acceptance (ISSUE 5): a campaign killed after K of N sweep runs re-opens
and completes only the N−K remaining scenarios, with cache-hit events
observed for the K completed ones; a resubmitted identical scenario
returns the stored RunResult (equal through the JSON round-trip) without
invoking the engine.

This file doubles as the crash harness for the kill-mid-sweep test: run
directly (``python tests/test_campaign.py CAMPAIGN_DIR K``) it starts the
sweep and hard-exits (``os._exit`` — no atexit, no close, no flush)
after K committed runs.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (Campaign, Engine, FlowSpec, RunResult, Scenario,
                       SimDB, TopologySpec, register_engine, run_key)
from repro.api.engines import _REGISTRY
from repro.api.store import RunStore, scenario_fingerprint

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def flows_scenario(scale: float = 1.0, name: str = "camp-waves") -> Scenario:
    flows = []
    fid = 0
    for wave in (0.0, 0.02):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=4e6 * scale,
                                  start=wave, cca="dctcp"))
            fid += 1
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}), flows=flows)


def sweep_scenarios(n: int = 6) -> list[Scenario]:
    """The kill-mid-sweep scenario list — must build identically in the
    crash subprocess and the resuming parent (content-addressed keys)."""
    return [flows_scenario(1.0 + 0.1 * i, name=f"sw{i}") for i in range(n)]


class CountingEngine(Engine):
    """Registry-pluggable engine that counts invocations — how the tests
    prove a cache hit never reached an engine."""
    calls = 0

    def run(self, scenario, **opts):
        type(self).calls += 1
        return RunResult(backend=self.name, scenario=scenario.name,
                         fcts={f.fid: 1.0 + f.size * 1e-9
                               for f in scenario.flows},
                         flow_bytes={f.fid: f.size for f in scenario.flows},
                         tags={f.fid: f.tag for f in scenario.flows},
                         iteration_time=1.0, events_processed=7,
                         wall_time=0.0, extras={"probe": [1, 2]})


@pytest.fixture
def counting_engine():
    register_engine("counting")(CountingEngine)
    CountingEngine.calls = 0
    yield CountingEngine
    _REGISTRY.pop("counting", None)


# --------------------------------------------------------------------- #
# RunStore + keys
# --------------------------------------------------------------------- #
def test_run_key_is_content_addressed():
    a, b = flows_scenario(), flows_scenario()
    assert scenario_fingerprint(a) == scenario_fingerprint(b)
    assert run_key(a, "packet", {}) == run_key(b, "packet", {})
    assert run_key(a, "packet", {}) != run_key(a, "wormhole", {})
    assert run_key(a, "packet", {}) != run_key(a, "packet", {"until": 1.0})
    assert run_key(flows_scenario(1.1), "packet", {}) != \
        run_key(a, "packet", {})
    # opt *order* must not matter, only content
    assert run_key(a, "hybrid", {"fidelity": "auto", "demote_after": 4}) == \
        run_key(a, "hybrid", {"demote_after": 4, "fidelity": "auto"})


def test_run_key_uncacheable_and_array_opts():
    """Opts with no canonical JSON form never dedup (a repr could truncate
    or embed a reused memory address); ndarray opts key by content."""
    scn = flows_scenario()
    db = SimDB()
    assert run_key(scn, "wormhole", {"db": db}) != \
        run_key(scn, "wormhole", {"db": db})
    big = np.arange(2000)
    near = big.copy()
    near[1000] = -1                       # differs only in the repr-elided middle
    assert run_key(scn, "packet", {"x": big}) == \
        run_key(scn, "packet", {"x": big.copy()})
    assert run_key(scn, "packet", {"x": big}) != \
        run_key(scn, "packet", {"x": near})


def test_run_store_disk_roundtrip(tmp_path, counting_engine):
    store = RunStore(tmp_path / "runs")
    scn = flows_scenario()
    result = CountingEngine().run(scn)
    key = run_key(scn, "counting", {})
    assert store.get(key) is None and store.misses == 1
    store.put(key, scn, "counting", {}, result)
    assert key in store and len(store) == 1 and store.keys() == [key]
    rec = store.get(key)
    assert store.hits == 1
    assert rec["backend"] == "counting"
    assert rec["scenario"] == scn.to_dict()
    assert RunResult.from_dict(rec["result"]).to_dict() == result.to_dict()
    # no torn/tmp files left next to the committed record
    assert [p.name for p in (tmp_path / "runs").iterdir()] == [f"{key}.json"]
    # a fresh store over the same dir sees the same record
    again = RunStore(tmp_path / "runs")
    assert again.get(key) == rec
    assert again.delete(key) and not again.delete(key)
    assert len(again) == 0


def test_run_store_rejects_foreign_record_version(tmp_path, counting_engine):
    store = RunStore(tmp_path / "runs")
    scn = flows_scenario()
    key = run_key(scn, "counting", {})
    store.put(key, scn, "counting", {}, CountingEngine().run(scn))
    rec = json.loads((tmp_path / "runs" / f"{key}.json").read_text())
    rec["record_version"] = 99
    (tmp_path / "runs" / f"{key}.json").write_text(json.dumps(rec))
    with pytest.raises(ValueError, match="record_version"):
        store.get(key)


def test_records_skips_corrupt_files_with_warning(tmp_path, counting_engine):
    """A truncated/garbled record must not poison iteration (dataset
    extraction reads the whole store): it is skipped with a warning,
    surfaces in corrupt_keys(), reads as a miss, and a resubmission of the
    same triple heals it."""
    store = RunStore(tmp_path / "runs")
    scns = [flows_scenario(1.0 + 0.1 * i, name=f"c{i}") for i in range(3)]
    keys = [run_key(s, "counting", {}) for s in scns]
    for scn, key in zip(scns, keys):
        store.put(key, scn, "counting", {}, CountingEngine().run(scn))
    bad = tmp_path / "runs" / f"{keys[1]}.json"
    bad.write_text(bad.read_text()[:40])             # torn copy
    with pytest.warns(RuntimeWarning, match="corrupt run record"):
        recs = list(store.records())
    assert [r["key"] for r in recs] == sorted([keys[0], keys[2]])
    assert store.corrupt_keys() == [keys[1]]
    assert store.get(keys[1]) is None and keys[1] not in store
    # rewriting the record heals it without a stale corrupt marker
    store.put(keys[1], scns[1], "counting", {}, CountingEngine().run(scns[1]))
    assert store.corrupt_keys() == [] and store.get(keys[1]) is not None
    assert len(list(store.records())) == 3


def test_campaign_resubmit_heals_corrupt_record(tmp_path, counting_engine):
    with Campaign.open(tmp_path / "camp") as camp:
        h = camp.submit(flows_scenario(), backend="counting")
        rec_file = tmp_path / "camp" / "runs" / f"{h.key}.json"
        rec_file.write_text("{not json")
        with pytest.warns(RuntimeWarning, match="corrupt"):
            h2 = camp.submit(flows_scenario(), backend="counting")
    assert not h2.cached and CountingEngine.calls == 2
    assert json.loads(rec_file.read_text())["key"] == h.key


def test_run_store_in_memory_matches_disk_shape(tmp_path, counting_engine):
    scn = flows_scenario()
    result = CountingEngine().run(scn)
    key = run_key(scn, "counting", {})
    mem, disk = RunStore(None), RunStore(tmp_path / "runs")
    mem.put(key, scn, "counting", {}, result)
    disk.put(key, scn, "counting", {}, result)
    assert mem.get(key) == disk.get(key)   # same canonical JSON either way


# --------------------------------------------------------------------- #
# submit: dedup without invoking the engine
# --------------------------------------------------------------------- #
def test_submit_dedup_skips_engine(tmp_path, counting_engine):
    camp = Campaign.open(tmp_path / "camp", name="dedup")
    events = []
    camp.subscribe(lambda e: events.append(e.kind))
    h1 = camp.submit(flows_scenario(), backend="counting")
    h2 = camp.submit(flows_scenario(), backend="counting")
    assert CountingEngine.calls == 1
    assert not h1.cached and h2.cached and h1.key == h2.key
    assert events == ["started", "finished", "cache_hit"]
    # the cached result IS the stored one, equal through the JSON round-trip
    assert h2.result.to_dict() == h1.result.to_dict()
    assert h2.result.to_dict() == \
        json.loads(json.dumps(h1.result.to_dict()))
    assert h2.result.fcts == h1.result.fcts          # int keys restored
    # different opts are a different experiment
    camp.submit(flows_scenario(), backend="counting", until=2.0)
    assert CountingEngine.calls == 2
    camp.close()


def test_submit_dedup_survives_reopen(tmp_path, counting_engine):
    camp = Campaign.open(tmp_path / "camp")
    first = camp.submit(flows_scenario(), backend="counting").result
    camp.close()
    camp2 = Campaign.open(tmp_path / "camp")
    h = camp2.submit(flows_scenario(), backend="counting")
    assert h.cached and CountingEngine.calls == 1
    assert h.result.to_dict() == first.to_dict()
    camp2.close()


def test_sweep_dedups_identical_scenarios_within_one_call(counting_engine):
    camp = Campaign.in_memory()
    kinds = []
    camp.subscribe(lambda e: kinds.append(e.kind))
    results = camp.sweep([flows_scenario(), flows_scenario(),
                          flows_scenario(1.5, name="other")],
                         backend="counting")
    assert CountingEngine.calls == 2
    assert kinds.count("cache_hit") == 1
    assert results[0].fcts == results[1].fcts
    assert results[2].scenario == "other"


# --------------------------------------------------------------------- #
# durable campaign invariants
# --------------------------------------------------------------------- #
def test_durable_campaign_owns_its_simdb(tmp_path):
    with pytest.raises(ValueError, match="owns its SimDB"):
        Campaign(tmp_path / "camp", db=SimDB())
    camp = Campaign.open(tmp_path / "camp")
    with pytest.raises(ValueError, match="owns its SimDB"):
        camp.submit(flows_scenario(), backend="wormhole", db=SimDB())
    with pytest.raises(ValueError, match="owns its SimDB"):
        camp.sweep([flows_scenario()], backend="wormhole", db=SimDB())
    camp.close()


def test_manifest_roundtrip_and_version_check(tmp_path):
    camp = Campaign.open(tmp_path / "camp", name="paper-sweeps")
    camp.close()
    assert Campaign.open(tmp_path / "camp").name == "paper-sweeps"
    manifest = tmp_path / "camp" / "campaign.json"
    manifest.write_text(json.dumps({"manifest_version": 99}))
    with pytest.raises(ValueError, match="manifest_version"):
        Campaign.open(tmp_path / "camp")


def test_campaign_simdb_warms_across_sessions(tmp_path):
    """The campaign's own SimDB (no caller-managed file plumbing)
    fast-forwards a new variant submitted in a later session."""
    camp = Campaign.open(tmp_path / "camp")
    cold = camp.submit(flows_scenario(1.0, name="v1"),
                       backend="wormhole").result
    camp.close()
    assert (tmp_path / "camp" / "simdb.json").exists()
    camp2 = Campaign.open(tmp_path / "camp")
    warm = camp2.submit(flows_scenario(1.1, name="v2"),
                        backend="wormhole").result
    assert warm.kernel_report["run_db_hits"] > 0
    assert warm.events_processed < cold.events_processed
    camp2.close()


def test_results_and_records_filters(counting_engine):
    camp = Campaign.in_memory()
    camp.submit(flows_scenario(name="a"), backend="counting")
    camp.submit(flows_scenario(name="b"), backend="counting")
    camp.submit(flows_scenario(name="a"), backend="analytic")
    assert len(camp.results()) == 3 and len(camp) == 3
    assert len(camp.results(backend="counting")) == 2
    assert {r["scenario"]["name"]
            for r in camp.records(backend="analytic")} == {"a"}
    assert len(camp.results(scenario="a")) == 2
    assert all(isinstance(r, RunResult) for r in camp.results())


def test_campaign_compare_hits_store_on_repeat(counting_engine):
    camp = Campaign.in_memory()
    cmp1 = camp.compare(flows_scenario(), backends=("counting", "analytic"))
    calls = CountingEngine.calls
    cmp2 = camp.compare(flows_scenario(), backends=("counting", "analytic"))
    assert CountingEngine.calls == calls           # all served from store
    assert cmp2["counting"].to_dict() == cmp1["counting"].to_dict()
    with pytest.raises(ValueError, match="baseline"):
        camp.compare(flows_scenario(), backends=("counting",),
                     baseline="analytic")


def test_observer_unsubscribe(counting_engine):
    camp = Campaign.in_memory()
    seen = []
    cb = camp.subscribe(lambda e: seen.append(e.kind))
    camp.submit(flows_scenario(), backend="counting")
    camp.unsubscribe(cb)
    camp.submit(flows_scenario(1.2, name="other"), backend="counting")
    assert seen == ["started", "finished"]


# --------------------------------------------------------------------- #
# the acceptance test: kill mid-sweep, re-open, resume
# --------------------------------------------------------------------- #
def _crash_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_kill_mid_sweep_resume(tmp_path):
    """A campaign hard-killed after K of N sweep runs re-opens and
    completes only the N−K remainder; the K completed runs surface as
    cache-hit events; a resubmitted identical scenario returns the stored
    result without simulating."""
    cdir = str(tmp_path / "camp")
    n, k = 6, 3
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), cdir, str(k)],
        env=_crash_env(), capture_output=True, text=True, timeout=300)
    assert proc.returncode == 17, (proc.stdout, proc.stderr)

    camp = Campaign.open(cdir)
    assert len(camp.store) == k        # exactly K committed, none torn
    stored_before = {key: camp.store._peek(key) for key in camp.store.keys()}

    events = []
    camp.subscribe(events.append)
    results = camp.sweep(sweep_scenarios(n), backend="analytic")
    kinds = [e.kind for e in events]
    assert kinds.count("cache_hit") == k
    assert kinds.count("started") == kinds.count("finished") == n - k
    assert all(r is not None for r in results)
    # the K cached results came through the JSON round-trip unchanged
    hit_keys = {e.key for e in events if e.kind == "cache_hit"}
    assert hit_keys == set(stored_before)
    for e in events:
        if e.kind == "cache_hit":
            assert e.result.to_dict() == stored_before[e.key]["result"]

    # resubmitting one completed scenario is a pure store read
    h = camp.submit(sweep_scenarios(n)[0], backend="analytic")
    assert h.cached
    assert h.result.to_dict() == results[0].to_dict()
    camp.close()

    # a fully resumed campaign has nothing left to run
    camp2 = Campaign.open(cdir)
    kinds2 = []
    camp2.subscribe(lambda e: kinds2.append(e.kind))
    camp2.sweep(sweep_scenarios(n), backend="analytic")
    assert kinds2.count("cache_hit") == n and "started" not in kinds2
    camp2.close()


@pytest.mark.slow
def test_parallel_sweep_resume_with_workers(tmp_path):
    """workers=2 sweeps commit incrementally too: a half sweep's results
    are all cache hits for the full parallel sweep that follows."""
    cdir = tmp_path / "camp"
    scns = [flows_scenario(1.0 + 0.1 * i, name=f"p{i}") for i in range(4)]
    camp = Campaign.open(cdir)
    camp.sweep(scns[:2], backend="wormhole", workers=2)
    camp.close()
    camp2 = Campaign.open(cdir)
    kinds = []
    camp2.subscribe(lambda e: kinds.append(e.kind))
    results = camp2.sweep(scns, backend="wormhole", workers=2)
    assert kinds.count("cache_hit") == 2
    assert kinds.count("finished") == 2
    assert [r.scenario for r in results] == [s.name for s in scns]
    # the campaign DB accumulated entries from both sessions' workers
    assert len(camp2.db) > 0
    camp2.close()


# --------------------------------------------------------------------- #
# CLI (python -m repro) over the same API
# --------------------------------------------------------------------- #
def _cli(*args, cwd=None):
    return subprocess.run([sys.executable, "-m", "repro", *args],
                          env=_crash_env(), capture_output=True, text=True,
                          cwd=cwd, timeout=300)


def test_cli_run_ls_show_rm_roundtrip(tmp_path):
    scn_file = tmp_path / "demo.json"
    scn_file.write_text(flows_scenario(name="cli-demo").to_json())
    cdir = str(tmp_path / "camp")

    out = _cli("run", str(scn_file), "--backend", "analytic", "-c", cdir)
    assert out.returncode == 0, out.stderr
    assert "cli-demo" in out.stdout and "running" in out.stdout

    # second invocation of the same triple is a cache hit
    out = _cli("run", str(scn_file), "--backend", "analytic", "-c", cdir)
    assert out.returncode == 0 and "cache hit" in out.stdout

    out = _cli("sweep", str(scn_file), "--backend", "analytic", "-c", cdir)
    assert out.returncode == 0, out.stderr
    assert "1 from the store, 0 simulated" in out.stdout

    out = _cli("ls", "-c", cdir)
    assert out.returncode == 0 and "analytic" in out.stdout
    key = out.stdout.split()[0]

    out = _cli("show", key, "-c", cdir)
    assert out.returncode == 0
    rec = json.loads(out.stdout)
    assert rec["backend"] == "analytic"
    assert rec["scenario"]["name"] == "cli-demo"

    assert _cli("show", "deadbeef", "-c", cdir).returncode == 1
    # rm refuses an ambiguous prefix (two stored runs share the empty one)
    _cli("run", str(scn_file), "--backend", "packet", "-c", cdir)
    bad = _cli("rm", "", "-c", cdir)
    assert bad.returncode == 1 and "ambiguous" in bad.stderr
    out = _cli("rm", key, "-c", cdir)
    assert out.returncode == 0 and "removed 1" in out.stdout
    assert "1 stored runs" in _cli("ls", "-c", cdir).stdout


def test_cli_engine_opts_reach_the_engine(tmp_path):
    scn_file = tmp_path / "demo.json"
    scn_file.write_text(flows_scenario(name="cli-opts").to_json())
    out = _cli("run", str(scn_file), "--backend", "hybrid",
               "--opt", "fidelity=flow")
    assert out.returncode == 0, out.stderr
    # a bad opt value must fail loudly, not run with defaults
    bad = _cli("run", str(scn_file), "--backend", "hybrid",
               "--opt", "fidelity=warp")
    assert bad.returncode != 0


if __name__ == "__main__":
    # crash harness: sweep, then hard-exit (no atexit/close) after K commits
    cdir, k = sys.argv[1], int(sys.argv[2])
    camp = Campaign.open(cdir)
    done = [0]

    def _chaos(event):
        if event.kind == "finished":
            done[0] += 1
            if done[0] >= k:
                os._exit(17)

    camp.subscribe(_chaos)
    camp.sweep(sweep_scenarios(), backend="analytic")
    os._exit(0)                        # not reached when k < len(sweep)
