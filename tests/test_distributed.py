"""Multi-device integration: pipeline parallelism and a small-mesh dry-run,
each in a subprocess with forced host device counts (so the main test
process keeps its single CPU device)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_py(code: str, devices: int, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_pipeline_parallel_matches_sequential():
    r = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.parallel.pipeline import pipeline_apply, stage_split
        mesh = jax.make_mesh((4,), ("pipe",))
        L, D, M, mb = 8, 16, 8, 4
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (L, D, D)) * 0.1
        def layer(w, x):
            return jnp.tanh(x @ w)
        def stage_fn(stage_params, x):
            def body(h, w):
                return layer(w, h), None
            h, _ = jax.lax.scan(body, x, stage_params)
            return h
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
        # sequential reference
        ref = xs
        def seq_body(h, w):
            return layer(w, h), None
        ref, _ = jax.lax.scan(seq_body, xs.reshape(M * mb, D), Ws)
        ref = ref.reshape(M, mb, D)
        staged = stage_split(Ws, 4)
        out = pipeline_apply(mesh, stage_fn, M)(staged, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("PP_OK")
    """, devices=4)
    assert "PP_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_small_mesh_dryrun_lowers_and_compiles():
    """8-device (2x4) mini-mesh: the same lower+compile path as the
    production dry-run, on a reduced arch (fast, real collectives)."""
    r = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import ARCHS
        from repro.models.api import build_model
        from repro.parallel.sharding import rules_for, tree_shardings, batch_shardings
        from repro.train import optimizer as O
        cfg = ARCHS["granite-3-2b"].reduced()
        model = build_model(cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = rules_for(cfg, mesh, "train")
        ps = model.param_structs()
        psh = tree_shardings(model.param_axes(), ps, rules, mesh)
        inputs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bsh = batch_shardings(inputs, rules, mesh)
        ocfg = O.AdamWConfig()
        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
            p2, o2, m = O.update(params, grads, opt, ocfg)
            return p2, o2, loss
        opt_structs = {"m": ps, "v": ps,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}
        osh = {"m": psh, "v": psh,
               "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        with mesh:
            lowered = jax.jit(train_step, in_shardings=(psh, osh, bsh)).lower(
                ps, opt_structs, inputs)
            compiled = lowered.compile()
        assert compiled.memory_analysis() is not None
        # and actually EXECUTE one step on the 8 fake devices
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), psh)
        opt = jax.device_put(O.init_state(params, ocfg), osh)
        batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
                 "labels": jnp.ones((8, 64), jnp.int32)}
        p2, o2, loss = jax.jit(train_step, in_shardings=(psh, osh, bsh))(
            params, opt, batch)
        assert bool(jnp.isfinite(loss))
        print("DRYRUN_OK", float(loss))
    """, devices=8)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_dryrun_artifacts_complete_and_clean():
    """Every (arch x shape x mesh) cell either succeeded or is an explicit
    documented skip — 68 artifacts, 0 errors."""
    art = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists() or len(list(art.glob("*.json"))) < 10:
        pytest.skip("dry-run sweep artifacts not generated in this checkout")
    recs = [json.loads(p.read_text()) for p in art.glob("*.json")]
    assert all(r["status"] == "ok" for r in recs), \
        [r for r in recs if r["status"] != "ok"][:2]
    from repro.configs.registry import cells
    expected = 2 * sum(1 for (_, _, skip) in cells() if skip is None)
    assert len(recs) == expected, (len(recs), expected)
